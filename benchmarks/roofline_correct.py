"""Scan-trip-count correction for the roofline table.

XLA's ``cost_analysis()`` counts a while-loop (lax.scan) body ONCE, so the
reported FLOPs/bytes for an L-layer model miss a factor ~L on the layer
stack.  We recover per-layer costs with a two-point extrapolation: lower the
same (arch, shape) at two small layer counts (La, Lb), then

    f(L) = f(La) + (L - La) * (f(Lb) - f(La)) / (Lb - La)

which is exact when layers are homogeneous (DeepSeek's dense layer 0 is
included in both points, so it cancels).  Collective bytes are corrected the
same way.  Usage:

  PYTHONPATH=src python -m benchmarks.roofline_correct \
      [--out benchmarks/results/roofline_corrected.json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

from repro.configs.base import ARCH_IDS, SHAPES, get_arch

# valid small layer counts per arch (vlm: multiples of 5; zamba: of 6;
# deepseek: > first_dense_layers)
LAYER_POINTS = {
    "llama-3.2-vision-11b": (5, 10),
    "zamba2-2.7b": (6, 12),
    "deepseek-v2-236b": (2, 4),
    "seamless-m4t-medium": (2, 4),
}
DEFAULT_POINTS = (1, 3)

FIELDS = ("flops_per_dev", "bytes_per_dev")


def run_point(arch, shape, layers, out):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--layers", str(layers), "--tag",
           f"L{layers}", "--out", out]
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_SCAN_UNROLL="64")
    subprocess.run(cmd, check=False, capture_output=True, env=env)


def load_jsonl(path):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out",
                    default="benchmarks/results/roofline_corrected.json")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default="")
    args = ap.parse_args()
    archs = args.archs.split(",") if args.archs else ARCH_IDS
    shapes = args.shapes.split(",") if args.shapes else list(SHAPES)

    base = {(r["arch"], r["shape"]): r for r in load_jsonl(
        "benchmarks/results/dryrun_single_pod.jsonl")}
    tmp = tempfile.mktemp(suffix=".jsonl")
    corrected = {}
    for arch in archs:
        La, Lb = LAYER_POINTS.get(arch, DEFAULT_POINTS)
        L = get_arch(arch).num_layers
        for shape in shapes:
            run_point(arch, shape, La, tmp)
            run_point(arch, shape, Lb, tmp)
            recs = {r["tag"]: r for r in load_jsonl(tmp)
                    if r["arch"] == arch and r["shape"] == shape
                    and r["status"] == "ok"}
            open(tmp, "w").close()
            ra, rb = recs.get(f"L{La}"), recs.get(f"L{Lb}")
            if not (ra and rb):
                print(f"[correct] {arch} x {shape}: point failed, skipping",
                      flush=True)
                continue
            out = dict(base.get((arch, shape), {}))
            for f in FIELDS:
                slope = (rb[f] - ra[f]) / (Lb - La)
                out[f + "_corr"] = ra[f] + (L - La) * slope
            ca = ra["collective_bytes_per_dev"].get("total", 0.0)
            cb = rb["collective_bytes_per_dev"].get("total", 0.0)
            out["coll_bytes_corr"] = ca + (L - La) * (cb - ca) / (Lb - La)
            from repro.roofline.analysis import roofline_terms
            terms = roofline_terms(out["flops_per_dev_corr"],
                                   out["bytes_per_dev_corr"],
                                   out["coll_bytes_corr"])
            out.update({k + "_corr": v for k, v in terms.items()})
            if out.get("flops_per_dev_corr"):
                out["useful_flops_ratio_corr"] = (
                    out.get("model_flops_per_dev", 0.0)
                    / out["flops_per_dev_corr"])
            corrected[f"{arch}|{shape}"] = out
            print(f"[correct] {arch} x {shape}: "
                  f"flops {out.get('flops_per_dev', 0):.2e} -> "
                  f"{out['flops_per_dev_corr']:.2e}, dom "
                  f"{out.get('dominant')} -> {out['dominant_corr']}",
                  flush=True)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(corrected, f, indent=1)
    print(f"[correct] wrote {args.out} ({len(corrected)} combos)")


if __name__ == "__main__":
    main()
