"""Render the dry-run JSONL records into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m benchmarks.roofline_report \
      benchmarks/results/dryrun_single_pod.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(paths):
    recs = []
    for p in paths:
        with open(p) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    # keep the latest record per (arch, shape, mesh, tag)
    latest = {}
    for r in recs:
        latest[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return list(latest.values())


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x * 1e9:.1f}ns"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1.0:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def table(recs):
    rows = ["| arch | shape | mesh | compute | memory | collective | "
            "dominant | useful-FLOPs | status |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | -"
                        f" | - | - | - | FAIL: {r.get('error', '')[:60]} |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | {r['dominant']} | "
            f"{100 * r.get('useful_flops_ratio', 0):.0f}% | ok |")
    return "\n".join(rows)


def main():
    paths = sys.argv[1:] or ["benchmarks/results/dryrun_single_pod.jsonl"]
    recs = load(paths)
    print(table(recs))
    n_ok = sum(r["status"] == "ok" for r in recs)
    print(f"\n{n_ok}/{len(recs)} combos OK")
    doms = defaultdict(int)
    for r in recs:
        if r["status"] == "ok":
            doms[r["dominant"]] += 1
    print("dominant-term histogram:", dict(doms))


if __name__ == "__main__":
    main()
