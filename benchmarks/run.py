"""Benchmark harness — one function per paper table/figure.

  Table I   -> bench_provider_ap          (per-provider mAP / AP50 / AP75)
  Fig. 2    -> bench_ensemble_combos      (AP50 of provider combinations)
  Table II  -> bench_baselines            (Random-1/N, Ensemble-N, Armol
                                           w/gt, w/o gt, PPO, TD3, UB)
  Fig. 6/7  -> bench_baselines also emits per-epoch AP50/cost curves
  Table III -> bench_scalability          (10 providers, 1023 actions)
  kernels   -> bench_kernels              (us_per_call vs jnp reference)

Budgets are sized for the CPU container; set REPRO_BENCH_EPOCHS /
REPRO_BENCH_IMAGES / REPRO_BENCH_STEPS to scale up (paper scale: 100
epochs x 2000 steps, batch 1000).  Results land in benchmarks/results/
*.json and are printed as ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import itertools
import json
import os
import sys
import time

import numpy as np

# REPRO_RESULTS_DIR lets tools/check_bench.py collect fresh numbers in a
# scratch dir without clobbering the committed baselines
RESULTS_DIR = os.environ.get(
    "REPRO_RESULTS_DIR",
    os.path.join(os.path.dirname(__file__), "results"))

EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "3"))
IMAGES = int(os.environ.get("REPRO_BENCH_IMAGES", "400"))
STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "400"))


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _save(name: str, obj) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def _traces():
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces
    return generate_traces(default_providers(), IMAGES, seed=0)


def _best_of(*fns, rounds: int = 3, warmup: bool = True):
    """Best-of-``rounds`` wall seconds for each candidate in ``fns``.

    The candidates' timed passes interleave round-by-round (fn0, fn1,
    ..., fn0, fn1, ...), so a load spike on a shared machine hits every
    candidate instead of biasing whichever ran during the spike; each
    keeps its best round.  ``warmup`` runs one untimed pass of each
    first, absorbing jit/compile/memo cost — turn it off for cold-path
    benchmarks whose setup cost IS the measurement.  Returns a float for
    a single candidate, else a list in ``fns`` order.
    """
    if warmup:
        for fn in fns:
            fn()
    best = [float("inf")] * len(fns)
    for _ in range(rounds):
        for k, fn in enumerate(fns):
            t0 = time.time()
            fn()
            best[k] = min(best[k], time.time() - t0)
    return best[0] if len(fns) == 1 else best


# ---------------------------------------------------------------------------
# Table I: per-provider AP
# ---------------------------------------------------------------------------

def bench_provider_ap(traces=None):
    from repro.ensemble.metrics import average_precision, ap50, coco_map
    traces = traces if traces is not None else _traces()
    gts = {i: g for i, g in enumerate(traces.gts)}
    rows = {}
    t0 = time.time()
    for pi, p in enumerate(traces.providers):
        dts = {i: traces.dets[i][pi] for i in range(len(traces))}
        rows[p.name] = {
            "mAP": round(100 * coco_map(dts, gts), 2),
            "AP50": round(100 * ap50(dts, gts), 2),
            "AP75": round(100 * average_precision(dts, gts, iou_thr=0.75),
                          2)}
    us = (time.time() - t0) * 1e6 / max(len(traces) * 3, 1)
    _save("table1_provider_ap", rows)
    for name, r in rows.items():
        _emit(f"table1/{name}", us,
              f"mAP={r['mAP']};AP50={r['AP50']};AP75={r['AP75']}")
    return rows


# ---------------------------------------------------------------------------
# Fig. 2: ensemble combinations
# ---------------------------------------------------------------------------

def bench_ensemble_combos(traces=None):
    from repro.ensemble.metrics import ap50
    from repro.ensemble.pipeline import ensemble_detections
    traces = traces if traces is not None else _traces()
    gts = {i: g for i, g in enumerate(traces.gts)}
    names = [p.name for p in traces.providers]
    rows = {}
    t0 = time.time()
    for r in range(1, len(names) + 1):
        for combo in itertools.combinations(range(len(names)), r):
            dts = {i: ensemble_detections([traces.dets[i][c] for c in combo])
                   for i in range(len(traces))}
            rows["+".join(names[c] for c in combo)] = round(
                100 * ap50(dts, gts), 2)
    us = (time.time() - t0) * 1e6 / max(len(rows) * len(traces), 1)
    _save("fig2_ensemble_combos", rows)
    for k, v in rows.items():
        _emit(f"fig2/{k}", us, f"AP50={v}")
    return rows


# ---------------------------------------------------------------------------
# Table II: baselines + Armol variants (+ Fig. 6/7 curves)
# ---------------------------------------------------------------------------

def _agent_row(history):
    last = history[-1]
    return {"mAP": round(last["map"], 2), "AP50": round(last["ap50"], 2),
            "cost": round(last["cost"], 3), "counts": last["counts"]}


def bench_baselines(traces=None):
    from repro.core.loops import (ensembleN_policy, evaluate_policy,
                                  random1_policy, randomN_policy, run_ppo,
                                  run_off_policy, upper_bound)
    from repro.core.ppo import PPO, PPOConfig
    from repro.core.sac import SAC, SACConfig
    from repro.core.td3 import TD3, TD3Config
    from repro.federation.env import ArmolEnv
    traces = traces if traces is not None else _traces()
    rows = {}
    histories = {}
    t0 = time.time()

    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    for name, pol in (("Random-1", random1_policy(env, seed=0)),
                      ("Random-N", randomN_policy(env, seed=0)),
                      ("Ensemble-N", ensembleN_policy(env))):
        r = evaluate_policy(pol, env)
        rows[name] = {"mAP": round(r["map"], 2),
                      "AP50": round(r["ap50"], 2),
                      "cost": round(r["cost"], 3), "counts": r["counts"]}

    def kw():
        return dict(epochs=EPOCHS, steps_per_epoch=STEPS, batch_size=256,
                    start_steps=min(STEPS, 500),
                    update_after=min(STEPS, 300), update_every=50,
                    update_iters=50, log=None)

    sac = SAC(SACConfig(state_dim=env.state_dim,
                        n_providers=env.n_providers, alpha=0.02))
    histories["Armol-w/ gt"] = run_off_policy(sac, env, **kw())
    rows["Armol-w/ gt"] = _agent_row(histories["Armol-w/ gt"])

    env_nogt = ArmolEnv(traces, mode="nogt", beta=-0.1, seed=1)
    sac2 = SAC(SACConfig(state_dim=env_nogt.state_dim,
                         n_providers=env_nogt.n_providers, alpha=0.02))
    histories["Armol-w/o gt"] = run_off_policy(sac2, env_nogt, **kw())
    rows["Armol-w/o gt"] = _agent_row(histories["Armol-w/o gt"])

    ppo = PPO(PPOConfig(state_dim=env.state_dim,
                        n_providers=env.n_providers))
    histories["Armol-PPO"] = run_ppo(ppo, env, epochs=EPOCHS,
                                     steps_per_epoch=STEPS, log=None)
    rows["Armol-PPO"] = _agent_row(histories["Armol-PPO"])

    td3 = TD3(TD3Config(state_dim=env.state_dim,
                        n_providers=env.n_providers))
    histories["Armol-TD3"] = run_off_policy(td3, env, **kw())
    rows["Armol-TD3"] = _agent_row(histories["Armol-TD3"])

    ub = upper_bound(env)
    rows["Upper Bound"] = {"mAP": round(ub["map"], 2),
                           "AP50": round(ub["ap50"], 2),
                           "cost": round(ub["cost"], 3),
                           "counts": ub["counts"]}
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    _save("table2_baselines", rows)
    _save("fig6_training_curves", histories)
    for k, v in rows.items():
        _emit(f"table2/{k}", us,
              f"mAP={v['mAP']};AP50={v['AP50']};cost={v['cost']}")
    return rows


# ---------------------------------------------------------------------------
# Table III: scalability to 10 providers (1023 actions)
# ---------------------------------------------------------------------------

def bench_scalability():
    from repro.core.loops import evaluate_policy, run_off_policy, \
        ensembleN_policy
    from repro.core.sac import SAC, SACConfig
    from repro.ensemble.metrics import ap50
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import scalability_providers
    from repro.federation.traces import generate_traces
    t0 = time.time()
    traces = generate_traces(scalability_providers(), IMAGES, seed=0)
    gts = {i: g for i, g in enumerate(traces.gts)}
    rows = {}
    for pi, p in enumerate(traces.providers):
        dts = {i: traces.dets[i][pi] for i in range(len(traces))}
        rows[f"MLaaS {pi}"] = {"AP50": round(100 * ap50(dts, gts), 2),
                               "cost": 1.0}
    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    r = evaluate_policy(ensembleN_policy(env), env)
    rows["All"] = {"AP50": round(r["ap50"], 2), "cost": round(r["cost"], 2)}
    sac = SAC(SACConfig(state_dim=env.state_dim,
                        n_providers=env.n_providers, alpha=0.02))
    hist = run_off_policy(sac, env, epochs=EPOCHS, steps_per_epoch=STEPS,
                          batch_size=256, start_steps=min(STEPS, 500),
                          update_after=min(STEPS, 300), update_every=50,
                          update_iters=50, log=None)
    rows["Armol"] = {"AP50": round(hist[-1]["ap50"], 2),
                     "cost": round(hist[-1]["cost"], 3)}
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    _save("table3_scalability", rows)
    _save("fig8_training_curve_10p", hist)
    for k, v in rows.items():
        _emit(f"table3/{k}", us, f"AP50={v['AP50']};cost={v['cost']}")
    return rows


# ---------------------------------------------------------------------------
# Subset-evaluation core: cached/batched vs the seed per-pair path
# ---------------------------------------------------------------------------

def bench_subset_cache():
    """Upper-bound-style enumeration of all 2^N - 1 subsets per test image
    (paper Algo. 2 / Tab. III regime) through the memoized
    ``SubsetEvaluationCore`` vs the frozen seed implementation
    (``benchmarks/seed_reference.py``).  Also reports the warm-cache pass
    (every (image, subset) pair already memoized — the steady state of a
    multi-epoch training run).  Per-image interleaving keeps the
    comparison fair on noisy shared machines.
    """
    sys.path.insert(0, os.path.dirname(__file__))
    from seed_reference import seed_ensemble_detections, seed_image_ap50
    from repro.core.loops import enumeration_actions
    from repro.federation.evaluation import SubsetEvaluationCore
    from repro.federation.providers import scalability_providers
    from repro.federation.traces import generate_traces

    n_prov = 7
    n_images = min(IMAGES, 60)
    traces = generate_traces(scalability_providers()[:n_prov], n_images,
                             seed=0)
    actions = enumeration_actions(n_prov)
    core = SubsetEvaluationCore(traces)
    masks = [core.mask_of(a) for a in actions]
    n_pairs = n_images * len(actions)

    seed_s = cached_s = 0.0
    mismatches = 0
    max_ap_diff = 0.0
    for img in range(n_images):
        gt = traces.gts[img]
        t0 = time.time()
        best_v, best_a = -1.0, None
        for a in actions:
            sel = [traces.dets[img][i] for i in range(n_prov) if a[i] > 0.5]
            v = seed_image_ap50(seed_ensemble_detections(sel), gt)
            if v > best_v:
                best_v, best_a = v, a
        seed_s += time.time() - t0
        t0 = time.time()
        best_m, best_vc = core.best_subset(img, masks)
        cached_s += time.time() - t0
        max_ap_diff = max(max_ap_diff, abs(best_v - best_vc))
        if core.mask_of(best_a) != best_m:
            mismatches += 1
    t0 = time.time()
    for img in range(n_images):
        core.best_subset(img, masks)
    warm_s = time.time() - t0

    out = {"n_providers": n_prov, "n_actions": len(actions),
           "n_images": n_images, "n_pairs": n_pairs,
           "seed_s": round(seed_s, 3), "cached_cold_s": round(cached_s, 3),
           "cached_warm_s": round(warm_s, 4),
           "speedup_cold": round(seed_s / max(cached_s, 1e-9), 2),
           "speedup_warm": round(seed_s / max(warm_s, 1e-9), 1),
           "best_subset_mismatches": mismatches,
           "max_best_ap50_diff": max_ap_diff,
           "cache": core.cache_sizes(), "stats": dict(core.stats)}
    assert mismatches == 0, \
        f"cached upper-bound picked different subsets on {mismatches} images"
    _save("subset_cache", out)
    _emit("subset_cache/seed", 1e6 * seed_s / n_pairs,
          f"total={out['seed_s']}s")
    _emit("subset_cache/cached_cold", 1e6 * cached_s / n_pairs,
          f"speedup={out['speedup_cold']}x")
    _emit("subset_cache/cached_warm", 1e6 * warm_s / n_pairs,
          f"speedup={out['speedup_warm']}x")
    return out


# ---------------------------------------------------------------------------
# Full-lattice subset evaluation vs the memoized per-bitmask loop
# ---------------------------------------------------------------------------

def bench_lattice():
    """One vectorized pass over all 2^N - 1 subsets per image
    (``evaluate_lattice``) vs the memoized per-bitmask enumeration
    (``best_subset``), at N in {5, 7, 10}, plus a first N=12 exact
    oracle: ``upper_bound`` end to end — 4095 subsets per test image.

    Both paths start COLD every round (fresh cores, so IoU tables and
    memo rebuild) because the lattice's win IS the cold path — warm,
    both are memo lookups.  Rounds interleave loop/lattice via the
    shared best-of harness so machine noise hits both, and the
    regression gate (tools/check_bench.py) checks the speedup RATIOS at
    N=7 and N=10, which cancel absolute machine speed.  The N=12 loop
    time is projected from a strided subsample of masks (popcount-order
    stride keeps the ensemble-size mixture representative) — running
    the full loop at N=12 is exactly what the lattice exists to avoid.
    """
    from repro.core.loops import upper_bound
    from repro.federation.env import ArmolEnv
    from repro.federation.evaluation import SubsetEvaluationCore, \
        popcount_masks
    from repro.federation.providers import lattice_stress_providers
    from repro.federation.traces import generate_traces

    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "3"))
    n_images = min(IMAGES, 12)
    out = {"n_images": n_images, "rounds": rounds, "sizes": {}}
    for n_prov in (5, 7, 10):
        traces = generate_traces(lattice_stress_providers(n_prov),
                                 n_images, seed=0)
        masks = popcount_masks(n_prov)
        picks = {}

        def run_loop():
            core = SubsetEvaluationCore(traces)
            picks["loop"] = [core.best_subset(i, masks)
                             for i in range(n_images)]

        def run_lattice():
            core = SubsetEvaluationCore(traces)
            rows = []
            for i in range(n_images):
                lat = core.evaluate_lattice(i)
                j = int(np.argmax(lat.ap))
                rows.append((int(lat.masks[j]), float(lat.ap[j])))
            picks["lattice"] = rows

        loop_s, lat_s = _best_of(run_loop, run_lattice, rounds=rounds,
                                 warmup=False)
        mismatches = sum(a != b for a, b in zip(picks["loop"],
                                                picks["lattice"]))
        assert mismatches == 0, \
            f"lattice argmax disagrees with best_subset on {mismatches} " \
            f"images at N={n_prov}"
        row = {"n_subsets": len(masks), "loop_s": round(loop_s, 3),
               "lattice_s": round(lat_s, 3),
               "speedup": round(loop_s / max(lat_s, 1e-9), 2)}
        out["sizes"][f"n{n_prov}"] = row
        _emit(f"lattice/n{n_prov}",
              1e6 * lat_s / (n_images * len(masks)),
              f"loop={row['loop_s']}s;lattice={row['lattice_s']}s;"
              f"speedup={row['speedup']}x")
    out["speedup_n7"] = out["sizes"]["n7"]["speedup"]
    out["speedup_n10"] = out["sizes"]["n10"]["speedup"]

    # N=12: the first exact oracle at 4095 subsets/image, end to end
    n12 = 12
    tr12 = generate_traces(lattice_stress_providers(n12), n_images, seed=0)
    env = ArmolEnv(tr12, mode="gt", beta=0.0, seed=1)
    t0 = time.time()
    ub = upper_bound(env)
    ub_s = time.time() - t0
    masks12 = popcount_masks(n12)
    sample = masks12[::64]              # strided over popcount order
    core = SubsetEvaluationCore(tr12)
    img0 = int(env.test_idx[0])
    core.precompute([img0])
    t0 = time.time()
    for m in sample:
        core.ap50(img0, m)
    loop12_proj = ((time.time() - t0) / len(sample)
                   * len(masks12) * len(env.test_idx))
    out["n12_oracle"] = {
        "n_subsets": len(masks12), "test_images": len(env.test_idx),
        "upper_bound_s": round(ub_s, 2),
        "loop_projected_s": round(loop12_proj, 1),
        "projected_speedup": round(loop12_proj / max(ub_s, 1e-9), 1),
        "ub_ap50": round(ub["ap50"], 2), "ub_cost": round(ub["cost"], 3)}
    _emit("lattice/n12_oracle", 1e6 * ub_s / max(len(env.test_idx), 1),
          f"upper_bound={out['n12_oracle']['upper_bound_s']}s;"
          f"loop_projected={out['n12_oracle']['loop_projected_s']}s;"
          f"ap50={out['n12_oracle']['ub_ap50']}")
    _save("lattice", out)
    return out


# ---------------------------------------------------------------------------
# Training drivers: multi-lane batched vs sequential reference steps/sec
# ---------------------------------------------------------------------------

def bench_train_driver():
    """Off-policy (SAC) and on-policy (PPO) training throughput of the
    multi-lane drivers (``step_lanes`` + ``add_batch`` + fused
    ``lax.scan`` update blocks) vs the frozen sequential references, at
    REPRO_BENCH_LANES lanes (default 8).  Subset-evaluation tables are
    prewarmed and both paths get a short compile warmup, so the numbers
    compare steady-state driver overhead, not jit or IoU-table cost.
    """
    from repro.core.loops import (run_off_policy, run_offpolicy_sequential,
                                  run_ppo, run_ppo_sequential)
    from repro.core.ppo import PPO, PPOConfig
    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces

    lanes = int(os.environ.get("REPRO_BENCH_LANES", "8"))
    n_images = min(IMAGES, 120)
    steps = STEPS
    traces = generate_traces(default_providers(), n_images, seed=0)
    env = ArmolEnv(traces, mode="gt", beta=-0.03, seed=1)
    env.core.precompute(np.arange(len(traces)))

    # paper-scale selector heads (3 providers need no 256x256 MLPs); the
    # benchmark compares driver overhead, so the gradient-step compute —
    # identical math on both paths — is kept at the problem's actual size
    def sac():
        return SAC(SACConfig(state_dim=env.state_dim,
                             n_providers=env.n_providers, alpha=0.02,
                             hidden=(32, 32)))

    def ppo():
        return PPO(PPOConfig(state_dim=env.state_dim,
                             n_providers=env.n_providers, hidden=(32, 32)))

    # start_steps/update_after are lane-multiples so both paths run the
    # same shapes end-to-end (no mixed explore/policy partial batches)
    burn = min(12 * lanes, steps // 4 - steps // 4 % lanes)
    kw = dict(epochs=1, steps_per_epoch=steps, batch_size=64,
              start_steps=burn, update_after=burn, update_every=50,
              update_iters=10, log=None, seed=0)

    def timed(driver, agent_fn, buffer_fn=None, **dkw):
        """Replay the driver with identical seeds: the first pass jits
        every shape and memoizes the exact (image, mask) stream the
        deterministic seeds repeat; the later passes measure steady-state
        driver throughput (min of 3 — this is a shared, noisy machine).
        The per-epoch test-episode evaluation is timed separately and
        subtracted: it is the identical epilogue on both paths, not part
        of the experience-collection/update loop under comparison.
        ``buffer_fn`` builds a fresh replay buffer per pass (buffers are
        stateful, so passes must not share one)."""
        from repro.core.loops import agent_policy, evaluate_policy
        dt = float("inf")
        for i in range(4):
            env.rng = np.random.default_rng(41)
            kw_i = dict(dkw, buffer=buffer_fn()) if buffer_fn else dkw
            t0 = time.time()
            hist = driver(agent_fn(), env, **kw_i)
            if i > 0:
                dt = min(dt, time.time() - t0)
            agent = agent_fn.last
        ev = min(_best_of(lambda: evaluate_policy(agent_policy(agent),
                                                  env)), dt / 2)
        return hist, dt - dkw.get("epochs", 1) * ev

    class _remember:
        def __init__(self, fn):
            self.fn = fn

        def __call__(self):
            self.last = self.fn()
            return self.last

    def timed_lanes(driver, agent_fn, variants, **dkw):
        """Like ``timed`` but interleaves passes of several buffer
        variants of the same driver, so a transient load spike on this
        shared machine hits all variants instead of biasing whichever
        one it landed on — the host-vs-device ratio is the gated metric
        and must not depend on measurement order."""
        from repro.core.loops import agent_policy, evaluate_policy
        dts = [float("inf")] * len(variants)
        hists = [None] * len(variants)
        for i in range(4):
            for j, buffer_fn in enumerate(variants):
                env.rng = np.random.default_rng(41)
                kw_i = dict(dkw, buffer=buffer_fn()) if buffer_fn else dkw
                t0 = time.time()
                hists[j] = driver(agent_fn(), env, **kw_i)
                if i > 0:
                    dts[j] = min(dts[j], time.time() - t0)
        agent = agent_fn.last
        ev = min(_best_of(lambda: evaluate_policy(agent_policy(agent),
                                                  env)), min(dts) / 2)
        e = dkw.get("epochs", 1)
        return [(h, dt - e * ev) for h, dt in zip(hists, dts)]

    # device-resident lane: jax-PRNG index draws, on-device feature
    # assembly from the env's feature table, no per-block metric sync
    def dev_buf():
        from repro.core.device_replay import DeviceReplayBuffer
        return DeviceReplayBuffer(100_000, env.state_dim, env.n_providers,
                                  seed=0, index_mode="jax",
                                  feature_table=env.device_features())

    sac, ppo = _remember(sac), _remember(ppo)
    h_seq, seq_s = timed(run_offpolicy_sequential, sac, **kw)
    (h_bat, bat_s), (h_dev, dev_s) = timed_lanes(
        run_off_policy, sac, [None, dev_buf], lanes=lanes, **kw)
    sps_seq = h_seq[-1]["steps"] / max(seq_s, 1e-9)
    sps_bat = h_bat[-1]["steps"] / max(bat_s, 1e-9)
    sps_dev = h_dev[-1]["steps"] / max(dev_s, 1e-9)

    _, ppo_seq_s = timed(run_ppo_sequential, ppo, epochs=1,
                         steps_per_epoch=steps, log=None)
    _, ppo_bat_s = timed(run_ppo, ppo, lanes=lanes, epochs=1,
                         steps_per_epoch=steps, log=None)
    ppo_steps = -(-steps // lanes) * lanes
    ppo_sps_seq = steps / max(ppo_seq_s, 1e-9)
    ppo_sps_bat = ppo_steps / max(ppo_bat_s, 1e-9)

    out = {"lanes": lanes, "n_images": n_images, "steps_per_epoch": steps,
           "offpolicy": {
               "sequential_s": round(seq_s, 3), "batched_s": round(bat_s, 3),
               "device_s": round(dev_s, 3),
               "sequential_steps_per_s": round(sps_seq, 1),
               "batched_steps_per_s": round(sps_bat, 1),
               "device_steps_per_s": round(sps_dev, 1),
               "speedup": round(sps_bat / max(sps_seq, 1e-9), 2),
               "speedup_device_vs_host": round(sps_dev / max(sps_bat, 1e-9),
                                               2),
               "final_ap50_sequential": round(h_seq[-1]["ap50"], 2),
               "final_ap50_batched": round(h_bat[-1]["ap50"], 2),
               "final_ap50_device": round(h_dev[-1]["ap50"], 2)},
           "ppo": {
               "sequential_s": round(ppo_seq_s, 3),
               "batched_s": round(ppo_bat_s, 3),
               "sequential_steps_per_s": round(ppo_sps_seq, 1),
               "batched_steps_per_s": round(ppo_sps_bat, 1),
               "speedup": round(ppo_sps_bat / max(ppo_sps_seq, 1e-9), 2)}}
    _save("train_driver", out)
    _emit("train_driver/offpolicy_sequential", 1e6 / max(sps_seq, 1e-9),
          f"steps_per_s={out['offpolicy']['sequential_steps_per_s']}")
    _emit("train_driver/offpolicy_batched", 1e6 / max(sps_bat, 1e-9),
          f"steps_per_s={out['offpolicy']['batched_steps_per_s']};"
          f"speedup={out['offpolicy']['speedup']}x;lanes={lanes}")
    _emit("train_driver/offpolicy_device", 1e6 / max(sps_dev, 1e-9),
          f"steps_per_s={out['offpolicy']['device_steps_per_s']};"
          f"speedup_device_vs_host="
          f"{out['offpolicy']['speedup_device_vs_host']}x;lanes={lanes}")
    _emit("train_driver/ppo_sequential", 1e6 / max(ppo_sps_seq, 1e-9),
          f"steps_per_s={out['ppo']['sequential_steps_per_s']}")
    _emit("train_driver/ppo_batched", 1e6 / max(ppo_sps_bat, 1e-9),
          f"steps_per_s={out['ppo']['batched_steps_per_s']};"
          f"speedup={out['ppo']['speedup']}x;lanes={lanes}")
    return out


# ---------------------------------------------------------------------------
# Serving: sequential handle vs batched handle_many vs async micro-batching
# ---------------------------------------------------------------------------

def bench_serving():
    """Requests/sec and p50/p99 latency of the federation serving paths
    under a Poisson open-loop client: per-request ``handle``, batched
    ``handle_many``, and the micro-batching ``AsyncFederationService``.

    The offered load is ``REPRO_BENCH_LAMBDA_X`` (default 8) times the
    measured sequential capacity, so every server is saturated and the
    throughput numbers compare capacities (the sequential server's
    latency diverges — that is the story).  Sync paths are measured on a
    virtual queue clock (real compute, simulated arrivals); the async
    service is driven in real time by a submitter thread.  All paths run
    warm (tables + memo + every jit flush shape prewarmed — this
    benchmarks steady-state serving), the three paths' runs are
    interleaved over ``REPRO_BENCH_ROUNDS`` rounds with each path keeping
    its best round (shared noisy machines), and the regression gate
    (tools/check_bench.py) gates on the capacity ratios, which cancel
    machine speed.
    """
    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces
    from repro.serving.async_service import AsyncFederationService
    from repro.serving.federation_service import FederationService

    n_images = min(IMAGES, 120)
    n_reqs = int(os.environ.get("REPRO_BENCH_REQUESTS", "600"))
    max_batch = int(os.environ.get("REPRO_BENCH_MAX_BATCH", "16"))
    workers = int(os.environ.get("REPRO_BENCH_WORKERS", "4"))
    max_wait_ms = float(os.environ.get("REPRO_BENCH_MAX_WAIT_MS", "2.0"))
    lambda_x = float(os.environ.get("REPRO_BENCH_LAMBDA_X", "8.0"))

    traces = generate_traces(default_providers(), n_images, seed=0)
    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, hidden=(32, 32)))
    svc = FederationService(env, agent)
    rng = np.random.default_rng(0)
    reqs = [int(i) for i in rng.integers(0, n_images, n_reqs)]

    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "3"))

    # warm: IoU tables, (image, mask) memo, and the jit cache for every
    # flush shape the open-loop client can produce (the batched forward
    # compiles once per distinct batch size)
    env.core.precompute(np.arange(n_images))
    for i in range(n_images):
        svc.handle(i)
    for b in range(1, max_batch + 1):
        svc.handle_many(list(range(min(b, n_images))))

    # sequential capacity sets the offered load
    calib = reqs[:100]
    t0 = time.time()
    for i in calib:
        svc.handle(i)
    seq_cap = len(calib) / (time.time() - t0)
    lam = lambda_x * seq_cap
    arrivals = rng.exponential(1.0 / lam, n_reqs).cumsum()

    def pct(lat):
        return (round(float(np.percentile(lat, 50)) * 1e3, 2),
                round(float(np.percentile(lat, 99)) * 1e3, 2))

    def run_sequential():
        # per-request handle on a virtual queue clock
        clock, lat = 0.0, np.zeros(n_reqs)
        for i, img in enumerate(reqs):
            start = max(arrivals[i], clock)
            t0 = time.time()
            svc.handle(img)
            clock = start + (time.time() - t0)
            lat[i] = clock - arrivals[i]
        return n_reqs / (clock - arrivals[0]), lat, None

    def run_many():
        # micro-batched handle_many on the same virtual clock: each flush
        # takes whatever has arrived, up to max_batch
        clock, lat, i = arrivals[0], np.zeros(n_reqs), 0
        while i < n_reqs:
            if arrivals[i] > clock:
                clock = arrivals[i]
            j = i + np.searchsorted(arrivals[i:], clock, side="right")
            j = min(j, i + max_batch, n_reqs)
            t0 = time.time()
            svc.handle_many(reqs[i:j])
            clock += time.time() - t0
            lat[i:j] = clock - arrivals[i:j]
            i = j
        return n_reqs / (clock - arrivals[0]), lat, None

    def run_async():
        # the real thing: concurrent submitter thread, real wall clock
        import threading
        with AsyncFederationService(env, agent, max_batch=max_batch,
                                    max_wait_ms=max_wait_ms,
                                    workers=workers) as asvc:
            asvc.handle_many(list(range(n_images)))     # warm the shards
            asvc.reset_stats()      # report only the measured window
            done = np.zeros(n_reqs)
            futures = [None] * n_reqs

            def record(i):
                def cb(_fut):
                    done[i] = time.monotonic()
                return cb

            base = time.monotonic()

            def submit_all():
                # coarse pacing: sleep only when >2ms ahead of schedule
                # (per-request sub-ms sleeps overshoot and would throttle
                # the offered load below lambda), then submit all due
                for i, img in enumerate(reqs):
                    delay = base + arrivals[i] - time.monotonic()
                    if delay > 2e-3:
                        time.sleep(delay)
                    futures[i] = asvc.submit(img)
                    futures[i].add_done_callback(record(i))

            sub = threading.Thread(target=submit_all)
            t0 = time.monotonic()
            sub.start()
            sub.join()
            while not np.all(done > 0):
                time.sleep(0.001)
            for f in futures:       # surface request failures, don't
                f.result()          # report them as completions
            lat = done - base - arrivals
            rps = n_reqs / (done.max() - t0)
            return rps, lat, (dict(asvc.stats), asvc.mean_flush_size())

    # interleave the three paths; each keeps its best round
    best = {}
    for _ in range(rounds):
        for name, fn in (("sequential", run_sequential),
                         ("handle_many", run_many), ("async", run_async)):
            r = fn()
            if name not in best or r[0] > best[name][0]:
                best[name] = r
    seq_rps, seq_lat, _ = best["sequential"]
    many_rps, many_lat, _ = best["handle_many"]
    async_rps, async_lat, (stats, mean_flush) = best["async"]
    seq_p50, seq_p99 = pct(seq_lat)
    many_p50, many_p99 = pct(many_lat)
    async_p50, async_p99 = pct(async_lat)

    out = {"n_images": n_images, "requests": n_reqs,
           "max_batch": max_batch, "workers": workers,
           "max_wait_ms": max_wait_ms,
           "offered_rps": round(lam, 1),
           "sequential": {"rps": round(seq_rps, 1), "p50_ms": seq_p50,
                          "p99_ms": seq_p99},
           "handle_many": {"rps": round(many_rps, 1), "p50_ms": many_p50,
                           "p99_ms": many_p99},
           "async": {"rps": round(async_rps, 1), "p50_ms": async_p50,
                     "p99_ms": async_p99,
                     "mean_flush": round(mean_flush, 1),
                     "flushes": stats["flushes"],
                     "max_flush": stats["max_flush"]},
           "speedup_async_vs_handle": round(async_rps / max(seq_rps, 1e-9),
                                            2),
           "speedup_many_vs_handle": round(many_rps / max(seq_rps, 1e-9),
                                           2)}
    _save("serving", out)
    _emit("serving/handle", 1e6 / max(seq_rps, 1e-9),
          f"rps={out['sequential']['rps']};p50={seq_p50}ms;p99={seq_p99}ms")
    _emit("serving/handle_many", 1e6 / max(many_rps, 1e-9),
          f"rps={out['handle_many']['rps']};p50={many_p50}ms;"
          f"p99={many_p99}ms;speedup={out['speedup_many_vs_handle']}x")
    _emit("serving/async", 1e6 / max(async_rps, 1e-9),
          f"rps={out['async']['rps']};p50={async_p50}ms;p99={async_p99}ms;"
          f"speedup={out['speedup_async_vs_handle']}x;"
          f"mean_flush={out['async']['mean_flush']}")
    return out


# ---------------------------------------------------------------------------
# Serving shards: thread vs process backend capacity at W in {1, 2, 4}
# ---------------------------------------------------------------------------

def bench_serving_mp():
    """Saturated-drain capacity of ``AsyncFederationService`` with thread
    vs process shard backends at W in {1, 2, 4}.

    The request stream is one permutation of DISTINCT images per round,
    with the shard caches invalidated between rounds: every request pays
    the real production cost of a never-seen image (IoU table build +
    ensemble assembly), which is exactly the work the GIL serializes on
    the thread backend and worker processes parallelize.  jit shapes and
    worker processes stay warm across rounds — this measures steady-state
    serving capacity, not spawn or compile cost.  7 providers (the
    Tab.-III scalability roster) keep per-request assembly realistic.

    At each W the thread and process services are alive TOGETHER and
    their drain rounds interleave (thread, process, thread, ...), so a
    load spike on a shared machine hits both backends, not one; each
    config keeps its best of ``REPRO_BENCH_ROUNDS`` rounds and the
    regression gate compares process/thread RATIOS at equal W, which
    cancel absolute machine speed.
    """
    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import scalability_providers
    from repro.federation.traces import generate_traces
    from repro.serving.async_service import AsyncFederationService

    n_prov = 7
    n_images = min(IMAGES, 240)
    max_batch = int(os.environ.get("REPRO_BENCH_MAX_BATCH", "16"))
    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "5"))
    widths = (1, 2, 4)

    traces = generate_traces(scalability_providers()[:n_prov], n_images,
                             seed=0)
    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, hidden=(32, 32)))
    reqs = [int(i) for i in
            np.random.default_rng(0).permutation(n_images)]

    def drain(svc) -> float:
        # cold caches, warm everything else: each request re-pays table
        # build + assembly, never jit or spawn
        svc.core.invalidate_images(reqs)
        svc.reset_stats()
        t0 = time.time()
        futs = [svc.submit(i) for i in reqs]
        for f in futs:
            f.result()
        return len(reqs) / (time.time() - t0)

    out = {"n_providers": n_prov, "n_images": n_images,
           "max_batch": max_batch, "rounds": rounds,
           "backends": {"thread": {}, "process": {}}}
    for w in widths:
        svcs = {}
        try:
            for backend in ("thread", "process"):
                svc = AsyncFederationService(
                    env, agent, max_batch=max_batch, max_wait_ms=2.0,
                    workers=w, shard_backend=backend)
                svc.handle(reqs[0])          # single-request jit shape
                svc.handle_many(reqs)        # batched jit shape + warm run
                svcs[backend] = svc
            best = {"thread": 0.0, "process": 0.0}
            for _ in range(rounds):
                for backend, svc in svcs.items():
                    best[backend] = max(best[backend], drain(svc))
            for backend, svc in svcs.items():
                out["backends"][backend][f"w{w}"] = {
                    "rps": round(best[backend], 1),
                    "mean_flush": round(svc.mean_flush_size(), 1)}
        finally:
            for svc in svcs.values():
                svc.close()
    for w in widths:
        t = out["backends"]["thread"][f"w{w}"]["rps"]
        p = out["backends"]["process"][f"w{w}"]["rps"]
        out[f"speedup_process_vs_thread_w{w}"] = round(p / max(t, 1e-9), 2)
    _save("serving_mp", out)
    for backend in ("thread", "process"):
        for w in widths:
            r = out["backends"][backend][f"w{w}"]
            _emit(f"serving_mp/{backend}_w{w}", 1e6 / max(r["rps"], 1e-9),
                  f"rps={r['rps']};mean_flush={r['mean_flush']}")
    for w in widths:
        _emit(f"serving_mp/speedup_w{w}", 0.0,
              f"process_vs_thread={out[f'speedup_process_vs_thread_w{w}']}x")
    return out


def bench_serving_socket():
    """Multi-host serving plane: socket shard hosts vs process shard
    workers, the HTTP front door's SLO, and host-kill degradation.

    Three sections, all sized by REPRO_BENCH_IMAGES/_MAX_BATCH/_ROUNDS:

    ``capacity``  — saturated-drain rps of ``AsyncFederationService``
        with ``transport='socket'`` vs ``transport='process'`` at H in
        {1, 2} hosts/workers, both alive together with interleaved
        rounds (same machine, same run — the gated ratio
        ``speedup_socket_vs_process_h2`` cancels absolute speed).  The
        socket plane pays pickle + TCP framing where the process plane
        pays pickle + pipe, so the ratio is its capacity *overhead*
        check: it must stay near 1.0, and a collapse means a framing or
        locking regression, not a slower machine.

    ``http``      — the same drain pushed through the stdlib HTTP front
        door.  The gated ``modeled_p99_ms`` is the p99 of the MODELED
        request latency (paper latency model + pinned seeds) observed
        over HTTP, which is machine-invariant: the transport may slow a
        run down, but it must never change what the model answers.
        Wall-clock HTTP rps is reported, not gated.

    ``host_kill`` — H=2 socket hosts, one SIGKILLed mid-drain.  Every
        in-flight and subsequent request must still complete
        (``completed_frac`` gated at 1.0) with exactly one host
        condemned — the requeue path, measured, not just unit-tested.
    """
    import signal

    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import scalability_providers
    from repro.federation.traces import generate_traces
    from repro.serving.async_service import AsyncFederationService
    from repro.serving.http_front import HttpFrontDoor, HttpServingClient

    n_prov = 7
    n_images = min(IMAGES, 240)
    max_batch = int(os.environ.get("REPRO_BENCH_MAX_BATCH", "16"))
    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "3"))
    hs = (1, 2)

    traces = generate_traces(scalability_providers()[:n_prov], n_images,
                             seed=0)
    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, hidden=(32, 32)))
    reqs = [int(i) for i in
            np.random.default_rng(0).permutation(n_images)]

    def drain(svc) -> float:
        svc.core.invalidate_images(reqs)
        svc.reset_stats()
        t0 = time.time()
        futs = [svc.submit(i) for i in reqs]
        for f in futs:
            f.result()
        return len(reqs) / (time.time() - t0)

    out = {"n_providers": n_prov, "n_images": n_images,
           "max_batch": max_batch, "rounds": rounds,
           "transports": {"process": {}, "socket": {}}}

    # -- capacity: socket vs process, interleaved, best-of --------------
    for h in hs:
        svcs = {}
        try:
            for transport in ("process", "socket"):
                svc = AsyncFederationService(
                    env, agent, max_batch=max_batch, max_wait_ms=2.0,
                    workers=h, transport=transport)
                svc.handle(reqs[0])          # single-request jit shape
                svc.handle_many(reqs)        # batched jit shape + warm run
                svcs[transport] = svc
            best = {"process": 0.0, "socket": 0.0}
            for _ in range(rounds):
                for transport, svc in svcs.items():
                    best[transport] = max(best[transport], drain(svc))
            for transport, svc in svcs.items():
                out["transports"][transport][f"h{h}"] = {
                    "rps": round(best[transport], 1),
                    "mean_flush": round(svc.mean_flush_size(), 1)}
        finally:
            for svc in svcs.values():
                svc.close()
    for h in hs:
        p = out["transports"]["process"][f"h{h}"]["rps"]
        s = out["transports"]["socket"][f"h{h}"]["rps"]
        out[f"speedup_socket_vs_process_h{h}"] = round(s / max(p, 1e-9), 2)

    # -- http: front-door drain, modeled p99 is the SLO -----------------
    with AsyncFederationService(
            env, agent, max_batch=max_batch, max_wait_ms=2.0, workers=2,
            transport="socket") as svc, \
            HttpFrontDoor(svc) as door:
        cli = HttpServingClient(door.url)
        try:
            cli.handle(reqs[0])
            best_rps, lats = 0.0, []
            for _ in range(rounds):
                svc.core.invalidate_images(reqs)
                t0 = time.time()
                results = [f.result()
                           for f in [cli.submit(i) for i in reqs]]
                best_rps = max(best_rps,
                               len(reqs) / (time.time() - t0))
                lats = [r.latency_ms for r in results]
            lats.sort()
            out["http"] = {
                "rps": round(best_rps, 1),
                "modeled_p99_ms": round(
                    lats[min(int(0.99 * len(lats)), len(lats) - 1)], 3),
                "modeled_mean_ms": round(sum(lats) / len(lats), 3)}
        finally:
            cli.close()

    # -- host_kill: SIGKILL one of two hosts mid-drain -------------------
    with AsyncFederationService(
            env, agent, max_batch=max_batch, max_wait_ms=2.0, workers=2,
            transport="socket") as svc:
        svc.handle_many(reqs)
        svc.core.invalidate_images(reqs)
        svc.reset_stats()
        futs = [svc.submit(i) for i in reqs]
        os.kill(svc.core.host_pids()[0], signal.SIGKILL)
        done = sum(1 for f in futs if f.result() is not None)
        out["host_kill"] = {
            "completed_frac": round(done / len(reqs), 3),
            "condemned": svc.transport.condemned,
            "requests_accounted": svc.stats["requests"]}

    _save("serving_socket", out)
    for transport in ("process", "socket"):
        for h in hs:
            r = out["transports"][transport][f"h{h}"]
            _emit(f"serving_socket/{transport}_h{h}",
                  1e6 / max(r["rps"], 1e-9),
                  f"rps={r['rps']};mean_flush={r['mean_flush']}")
    for h in hs:
        _emit(f"serving_socket/speedup_h{h}", 0.0,
              f"socket_vs_process={out[f'speedup_socket_vs_process_h{h}']}x")
    _emit("serving_socket/http", 1e6 / max(out["http"]["rps"], 1e-9),
          f"rps={out['http']['rps']};"
          f"modeled_p99_ms={out['http']['modeled_p99_ms']}")
    _emit("serving_socket/host_kill", 0.0,
          f"completed_frac={out['host_kill']['completed_frac']};"
          f"condemned={out['host_kill']['condemned']}")
    return out


# ---------------------------------------------------------------------------
# Serving scenarios: latency / cost SLOs per regime under provider dynamics
# ---------------------------------------------------------------------------

def bench_serving_scenarios():
    """Poisson open-loop client through non-stationary provider schedules
    on the process-backend serving plane, recording per-regime SLOs.

    One scenario step per request: the serving clock walks the schedule,
    segments swap at flush boundaries, and every request is accounted
    under its segment's fee/latency vectors (a down provider bills 0 and
    costs its outage timeout if the selector still picks it).  Per
    segment we report p50/p99 of the MODELED request latency and the
    mean cost per request — both machine-speed-invariant (they follow
    from the paper's latency/fee model, not the wall clock), which is
    what the regression gate checks.  Wall-clock throughput is reported
    as context, never gated.

    Requests are attributed to segments by arrival index; flush
    boundaries can skew attribution by up to max_batch requests, which
    blurs only the handful of requests at each switch.
    """
    from repro.core.sac import SAC, SACConfig
    from repro.federation.providers import default_providers
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario)
    from repro.serving.async_service import AsyncFederationService

    names = [s for s in os.environ.get(
        "REPRO_BENCH_SERVE_SCENARIOS", "provider_outage,price_war"
        ).split(",") if s]
    n_reqs = int(os.environ.get("REPRO_BENCH_REQUESTS", "600"))
    n_images = min(IMAGES, 120)
    max_batch = int(os.environ.get("REPRO_BENCH_MAX_BATCH", "16"))
    workers = int(os.environ.get("REPRO_BENCH_WORKERS", "4"))
    backend = os.environ.get("REPRO_BENCH_SHARD_BACKEND", "process")
    lambda_x = float(os.environ.get("REPRO_BENCH_LAMBDA_X", "4.0"))

    providers = default_providers()
    out = {"config": {"requests": n_reqs, "n_images": n_images,
                      "max_batch": max_batch, "workers": workers,
                      "shard_backend": backend, "scenarios": names}}
    for name in names:
        schedule = build_scenario(name, providers, horizon=n_reqs)
        pool = DynamicProviderPool(providers, schedule, n_images=n_images,
                                   seed=0)
        env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                    observe_pool=False, seed=1)
        agent = SAC(SACConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers, hidden=(32, 32)))
        rng = np.random.default_rng(0)
        reqs = [int(i) for i in rng.integers(0, n_images, n_reqs)]
        with AsyncFederationService(env, agent, max_batch=max_batch,
                                    max_wait_ms=2.0, workers=workers,
                                    pool=pool, shard_backend=backend) as svc:
            svc.handle(reqs[0])
            svc.handle_many(list(range(n_images)))   # warm shards + jit
            svc.reset_stats()
            svc.set_clock(0)    # warm-up must not consume the schedule
            # offered load: a quick warm drain calibrates capacity, the
            # client then offers lambda_x times that (saturation)
            t0 = time.time()
            for f in [svc.submit(i) for i in reqs[:100]]:
                f.result()
            cap = 100 / (time.time() - t0)
            svc.reset_stats()
            svc.set_clock(0)
            arrivals = rng.exponential(1.0 / (lambda_x * cap),
                                       n_reqs).cumsum()
            base = time.monotonic()
            futures = []
            for i, img in enumerate(reqs):
                delay = base + arrivals[i] - time.monotonic()
                if delay > 2e-3:
                    time.sleep(delay)
                futures.append(svc.submit(img))
            results = [f.result() for f in futures]
            wall_s = time.monotonic() - base
            stats = dict(svc.stats)
            # aggregated over every regime core on every shard: for the
            # process backend this is where segment activity actually
            # lives (the pool's parent-side cache_report stays ~empty)
            shard_stats = dict(svc.core.stats)
            shard_sizes = svc.core.cache_sizes()
        lat = np.asarray([r.latency_ms for r in results])
        cost = np.asarray([r.cost_milli_usd for r in results])
        segs = np.asarray([schedule.segment_index(i)
                           for i in range(n_reqs)])
        seg_rows = []
        for s in sorted(set(segs.tolist())):
            m = segs == s
            view = pool.view_at(int(schedule.segment_range(s)[0]))
            seg_rows.append({
                "seg": int(s), "requests": int(m.sum()),
                "down": [p.name for j, p in enumerate(view.profiles)
                         if not view.active[j]],
                "p50_ms": round(float(np.percentile(lat[m], 50)), 1),
                "p99_ms": round(float(np.percentile(lat[m], 99)), 1),
                "cost_per_request": round(float(cost[m].mean()), 4)})
        row = {
            "segments": seg_rows,
            "worst_p99_ms": round(max(r["p99_ms"] for r in seg_rows), 1),
            "cost_per_request": round(float(cost.mean()), 4),
            "wall_rps": round(n_reqs / wall_s, 1),
            "mean_flush": round(stats["requests"]
                                / max(stats["flushes"], 1), 1),
            "flush_reasons": {k: stats[k] for k in
                              ("flush_full", "flush_timeout",
                               "flush_drain")},
            "shard_cache_sizes": shard_sizes,
            "shard_ens_hit_rate": round(
                shard_stats.get("ens_hits", 0)
                / max(shard_stats.get("ens_hits", 0)
                      + shard_stats.get("ens_misses", 0), 1), 4),
            "pool_cache": pool.cache_report()}
        out[name] = row
        _emit(f"serving_scenarios/{name}", 1e6 * wall_s / n_reqs,
              f"worst_p99={row['worst_p99_ms']}ms;"
              f"cost_per_req={row['cost_per_request']};"
              f"rps={row['wall_rps']};segments={len(seg_rows)}")
    _save("serving_scenarios", out)
    return out


# ---------------------------------------------------------------------------
# Scenario suite: online adaptation under non-stationary provider pools
# ---------------------------------------------------------------------------

def bench_scenarios():
    """Run the built-in non-stationary scenarios end-to-end: SAC adapts
    online (``repro.scenarios.run_online``) through each schedule's regime
    switches, and every segment is scored against the per-segment oracle
    (best active subset per image, ap50 + beta * fee).

    Reported per segment: recovery (agent reward / oracle reward — the
    acceptance bar is >= 0.8 after every switch), regret, AP50, cost, and
    the subset-evaluation cache hit rate the stream saw inside the
    segment (the warm-path health of the pool's segment-keyed caches).
    ``REPRO_BENCH_SCENARIOS`` (comma list) picks scenarios;
    ``REPRO_BENCH_HORIZON`` scales every schedule.
    """
    from repro.core.sac import SAC, SACConfig
    from repro.federation.providers import default_providers
    from repro.scenarios import (BUILTIN_SCENARIOS, DynamicProviderPool,
                                 NonStationaryArmolEnv, build_scenario,
                                 run_online)

    names = [s for s in os.environ.get(
        "REPRO_BENCH_SCENARIOS", ",".join(BUILTIN_SCENARIOS)).split(",")
        if s]
    horizon = int(os.environ.get("REPRO_BENCH_HORIZON", "1600"))
    n_images = min(IMAGES, 120)
    beta = -0.03
    providers = default_providers()
    rows = {}
    post, hits = [], []
    for name in names:
        t0 = time.time()
        schedule = build_scenario(name, providers, horizon=horizon)
        pool = DynamicProviderPool(providers, schedule, n_images=n_images,
                                   seed=0)
        env = NonStationaryArmolEnv(pool, mode="gt", beta=beta,
                                    observe_pool=True, seed=1)
        # gamma=0 because provider selection is a contextual bandit (the
        # next image does not depend on the subset chosen for this one)
        agent = SAC(SACConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers, alpha=0.02,
                              lr=3e-4, gamma=0.0, hidden=(32, 32)))
        res = run_online(agent, env, lanes=4, seed=0, log=None)
        rows[name] = res
        s = res["summary"]
        post += [x["recovery"] for x in res["segments"] if x["seg"] >= 1]
        hits.append(s["mean_cache_hit_rate"])
        _emit(f"scenarios/{name}",
              1e6 * (time.time() - t0) / max(s["steps"], 1),
              f"min_recovery={s['min_recovery_post_switch']};"
              f"segments={s['n_segments']};"
              f"cache_hit={s['mean_cache_hit_rate']}")
    out = {"config": {"horizon": horizon, "n_images": n_images,
                      "beta": beta, "scenarios": names},
           "scenarios": rows,
           "summary": {
               "scenarios_run": len(names),
               "min_recovery": round(min(post), 4) if post else None,
               "mean_recovery":
                   round(float(np.mean(post)), 4) if post else None,
               "mean_cache_hit_rate": round(float(np.mean(hits)), 4)}}
    _save("scenarios", out)
    _emit("scenarios/summary", 0.0,
          f"min_recovery={out['summary']['min_recovery']};"
          f"mean_recovery={out['summary']['mean_recovery']};"
          f"cache_hit={out['summary']['mean_cache_hit_rate']}")
    return out


# ---------------------------------------------------------------------------
# Roofline: achieved vs peak FLOPs/bandwidth of the device-resident paths
# ---------------------------------------------------------------------------

def bench_roofline():
    """Measured roofline points (``repro.roofline.measure``) for the
    device-resident training paths:

      * ``fused_update``  — the SAC ``lax.scan`` update block vs K eager
        update dispatches: wall speedup, plus FLOPs parity from the
        compiled executables' cost analyses.  XLA's cost model counts a
        scanned body ONCE (trip count excluded), so parity is fused-body
        FLOPs over one eager step's FLOPs, ~1.0 — a deterministic,
        machine-invariant check that the fusion drops dispatch overhead,
        not work.
      * ``iou_batch``     — the batched pairwise-IoU path: HLO-derived
        arithmetic intensity places it on the roofline (far below the
        compute/memory knee: it is bandwidth-bound by construction), and
        the CPU-twin vs interpret-mode-Pallas timing records why
        ``resolve_use_kernel`` routes CPU backends to the twin.
      * ``replay_chain``  — T circular writes + one block sample, device
        buffer vs numpy buffer + host->device upload: the same-run
        speedup ratio is the committed gate.

    Achieved FLOP/s and fractions of the TPU-class ``HW`` peaks are
    recorded for interpretation but NEVER gated — this container runs the
    CPU backend, so only same-run ratios and HLO-derived quantities
    (machine-invariant) carry across machines.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import sac as sac_mod
    from repro.core.device_replay import DeviceReplayBuffer
    from repro.core.replay_buffer import ReplayBuffer
    from repro.core.sac import SAC, SACConfig
    from repro.kernels.iou_matrix.kernel import iou_matrix_pallas
    from repro.kernels.iou_matrix.ref import iou_matrix_ref
    from repro.roofline import HW, achieved_point, hlo_cost, timed_best

    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "5"))
    rng = np.random.default_rng(0)
    hw = HW()
    out = {"hw": {"peak_flops": hw.peak_flops, "hbm_bw": hw.hbm_bw},
           "backend": jax.default_backend()}

    # --- fused collect->update block vs eager per-step dispatch --------
    D, N, K, B = 80, 3, 10, 64
    cfg = SACConfig(state_dim=D, n_providers=N, hidden=(32, 32))
    agent = SAC(cfg)
    blk = {"s": rng.standard_normal((K, B, D)).astype(np.float32),
           "a": (rng.random((K, B, N)) > 0.5).astype(np.float32),
           "r": rng.standard_normal((K, B)).astype(np.float32),
           "s2": rng.standard_normal((K, B, D)).astype(np.float32),
           "d": np.zeros((K, B), np.float32)}
    single = {k: v[0] for k, v in blk.items()}
    ec = hlo_cost(sac_mod._update, cfg, agent.state,
                  {k: jnp.asarray(v) for k, v in single.items()})
    fc = hlo_cost(sac_mod._update_block, cfg, agent.state,
                  {k: jnp.asarray(v) for k, v in blk.items()})

    def run_fused():
        agent.update_block(blk, sync=False)
        jax.block_until_ready(agent.state)

    def run_eager():
        for _ in range(K):
            agent.update(single)
        jax.block_until_ready(agent.state)

    # interleaved rounds: machine noise hits both candidates
    fused_s, eager_s = _best_of(run_fused, run_eager, rounds=rounds)
    # cost_analysis reports the scan BODY once: scale by K for the whole
    # block's roofline point; body/eager-step ratio is the parity gate
    block_cost = {"flops": K * fc["flops"], "bytes": K * fc["bytes"],
                  "intensity": fc["intensity"]}
    pt = achieved_point(block_cost, fused_s, hw=hw)
    out["fused_update"] = {
        "K": K, "batch": B, "state_dim": D,
        "eager_s_per_block": round(eager_s, 5),
        "fused_s_per_block": round(fused_s, 5),
        "speedup_fused_vs_eager": round(eager_s / max(fused_s, 1e-12), 2),
        "flops_parity": round(fc["flops"] / max(ec["flops"], 1e-9), 4),
        "hlo_flops": block_cost["flops"], "hlo_bytes": block_cost["bytes"],
        "hlo_intensity": round(fc["intensity"], 3),
        "achieved_flops_s": round(pt["achieved_flops_s"], 1),
        "frac_peak_flops": pt["frac_peak_flops"], "bound": pt["bound"]}
    _emit("roofline/fused_update", 1e6 * fused_s,
          f"speedup_vs_eager={out['fused_update']['speedup_fused_vs_eager']}"
          f"x;flops_parity={out['fused_update']['flops_parity']};"
          f"intensity={out['fused_update']['hlo_intensity']}")

    # --- batched pairwise IoU ------------------------------------------
    M, Nb = 256, 512
    a = jnp.asarray(rng.random((M, 4)), jnp.float32)
    b = jnp.asarray(rng.random((Nb, 4)), jnp.float32)
    ref = jax.jit(iou_matrix_ref)
    ic = hlo_cost(ref, a, b)
    ref_s, _ = timed_best(ref, a, b, repeats=rounds)
    pal_s, _ = timed_best(
        lambda x, y: iou_matrix_pallas(x, y, interpret=True), a, b,
        repeats=max(rounds // 2, 1))
    ipt = achieved_point(ic, ref_s, hw=hw)
    out["iou_batch"] = {
        "m": M, "n": Nb,
        "hlo_flops": ic["flops"], "hlo_bytes": ic["bytes"],
        "hlo_intensity": round(ic["intensity"], 3),
        "twin_s": round(ref_s, 6), "pallas_interpret_s": round(pal_s, 4),
        "twin_vs_interpret": round(pal_s / max(ref_s, 1e-12), 1),
        "achieved_bw_s": round(ipt["achieved_bw_s"], 1),
        "frac_peak_bw": ipt["frac_peak_bw"],
        "knee_intensity": round(ipt["knee_intensity"], 1),
        "bound": ipt["bound"]}
    _emit("roofline/iou_batch", 1e6 * ref_s,
          f"intensity={out['iou_batch']['hlo_intensity']};"
          f"bound={out['iou_batch']['bound']};"
          f"twin_vs_interpret={out['iou_batch']['twin_vs_interpret']}x")

    # --- replay write+sample chain: device vs host buffer --------------
    # T ticks per sampled block mirrors the multi-lane driver's regime
    # (update_every=50 at 8 lanes: ~6 collect ticks per update block);
    # the two chains interleave round-by-round so load spikes hit both
    cap, L, T = 4096, 8, 6
    rows = (rng.standard_normal((T, L, D)).astype(np.float32),
            (rng.random((T, L, N)) > 0.5).astype(np.float32),
            rng.standard_normal((T, L)).astype(np.float32),
            rng.standard_normal((T, L, D)).astype(np.float32),
            np.zeros((T, L), np.float32))
    hbuf = ReplayBuffer(cap, D, N, seed=0)
    dbuf = DeviceReplayBuffer(cap, D, N, seed=0, index_mode="jax")

    def chain_host():
        # the host path as run_off_policy drives it: numpy writes, numpy
        # index draw + gather, then the block's host->device upload
        for t in range(T):
            hbuf.add_batch(*(x[t] for x in rows))
        blk = hbuf.sample_block(K, B)
        jax.block_until_ready({k: jnp.asarray(v) for k, v in blk.items()})

    def chain_device():
        for t in range(T):
            dbuf.add_batch(*(x[t] for x in rows))
        jax.block_until_ready(dbuf.sample_block(K, B))

    host_s, dev_s = _best_of(chain_host, chain_device, rounds=rounds)
    out["replay_chain"] = {
        "capacity": cap, "ticks": T, "lanes": L, "K": K, "batch": B,
        "host_s": round(host_s, 5), "device_s": round(dev_s, 5),
        "speedup_device_vs_host": round(host_s / max(dev_s, 1e-12), 2)}
    _emit("roofline/replay_chain", 1e6 * dev_s,
          f"host={out['replay_chain']['host_s']}s;"
          f"device={out['replay_chain']['device_s']}s;speedup_device="
          f"{out['replay_chain']['speedup_device_vs_host']}x")
    _save("roofline", out)
    return out


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (CPU interpret mode — correctness-level timing)
# ---------------------------------------------------------------------------

def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.iou_matrix.kernel import iou_matrix_pallas
    from repro.kernels.iou_matrix.ref import iou_matrix_ref
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_naive
    rng = np.random.default_rng(0)
    rows = {}

    def timeit(fn, *args, n=5):
        fn(*args)                      # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) * 1e6 / n

    a = jnp.asarray(rng.random((256, 4)), jnp.float32)
    b = jnp.asarray(rng.random((512, 4)), jnp.float32)
    rows["iou_pallas_interp"] = timeit(
        lambda x, y: iou_matrix_pallas(x, y, interpret=True), a, b)
    rows["iou_ref"] = timeit(iou_matrix_ref, a, b)

    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    rows["flash_pallas_interp"] = timeit(
        lambda x: flash_attention_pallas(x, x, x, block_q=64, block_k=64,
                                         interpret=True), q)
    rows["flash_ref"] = timeit(lambda x: attention_ref(x, x, x), q)

    xh = jnp.asarray(rng.standard_normal((1, 128, 2, 16)), jnp.float32)
    dt = jnp.asarray(rng.random((1, 128, 2)) * 0.4 + 0.05, jnp.float32)
    A = -jnp.ones((2,), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 128, 8)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((1, 128, 8)), jnp.float32)
    rows["ssd_pallas_interp"] = timeit(
        lambda *xs: ssd_scan(*xs, chunk=32), xh, dt, A, Bm, Cm)
    rows["ssd_ref_naive"] = timeit(ssd_naive, xh, dt, A, Bm, Cm)

    _save("kernel_micro", rows)
    for k, v in rows.items():
        _emit(f"kernels/{k}", v, "interpret-mode; TPU is the target")
    return rows


# ---------------------------------------------------------------------------

def bench_frontier():
    """Cost–accuracy frontier: RL vs cascade vs MCT vs hybrid across the
    scenario suite (``repro.selection.frontier``).  Everything gated is
    seeded/modeled — curves and dominance invariants are deterministic,
    machine-invariant quantities, not timings."""
    import time as _time

    from repro.selection.frontier import run_frontier

    horizon = int(os.environ.get("REPRO_BENCH_FRONTIER_HORIZON", "480"))
    n_images = min(IMAGES, 96)
    t0 = _time.time()
    out = run_frontier(horizon=horizon, n_images=n_images, seed=0,
                       log=None)
    out["wall_s"] = round(_time.time() - t0, 1)
    _save("frontier", out)
    inv = out["invariants"]
    for arm in ("rl", "cascade", "hybrid", "mct"):
        for p in out["frontier"][arm]:
            _emit(f"frontier/{arm}_knob_{p['knob']}", 0.0,
                  f"ap50={p['ap50']} cost={p['cost']}")
    _emit("frontier/invariants", 0.0,
          f"rl>cheapest={inv['rl_dominates_cheapest']} "
          f"rl>all={inv['rl_dominates_all_providers']} "
          f"hybrid>=cascade={inv['hybrid_ge_cascade']}")
    _emit("frontier/paper_point", 0.0,
          f"cost_saving={out['paper_point']['cost_saving_frac']} "
          f"ap50={out['paper_point']['ap50']}")
    return out


# ---------------------------------------------------------------------------

def bench_obs_overhead():
    """Observability overhead on the async serving hot path.

    Two identically configured ``AsyncFederationService`` instances over
    the same env — one bare, one with the full ``repro.obs`` stack
    attached (metrics registry + JSONL serving log + sampled tracing) —
    each drain the same warm request stream; the runs interleave
    round-by-round (``_best_of``), so machine-speed and load spikes
    cancel in the ratio.  The gated ``throughput_ratio`` =
    instrumented/bare requests-per-second must stay ~1.0: the design
    contract is that observability on the hot path is within noise.
    Result parity between the two services is asserted outright.
    """
    import tempfile

    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces
    from repro.obs import Obs
    from repro.serving.async_service import AsyncFederationService

    n_images = min(IMAGES, 120)
    n_reqs = int(os.environ.get("REPRO_BENCH_REQUESTS", "480"))
    max_batch = int(os.environ.get("REPRO_BENCH_MAX_BATCH", "16"))
    workers = int(os.environ.get("REPRO_BENCH_WORKERS", "2"))
    rounds = int(os.environ.get("REPRO_BENCH_ROUNDS", "5"))
    trace_sample = float(os.environ.get("REPRO_BENCH_TRACE_SAMPLE",
                                        "0.01"))

    traces = generate_traces(default_providers(), n_images, seed=0)
    env = ArmolEnv(traces, mode="gt", beta=0.0, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, hidden=(32, 32)))
    rng = np.random.default_rng(0)
    reqs = [int(i) for i in rng.integers(0, n_images, n_reqs)]
    env.core.precompute(np.arange(n_images))

    obs_dir = tempfile.mkdtemp(prefix="obs-bench-")
    obs = Obs(obs_dir, trace_sample=trace_sample)
    obs.open_serving_log([p.name for p in traces.providers], traces.gts)
    svc_bare = AsyncFederationService(env, agent, max_batch=max_batch,
                                      workers=workers)
    svc_inst = AsyncFederationService(env, agent, max_batch=max_batch,
                                      workers=workers, obs=obs)
    try:
        # precompute the full subset lattice on the instrumented
        # service's shard cores so the serving log's AP50 column is a
        # table hit (the documented deployment shape for gt-scored
        # logging) — the stochastic policy samples fresh masks every
        # round, and without the lattice each unseen (image, mask) pair
        # would pay a fresh AP matching inside the timed region
        for i in range(n_images):
            svc_inst.core.evaluate_lattice(i)
        # warm both planes (jit flush shapes + shard memos) and assert
        # the instrumented service is result-identical to the bare one
        ref = svc_bare.handle_many(reqs[:64])
        got = svc_inst.handle_many(reqs[:64])
        assert all(
            a.cost_milli_usd == b.cost_milli_usd
            and a.latency_ms == b.latency_ms
            and np.array_equal(a.detections.boxes, b.detections.boxes)
            for a, b in zip(ref, got)), "obs on/off results diverged"

        def _drain(svc):
            futures = [svc.submit(i) for i in reqs]
            for f in futures:
                f.result()

        t_bare, t_inst = _best_of(lambda: _drain(svc_bare),
                                  lambda: _drain(svc_inst),
                                  rounds=rounds)
    finally:
        svc_bare.close()
        svc_inst.close()
        obs.write_metrics(svc_inst.extra_metric_snapshots())
        obs.close()

    out = {
        "n_requests": n_reqs, "n_images": n_images,
        "max_batch": max_batch, "workers": workers,
        "trace_sample": trace_sample, "rounds": rounds,
        "bare_rps": round(n_reqs / t_bare, 1),
        "instrumented_rps": round(n_reqs / t_inst, 1),
        # >= 1.0 means instrumented matched/beat bare that run; the gate
        # (tools/check_bench.py) fails if the committed ratio regresses
        "throughput_ratio": round(t_bare / t_inst, 4),
    }
    _save("obs_overhead", out)
    _emit("obs_overhead/bare", t_bare * 1e6 / n_reqs,
          f"rps={out['bare_rps']}")
    _emit("obs_overhead/instrumented", t_inst * 1e6 / n_reqs,
          f"rps={out['instrumented_rps']};"
          f"ratio={out['throughput_ratio']}")
    return out


BENCHES = {
    "provider_ap": bench_provider_ap,
    "ensemble_combos": bench_ensemble_combos,
    "baselines": bench_baselines,
    "scalability": bench_scalability,
    "subset_cache": bench_subset_cache,
    "lattice": bench_lattice,
    "train_driver": bench_train_driver,
    "serving": bench_serving,
    "serving_mp": bench_serving_mp,
    "serving_socket": bench_serving_socket,
    "serving_scenarios": bench_serving_scenarios,
    "scenarios": bench_scenarios,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
    "frontier": bench_frontier,
    "obs_overhead": bench_obs_overhead,
}


def main() -> None:
    only = [a for a in sys.argv[1:] if not a.startswith("-")]
    names = only or list(BENCHES)
    print("name,us_per_call,derived")
    shared = None
    for n in names:
        fn = BENCHES[n]
        if n in ("provider_ap", "ensemble_combos", "baselines"):
            if shared is None:
                shared = _traces()
            fn(shared)
        else:
            fn()


if __name__ == "__main__":
    main()
