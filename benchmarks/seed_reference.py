"""Frozen seed-PR implementations of the subset-evaluation hot path.

Copied verbatim from the seed commit (git 92348a8) so that
``bench_subset_cache`` can measure the batched/cached core against the
exact per-image, per-action path this repo started with — the numbers stay
honest even as the live modules keep getting faster.  Do NOT "fix" or
optimize this file; it is the baseline.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix
from repro.ensemble.voting import vote_filter

RECALL_POINTS = np.linspace(0.0, 1.0, 101)
IOU_GROUP_THR = 0.5


# --- seed voting.group_detections ------------------------------------------

def seed_group_detections(dets: Detections, *,
                          iou_thr: float = IOU_GROUP_THR) -> List[np.ndarray]:
    n = len(dets)
    if n == 0:
        return []
    order = np.argsort(-dets.scores, kind="stable")
    iou = iou_matrix(dets.boxes, dets.boxes)
    groups: List[List[int]] = []
    reps: List[int] = []
    for i in order:
        placed = False
        for gi, rep in enumerate(reps):
            if dets.labels[i] == dets.labels[rep] and iou[i, rep] > iou_thr:
                groups[gi].append(int(i))
                placed = True
                break
        if not placed:
            groups.append([int(i)])
            reps.append(int(i))
    return [np.asarray(g, np.int64) for g in groups]


# --- seed ablation.wbf ------------------------------------------------------

def seed_wbf(dets: Detections, groups: List[np.ndarray], *,
             n_models: int = 0) -> Detections:
    if not groups:
        return Detections.empty()
    boxes, scores, labels, provs = [], [], [], []
    for g in groups:
        b = dets.boxes[g]
        s = dets.scores[g]
        w = s / max(float(np.sum(s)), 1e-12)
        boxes.append(np.sum(b * w[:, None], axis=0))
        sc = float(np.mean(s))
        if n_models > 1:
            if dets.providers is not None:
                t = len(np.unique(dets.providers[g]))
            else:
                t = len(g)
            sc *= min(t, n_models) / n_models
        scores.append(sc)
        labels.append(int(dets.labels[g[0]]))
        provs.append(int(dets.providers[g[0]])
                     if dets.providers is not None else 0)
    return Detections(np.stack(boxes), np.asarray(scores, np.float32),
                      np.asarray(labels, np.int32),
                      np.asarray(provs, np.int32))


# --- seed pipeline.ensemble_detections (affirmative-wbf path) ---------------

def seed_ensemble_detections(per_provider: Sequence[Detections], *,
                             voting: str = "affirmative",
                             iou_thr: float = 0.5) -> Detections:
    tagged = []
    for i, d in enumerate(per_provider):
        t = Detections(d.boxes, d.scores, d.labels)
        t.providers = np.full(len(t), i, np.int32)
        tagged.append(t)
    merged = Detections.concat(tagged)
    if len(merged) == 0:
        return merged
    groups = seed_group_detections(merged, iou_thr=iou_thr)
    groups = vote_filter(merged, groups, method=voting,
                         n_selected=len(per_provider))
    return seed_wbf(merged, groups, n_models=len(per_provider))


# --- seed metrics (average_precision / image_ap50) --------------------------

def _seed_match_image(dt: Detections, gt: Detections, label: int,
                      iou_thr: float):
    di = np.where(dt.labels == label)[0]
    gi = np.where(gt.labels == label)[0]
    if len(di) == 0:
        return np.zeros(0), np.zeros(0, bool), len(gi)
    order = di[np.argsort(-dt.scores[di], kind="stable")]
    tp = np.zeros(len(order), bool)
    if len(gi):
        iou = iou_matrix(dt.boxes[order], gt.boxes[gi])
        taken = np.zeros(len(gi), bool)
        for r in range(len(order)):
            best, bj = iou_thr, -1
            for c in range(len(gi)):
                if not taken[c] and iou[r, c] >= best:
                    best, bj = iou[r, c], c
            if bj >= 0:
                taken[bj] = True
                tp[r] = True
    return dt.scores[order], tp, len(gi)


def seed_average_precision(dts, gts, *, iou_thr: float = 0.5) -> float:
    labs = set()
    for g in gts.values():
        labs.update(np.unique(g.labels).tolist())
    aps = []
    for lab in sorted(labs):
        scores, tps, n_gt = [], [], 0
        for img, gt in gts.items():
            dt = dts.get(img, Detections.empty())
            s, t, n = _seed_match_image(dt, gt, lab, iou_thr)
            scores.append(s)
            tps.append(t)
            n_gt += n
        if n_gt == 0:
            continue
        scores = np.concatenate(scores)
        tps = np.concatenate(tps)
        order = np.argsort(-scores, kind="stable")
        tps = tps[order]
        tp_cum = np.cumsum(tps)
        fp_cum = np.cumsum(~tps)
        recall = tp_cum / n_gt
        precision = tp_cum / np.maximum(tp_cum + fp_cum, 1e-12)
        for i in range(len(precision) - 2, -1, -1):
            precision[i] = max(precision[i], precision[i + 1])
        ap = 0.0
        for r in RECALL_POINTS:
            idx = np.searchsorted(recall, r, side="left")
            ap += precision[idx] if idx < len(precision) else 0.0
        aps.append(ap / len(RECALL_POINTS))
    return float(np.mean(aps)) if aps else 0.0


def seed_image_ap50(dt: Detections, gt: Detections) -> float:
    return seed_average_precision({0: dt}, {0: gt}, iou_thr=0.5)
