"""Lower+compile one (arch x shape) on the production mesh and print its
roofline terms — the single-combo version of ``python -m
repro.launch.dryrun --all``.

  PYTHONPATH=src python examples/multiarch_dryrun.py \
      --arch deepseek-v2-236b --shape train_4k --multi-pod
"""
import argparse
import json
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape]
    if args.multi_pod:
        cmd.append("--multi-pod")
    # subprocess so the 512-device XLA flag never leaks into this process
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
