"""Quickstart: train an Armol SAC selector on synthetic MLaaS traces and
compare it against the paper's baselines — runs in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.loops import (ensembleN_policy, evaluate_policy,
                              random1_policy, run_off_policy)
from repro.core.sac import SAC, SACConfig
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces


def main():
    print("== Armol quickstart: 3 providers (aws/azure/google), 300 images")
    traces = generate_traces(default_providers(), 300, seed=0)
    env = ArmolEnv(traces, mode="gt", beta=-0.03, seed=1)

    for name, pol in (("Random-1", random1_policy(env)),
                      ("Ensemble-N", ensembleN_policy(env))):
        r = evaluate_policy(pol, env)
        print(f"  {name:12s} AP50={r['ap50']:5.2f} cost={r['cost']:.3f}")

    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, alpha=0.02))
    print("  training SAC (3 epochs x 300 steps, 8 lanes)...")
    hist = run_off_policy(agent, env, lanes=8, epochs=3,
                          steps_per_epoch=300, batch_size=128,
                          start_steps=200, update_after=200,
                          update_every=50, update_iters=25, log=None)
    last = hist[-1]
    print(f"  {'Armol (SAC)':12s} AP50={last['ap50']:5.2f} "
          f"cost={last['cost']:.3f} counts={last['counts']}")
    print("done: the agent selects provider subsets per image instead of "
          "querying everyone.")


if __name__ == "__main__":
    main()
