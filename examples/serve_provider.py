"""Provider-side + federation-side serving demo.

1. Spins up a reduced-config LM ServeEngine (any of the 10 assigned
   architectures) and serves a batch of token requests.
2. Runs the deployable FederationService: image request -> SAC selection ->
   provider fan-out -> word grouping -> WBF ensemble, with cost/latency
   accounting.
3. Serves the same request stream through the micro-batching
   AsyncFederationService (sharded caches, one batched forward per flush).

  PYTHONPATH=src python examples/serve_provider.py --arch zamba2-2.7b
"""
import argparse
import time

import numpy as np

from repro.configs.base import get_arch
from repro.core.sac import SAC, SACConfig
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving.async_service import AsyncFederationService
from repro.serving.engine import Request, ServeEngine
from repro.serving.federation_service import FederationService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    # --- provider-side LM serving
    cfg = get_arch(args.arch).reduced()
    engine = ServeEngine(cfg, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, size=8, dtype=np.int32),
                    max_new_tokens=8, rid=i) for i in range(args.requests)]
    outs = engine.serve(reqs)
    print(f"[provider] {cfg.name}: served {len(outs)} requests "
          f"({outs[0].latency_s:.2f}s batch latency)")

    # --- federation-side service
    traces = generate_traces(default_providers(), 200, seed=0)
    env = ArmolEnv(traces, mode="gt", beta=-0.03, seed=0)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers))
    svc = FederationService(env, agent)
    for i in env.test_idx[:5]:
        res = svc.handle(int(i))
        picked = [env.traces.providers[j].name
                  for j in np.where(res.action > 0.5)[0]]
        print(f"[federation] image {int(i)}: providers={picked} "
              f"dets={len(res.detections)} cost={res.cost_milli_usd:.0f}m$ "
              f"latency={res.latency_ms:.0f}ms")

    # --- async federation serving: concurrent clients, micro-batched
    stream = [int(i) for i in
              np.random.default_rng(1).choice(env.test_idx, 200)]
    with AsyncFederationService(env, agent, max_batch=16, max_wait_ms=2.0,
                                workers=4) as asvc:
        asvc.handle_many(stream[:16])           # warm jit + shards
        asvc.reset_stats()
        t0 = time.time()
        results = [f.result() for f in [asvc.submit(i) for i in stream]]
        dt = time.time() - t0
        print(f"[federation/async] {len(results)} requests in {dt:.2f}s "
              f"({len(results) / max(dt, 1e-9):.0f} req/s, "
              f"mean flush {asvc.mean_flush_size():.1f}, "
              f"{asvc.workers} cache shards)")


if __name__ == "__main__":
    main()
