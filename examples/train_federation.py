"""Full federation training driver (the paper's experiment loop).

Reproduces the Tab. II protocol end-to-end: trace generation, word grouping,
SAC/TD3/PPO training with the combinatorial action mapping, per-epoch test
episodes, and a final comparison against Random-1/N, Ensemble-N, and the
brute-force Upper Bound.

Experience collection runs on the multi-lane batched drivers
(``--lanes`` parallel env lanes, fused lax.scan update blocks);
``--lanes 1`` reproduces the sequential reference bit-for-bit.

  PYTHONPATH=src python examples/train_federation.py --algo sac \
      --epochs 10 --steps 1000 --images 1000 --mode gt --beta -0.03 \
      --lanes 8
"""
import argparse
import json

from repro.core.loops import (ensembleN_policy, evaluate_policy,
                              random1_policy, randomN_policy, run_off_policy,
                              run_ppo, upper_bound)
from repro.core.ppo import PPO, PPOConfig
from repro.core.sac import SAC, SACConfig
from repro.core.td3 import TD3, TD3Config
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers, \
    scalability_providers
from repro.federation.traces import generate_traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", choices=["sac", "td3", "ppo"], default="sac")
    ap.add_argument("--mode", choices=["gt", "nogt"], default="gt")
    ap.add_argument("--beta", type=float, default=-0.03)
    ap.add_argument("--alpha", type=float, default=0.02)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--images", type=int, default=1000)
    ap.add_argument("--lanes", type=int, default=8,
                    help="parallel env lanes for the batched drivers "
                         "(1 = bit-identical to the sequential reference)")
    ap.add_argument("--ten-providers", action="store_true")
    ap.add_argument("--with-baselines", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    providers = scalability_providers() if args.ten_providers \
        else default_providers()
    traces = generate_traces(providers, args.images, seed=0)
    env = ArmolEnv(traces, mode=args.mode, beta=args.beta, seed=1)
    print(f"[federation] {len(providers)} providers, {args.images} images, "
          f"mode={args.mode}, beta={args.beta}")

    if args.algo == "sac":
        agent = SAC(SACConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers,
                              alpha=args.alpha))
        hist = run_off_policy(agent, env, lanes=args.lanes,
                              epochs=args.epochs,
                              steps_per_epoch=args.steps)
    elif args.algo == "td3":
        agent = TD3(TD3Config(state_dim=env.state_dim,
                              n_providers=env.n_providers))
        hist = run_off_policy(agent, env, lanes=args.lanes,
                              epochs=args.epochs,
                              steps_per_epoch=args.steps)
    else:
        agent = PPO(PPOConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers))
        hist = run_ppo(agent, env, lanes=args.lanes, epochs=args.epochs,
                       steps_per_epoch=args.steps)

    results = {"armol": hist[-1], "history": hist}
    if args.with_baselines:
        for name, pol in (("random1", random1_policy(env)),
                          ("randomN", randomN_policy(env)),
                          ("ensembleN", ensembleN_policy(env))):
            results[name] = evaluate_policy(pol, env)
        if env.n_providers <= 10:
            results["upper_bound"] = upper_bound(env)
        for k in ("random1", "randomN", "ensembleN", "upper_bound"):
            if k in results:
                r = results[k]
                print(f"  {k:12s} AP50={r['ap50']:5.2f} "
                      f"cost={r['cost']:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"[federation] wrote {args.out}")


if __name__ == "__main__":
    main()
