"""Flat-key npz checkpointing for arbitrary pytrees (no orbax offline).

Keys encode the tree path; dtypes (incl. bfloat16, via a uint16 view) and a
manifest of leaf treedefs round-trip exactly.  Works for model params, opt
states, RL agent states, and replay buffers.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_BF16_TAG = "__bf16__"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    leaves, treedef = _flatten(tree)
    arrays = {}
    meta = {"treedef": str(treedef), "n": len(leaves), "dtypes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            meta["dtypes"].append(_BF16_TAG)
            arr = arr.view(np.uint16)
        else:
            meta["dtypes"].append(str(arr.dtype))
        arrays[f"leaf_{i}"] = arr
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)
    with open(path.removesuffix(".npz") + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    with open(path.removesuffix(".npz") + ".meta.json") as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert meta["n"] == len(leaves_like), \
        f"checkpoint has {meta['n']} leaves, target has {len(leaves_like)}"
    out = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        if meta["dtypes"][i] == _BF16_TAG:
            arr = arr.view(jnp.bfloat16)
        ref_arr = np.asarray(ref)
        assert arr.shape == ref_arr.shape, \
            f"leaf {i}: ckpt {arr.shape} != target {ref_arr.shape}"
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
