from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, MoEConfig,  # noqa: F401
                                MLAConfig, SSMConfig, ShapeConfig, all_archs,
                                get_arch, get_shape)
