"""Architecture + workload configuration system.

Every assigned architecture gets one module in this package exporting ``CONFIG``
(the exact full-scale config) and the registry maps ``--arch <id>`` to it.
``reduced()`` derives the CPU-smoke variant (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0             # hidden dim of the shared expert(s)
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    # layer index predicate: layers < first_dense_layers are dense
    first_dense_layers: int = 0
    d_ff_dense: int = 0           # FFN dim of the dense (non-MoE) layers


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    qk_norm: bool = False
    parallel_block: bool = False  # command-r style parallel attn+mlp
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # vlm: cross-attention every `cross_attn_every` layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    d_vision: int = 0
    # hybrid (zamba2): shared attention block applied every `shared_attn_every`
    shared_attn_every: int = 0
    # encoder-decoder (audio): num_layers == decoder layers
    encoder_layers: int = 0
    num_audio_frames: int = 0
    # long-context plan: "native" (ssm/state/latent) or "sliding_window"
    long_context: str = "sliding_window"
    sliding_window: int = 8192
    dtype: str = "bfloat16"
    source: str = ""              # provenance citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.family == "ssm" or (self.family == "hybrid"):
            ssm = self.ssm
            assert ssm is not None
            di = ssm.d_inner(d)
            nh = ssm.n_heads(d)
            mamba = d * (2 * di + 2 * ssm.d_state * 1 + nh)  # in_proj(z,x,B,C,dt)
            mamba += di * ssm.d_conv + di * d  # conv + out_proj
            mamba += 2 * nh + di               # A_log, D, dt_bias-ish
        if self.family == "ssm":
            n += self.num_layers * (mamba + d)
            return n
        # attention params
        if self.mla is not None:
            m = self.mla
            attn = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim)
            attn += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            attn += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            attn += self.num_heads * m.v_head_dim * d
        else:
            attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
        ffn_dense = 3 * d * self.d_ff
        if self.moe is not None:
            mo = self.moe
            ffn_moe = mo.num_experts * 3 * d * mo.d_expert \
                + mo.num_shared_experts * 3 * d * mo.d_shared + d * mo.num_experts
            n_moe_layers = self.num_layers - mo.first_dense_layers
            n += mo.first_dense_layers * (attn + 3 * d * mo.d_ff_dense)
            n += n_moe_layers * (attn + ffn_moe)
        elif self.family == "hybrid":
            # zamba: num_layers mamba blocks + ONE shared attn+ffn block
            n += self.num_layers * (mamba + d)
            n += attn + ffn_dense
        else:
            layers = self.num_layers + self.encoder_layers
            n += layers * (attn + ffn_dense)
            if self.is_encoder_decoder:  # cross attention in decoder
                n += self.num_layers * attn
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            n += n_cross * (attn + ffn_dense)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full_ffn = mo.num_experts * 3 * self.d_model * mo.d_expert
        act_ffn = mo.top_k * 3 * self.d_model * mo.d_expert
        n_moe_layers = self.num_layers - mo.first_dense_layers
        return self.param_count() - n_moe_layers * (full_ffn - act_ffn)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        hd = d // heads if heads else 0
        kw = dict(
            num_layers=2, d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=hd, d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512), sliding_window=64,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            d_vision=min(self.d_vision, d) if self.d_vision else 0,
            num_audio_frames=min(self.num_audio_frames, 16) if self.num_audio_frames else 0,
            encoder_layers=2 if self.encoder_layers else 0,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=2, d_expert=64,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_shared=64 if self.moe.num_shared_experts else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=128 if self.moe.first_dense_layers else 0,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=hd, qk_rope_head_dim=hd // 2,
                                  v_head_dim=hd)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                                  chunk=32)
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (workloads)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "command-r-plus-104b",
    "olmoe-1b-7b",
    "qwen1.5-110b",
    "stablelm-12b",
    "deepseek-v2-236b",
    "llama-3.2-vision-11b",
    "mamba2-370m",
    "qwen1.5-0.5b",
    "zamba2-2.7b",
    "seamless-m4t-medium",
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def all_archs():
    return {a: get_arch(a) for a in ARCH_IDS}
