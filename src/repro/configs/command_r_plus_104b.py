"""Command R+ 104B — dense GQA, no biases, parallel attn+FFN block.

[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    qkv_bias=False,
    norm="layernorm",          # Cohere uses bias-free LayerNorm
    act="silu",
    rope_theta=75_000_000.0,
    tie_embeddings=True,
    parallel_block=True,       # attn and MLP read the same norm output
    long_context="sliding_window",
    sliding_window=8192,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
