"""DeepSeek-V2 236B — MLA (kv_lora=512) + 160-routed/2-shared top-6 MoE.

Layer 0 is a dense FFN layer (d_ff=12288); layers 1..59 are MoE.
Decode caches the 512-d latent + rope key only -> long_500k is native.
[arXiv:2405.04434]
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: effectively MHA over decompressed KV
    head_dim=128,
    d_ff=1536,                 # routed-expert hidden dim
    vocab_size=102400,
    qkv_bias=False,
    norm="rmsnorm",
    act="silu",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                  num_shared_experts=2, d_shared=1536,
                  first_dense_layers=1, d_ff_dense=12288),
    long_context="native",     # latent KV cache is (seq, 512+64) per layer
    source="arXiv:2405.04434",
)
