"""Llama-3.2-Vision-11B backbone — GQA decoder with cross-attn image layers.

Every 5th layer is a gated cross-attention layer over precomputed patch
embeddings (vision encoder is a STUB per the assignment carve-out:
input_specs() supplies (B, 1600, 4096) projected patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    qkv_bias=False,
    norm="rmsnorm",
    act="silu",
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1600,
    d_vision=4096,
    long_context="sliding_window",
    sliding_window=8192,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
