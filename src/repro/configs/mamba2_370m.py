"""Mamba2-370M — attention-free SSD (state-space duality).

[arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    long_context="native",     # O(1) recurrent state
    source="arXiv:2405.21060",
)
