"""OLMoE-1B-7B — 64-expert top-8 MoE, every layer MoE, QK-norm.

[arXiv:2409.02060]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,                 # per-expert hidden dim
    vocab_size=50304,
    qkv_bias=False,
    norm="rmsnorm",
    act="silu",
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    long_context="sliding_window",
    sliding_window=8192,
    source="arXiv:2409.02060",
)
