"""Qwen1.5-110B — dense GQA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family scaling]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    sliding_window=8192,
    source="hf:Qwen/Qwen1.5-0.5B",
)
