"""SeamlessM4T-medium backbone — encoder-decoder transformer.

Audio frontend (mel + conv codec) is a STUB per the assignment carve-out:
input_specs() supplies precomputed (B, frames, 1024) frame embeddings.
[arXiv:2308.11596]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    num_audio_frames=1024,
    long_context="sliding_window",
    sliding_window=8192,
    source="arXiv:2308.11596",
)
