"""StableLM-2-12B — dense GQA, head_dim=160, per-head QK-norm.

Paper uses 25% partial RoPE; we apply full RoPE (delta documented in DESIGN.md).
[hf:stabilityai/stablelm-2-1_6b family scaling]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    qkv_bias=False,
    norm="layernorm",
    act="silu",
    qk_norm=True,
    long_context="sliding_window",
    sliding_window=8192,
    source="hf:stabilityai/stablelm-2-1_6b",
)
