"""Zamba2-2.7B — hybrid: 54 Mamba-2 blocks + ONE shared GQA attn+FFN block
applied every 6 mamba blocks (9 super-blocks).

[arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    norm="rmsnorm",
    act="gelu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_every=6,
    long_context="native",     # mamba state is O(1); shared attn uses ring cache
    sliding_window=8192,
    source="arXiv:2411.15242",
)
