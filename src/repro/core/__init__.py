# The paper's primary contribution: combinatorial-RL MLaaS provider
# selection (Armol).  Agents (SAC / TD3 / PPO), the nearest-neighbour
# combinatorial action mapping, replay buffer, and the state feature
# extractor live here; the environment/trace substrate is repro.federation.
from repro.core.action_space import (threshold_map, codebook,  # noqa: F401
                                     nearest_in_codebook, wolpertinger_select)
from repro.core.replay_buffer import ReplayBuffer  # noqa: F401
from repro.core.sac import SAC, SACConfig  # noqa: F401
from repro.core.td3 import TD3, TD3Config  # noqa: F401
from repro.core.ppo import PPO, PPOConfig  # noqa: F401
