"""Combinatorial action space for MLaaS provider selection (paper Eq. 3-4).

The actor emits a *proto action* a_hat in [0,1]^N; tau maps it to the nearest
binary vector in A = {0,1}^N \\ {0}:

    tau(a_hat) = argmin_{a in A} |a - a_hat|^2

Three implementations:
  * ``threshold_map`` — exact O(N) nearest neighbour.  For the l2 metric over
    the unconstrained hypercube the NN is elementwise thresholding at 0.5;
    the a != 0 constraint is enforced by flipping the largest coordinate on
    (the flip with minimal l2 penalty), which is provably still the argmin
    over A.
  * ``nearest_in_codebook`` — brute-force argmin over the enumerated
    codebook (N <= 16), used as the oracle in property tests.
  * ``wolpertinger_select`` — beyond-paper: k nearest codebook actions
    re-ranked by the critic Q(s, a) (Dulac-Arnold et al. 2015), which trades
    a little compute for robustness to critic/actor mismatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def threshold_map(proto: jnp.ndarray) -> jnp.ndarray:
    """Exact tau for a single proto action or a batch (last dim = N)."""
    a = (proto > 0.5).astype(jnp.float32)
    # enforce a != 0: if empty, set the coordinate with the largest proto
    empty = jnp.sum(a, axis=-1, keepdims=True) == 0
    best = jax.nn.one_hot(jnp.argmax(proto, axis=-1), proto.shape[-1],
                          dtype=jnp.float32)
    return jnp.where(empty, best, a)


@functools.lru_cache(maxsize=8)
def codebook(n: int) -> np.ndarray:
    """All binary vectors in {0,1}^n except 0 — shape (2^n - 1, n)."""
    assert n <= 16, "codebook enumeration is for small N only"
    idx = np.arange(1, 2 ** n, dtype=np.uint32)
    bits = ((idx[:, None] >> np.arange(n)[None, :]) & 1).astype(np.float32)
    return bits


def nearest_in_codebook(proto: jnp.ndarray, n: int) -> jnp.ndarray:
    cb = jnp.asarray(codebook(n))                    # (M, n)
    d = jnp.sum((cb - proto[..., None, :]) ** 2, axis=-1)   # (..., M)
    return cb[jnp.argmin(d, axis=-1)]


def k_nearest(proto: jnp.ndarray, n: int, k: int) -> jnp.ndarray:
    cb = jnp.asarray(codebook(n))
    d = jnp.sum((cb - proto[..., None, :]) ** 2, axis=-1)   # (..., M)
    _, idx = jax.lax.top_k(-d, k)
    return cb[idx]                                   # (..., k, n)


def wolpertinger_select(proto: jnp.ndarray, state: jnp.ndarray, q_fn,
                        *, k: int = 8) -> jnp.ndarray:
    """tau followed by critic re-ranking over the k nearest actions.

    q_fn(state (D,), actions (k, N)) -> (k,) values.
    """
    n = proto.shape[-1]
    cand = k_nearest(proto, n, k)                    # (k, n)
    q = q_fn(state, cand)
    return cand[jnp.argmax(q)]
