"""Shared fused-update machinery for the RL agents.

``scan_update_block(update_fn)`` lifts a per-step jitted update
``(cfg, state, batch) -> (state, metrics)`` into a jitted ``lax.scan``
over stacked (K, B, ...) batches with donated agent state, so a block of
K gradient steps costs one host->device round trip.  On CPU the scanned
body is bit-identical to K eager ``update_fn`` calls (asserted by the
parity suite), so drivers may mix the two freely.
"""
from __future__ import annotations

from functools import partial

import jax


def scan_update_block(update_fn):
    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _block(cfg, state, batches):
        def body(st, b):
            st2, metrics = update_fn(cfg, st, b)
            return st2, metrics
        return jax.lax.scan(body, state, batches)
    return _block
