"""Device-resident circular replay buffer (jax arrays end to end).

``DeviceReplayBuffer`` mirrors the numpy ``ReplayBuffer`` contract but
keeps all transition storage on device: circular writes are donated
jitted scatters (``lax.dynamic_update_slice`` for scalar adds, index
scatter for batches), and ``sample``/``sample_block`` gather directly
into device arrays that ``SAC/TD3.update_block`` consumes with zero
host round trips between collect and update.

Two index sources for the sample draw:

  * ``index_mode="jax"``   — a jitted, fori-free ``jax.random.randint``
    over an explicit PRNG key held by the buffer (the production path:
    the whole sample->update chain stays on device).
  * ``index_mode="host"``  — indices from the same
    ``np.random.default_rng(seed)`` stream the numpy buffer consumes,
    gathered on device.  Pure gathers are exact, so a driver fed this
    buffer is BIT-IDENTICAL to one fed the numpy buffer (transition
    stream, sampled batches, update math) — the parity mode the
    device-path driver tests pin against the frozen sequential
    references.

With a ``feature_table`` (a device mirror of the env's per-image state
features), ``add_batch_indexed`` assembles the state/next-state rows ON
DEVICE from image indices — the host ships only small index/reward
vectors per tick, never the (L, D) feature rows.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class _Store(NamedTuple):
    state: jnp.ndarray
    action: jnp.ndarray
    reward: jnp.ndarray
    next_state: jnp.ndarray
    done: jnp.ndarray


def _scatter(store: _Store, rows: _Store, idx: jnp.ndarray) -> _Store:
    return _Store(*(buf.at[idx].set(new)
                    for buf, new in zip(store, rows)))


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _write_batch(store: _Store, rows: _Store, ptr, capacity: int) -> _Store:
    """Donated circular write of B rows starting at ``ptr``; B <= capacity
    (the caller drops the rows a scalar loop would overwrite)."""
    B = rows.reward.shape[0]
    idx = (ptr + jnp.arange(B)) % capacity
    return _scatter(store, rows, idx)


def _slab(store: _Store, rows: _Store, ptr) -> _Store:
    def upd(buf, new):
        start = (ptr,) + (0,) * (buf.ndim - 1)
        return jax.lax.dynamic_update_slice(buf, new, start)
    return _Store(*(upd(buf, new) for buf, new in zip(store, rows)))


@partial(jax.jit, donate_argnums=(0,))
def _write_batch_contig(store: _Store, rows: _Store, ptr) -> _Store:
    """Non-wrapping fast path (the common case: the host caller knows
    ptr + B <= capacity): a donated contiguous slab update, cheaper to
    lower than the modular scatter."""
    return _slab(store, rows, ptr)


@partial(jax.jit, static_argnums=(4,), donate_argnums=(0,))
def _write_batch_indexed(store: _Store, table, parts, ptr,
                         capacity: int) -> _Store:
    """Like ``_write_batch`` but the state/next-state rows are gathered
    from the device feature table inside the same jitted write — the
    on-device env feature assembly path."""
    s_idx, a, r, s2_idx, d = parts
    rows = _Store(table[s_idx], a, r, table[s2_idx], d)
    B = rows.reward.shape[0]
    idx = (ptr + jnp.arange(B)) % capacity
    return _scatter(store, rows, idx)


@partial(jax.jit, donate_argnums=(0,))
def _write_batch_indexed_contig(store: _Store, table, parts, ptr) -> _Store:
    """Non-wrapping variant of the indexed write."""
    s_idx, a, r, s2_idx, d = parts
    return _slab(store, _Store(table[s_idx], a, r, table[s2_idx], d), ptr)


@partial(jax.jit, donate_argnums=(0,))
def _write_one(store: _Store, rows: _Store, ptr) -> _Store:
    """Donated single-row write at ``ptr`` (never wraps)."""
    def upd(buf, row):
        start = (ptr,) + (0,) * (buf.ndim - 1)
        return jax.lax.dynamic_update_slice(buf, row[None], start)
    return _Store(*(upd(buf, row) for buf, row in zip(store, rows)))


@partial(jax.jit, static_argnums=(2, 3))
def _draw_block(key, size, iters: int, batch: int):
    """One key split + ONE fori-free randint for the whole (iters, batch)
    index matrix (``size`` is traced, so buffer growth never recompiles)."""
    key, sub = jax.random.split(key)
    idx = jax.random.randint(sub, (iters, batch), 0, size)
    return key, idx


@partial(jax.jit, static_argnums=(3, 4))
def _sample_block_jax(store: _Store, key, size, iters: int, batch: int):
    """Fused draw + gather: the jax-index-mode hot path is ONE dispatch
    per sampled block."""
    key, sub = jax.random.split(key)
    idx = jax.random.randint(sub, (iters, batch), 0, size)
    return key, {"s": store.state[idx], "a": store.action[idx],
                 "r": store.reward[idx], "s2": store.next_state[idx],
                 "d": store.done[idx]}


@jax.jit
def _gather(store: _Store, idx) -> Dict[str, jnp.ndarray]:
    return {"s": store.state[idx], "a": store.action[idx],
            "r": store.reward[idx], "s2": store.next_state[idx],
            "d": store.done[idx]}


class DeviceReplayBuffer:
    """Drop-in replay buffer with jax-array storage.

    ``state``/``action``/... read back as numpy views (host copies) so
    the numpy buffer's parity assertions apply verbatim; the hot path
    never touches them.
    """

    # run_off_policy keys off these to fuse collect->update on device
    device_resident = True

    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0, *, index_mode: str = "jax",
                 feature_table: Optional[jnp.ndarray] = None):
        if index_mode not in ("jax", "host"):
            raise ValueError(f"index_mode must be 'jax' or 'host', "
                             f"got {index_mode!r}")
        self.capacity = capacity
        self.index_mode = index_mode
        self._store = _Store(
            jnp.zeros((capacity, state_dim), jnp.float32),
            jnp.zeros((capacity, action_dim), jnp.float32),
            jnp.zeros((capacity,), jnp.float32),
            jnp.zeros((capacity, state_dim), jnp.float32),
            jnp.zeros((capacity,), jnp.float32))
        self.size = 0
        self.ptr = 0
        # host generator mirrors the numpy buffer's stream; in "jax" mode
        # the explicit PRNG key drives the jitted index draw instead
        self.rng = np.random.default_rng(seed)
        self._key = jax.random.PRNGKey(seed)
        self.feature_table = None if feature_table is None else \
            jnp.asarray(feature_table, jnp.float32)

    # ------------------------------------------------------------------
    # numpy-compatible read views (parity assertions, checkpoints)
    # ------------------------------------------------------------------
    @property
    def state(self) -> np.ndarray:
        return np.asarray(self._store.state)

    @property
    def action(self) -> np.ndarray:
        return np.asarray(self._store.action)

    @property
    def reward(self) -> np.ndarray:
        return np.asarray(self._store.reward)

    @property
    def next_state(self) -> np.ndarray:
        return np.asarray(self._store.next_state)

    @property
    def done(self) -> np.ndarray:
        return np.asarray(self._store.done)

    @property
    def indexed(self) -> bool:
        """True when ``add_batch_indexed`` can assemble feature rows on
        device (a feature table was attached)."""
        return self.feature_table is not None

    # ------------------------------------------------------------------
    # writes
    # ------------------------------------------------------------------
    def _advance(self, B: int) -> None:
        self.ptr = (self.ptr + B) % self.capacity
        self.size = min(self.size + B, self.capacity)

    # Host-boundary discipline: normalize shapes/dtypes with cheap numpy
    # ops and hand NUMPY leaves straight to the jitted writes — the pjit
    # C++ fastpath converts arguments at dispatch for a fraction of what
    # a python-level ``jnp.asarray`` per leaf costs.

    def add(self, s, a, r, s2, d) -> None:
        rows = _Store(np.asarray(s, np.float32).reshape(-1),
                      np.asarray(a, np.float32).reshape(-1),
                      np.float32(r), np.asarray(s2, np.float32).reshape(-1),
                      np.float32(d))
        self._store = _write_one(self._store, rows, self.ptr)
        self._advance(1)

    def add_batch(self, s, a, r, s2, d) -> None:
        """Vectorized donated circular write of B transitions; matches B
        scalar ``add`` calls exactly, wraparound and B > capacity (only
        the last ``capacity`` rows survive) included."""
        state_dim = self._store.state.shape[1]
        action_dim = self._store.action.shape[1]
        rows = _Store(np.asarray(s, np.float32).reshape(-1, state_dim),
                      np.asarray(a, np.float32).reshape(-1, action_dim),
                      np.asarray(r, np.float32).reshape(-1),
                      np.asarray(s2, np.float32).reshape(-1, state_dim),
                      np.asarray(d, np.float32).reshape(-1))
        B = rows.reward.shape[0]
        if B == 0:
            return
        skip = max(0, B - self.capacity)    # rows a scalar loop overwrites
        if skip:
            rows = _Store(*(x[skip:] for x in rows))
        start = (self.ptr + skip) % self.capacity
        if start + (B - skip) <= self.capacity:     # no wrap: slab update
            self._store = _write_batch_contig(self._store, rows, start)
        else:
            self._store = _write_batch(self._store, rows, start,
                                       self.capacity)
        self._advance(B)

    def add_batch_indexed(self, s_idx, a, r, s2_idx, d) -> None:
        """Circular write where state/next-state rows are gathered ON
        DEVICE from the attached feature table — only image indices,
        actions, rewards and done flags cross the host boundary."""
        if self.feature_table is None:
            raise ValueError("add_batch_indexed requires a feature_table")
        action_dim = self._store.action.shape[1]
        parts = (np.asarray(s_idx, np.int32).reshape(-1),
                 np.asarray(a, np.float32).reshape(-1, action_dim),
                 np.asarray(r, np.float32).reshape(-1),
                 np.asarray(s2_idx, np.int32).reshape(-1),
                 np.asarray(d, np.float32).reshape(-1))
        B = parts[2].shape[0]
        if B == 0:
            return
        skip = max(0, B - self.capacity)
        if skip:
            parts = tuple(x[skip:] for x in parts)
        start = (self.ptr + skip) % self.capacity
        if start + (B - skip) <= self.capacity:     # no wrap: slab update
            self._store = _write_batch_indexed_contig(
                self._store, self.feature_table, parts, start)
        else:
            self._store = _write_batch_indexed(
                self._store, self.feature_table, parts, start,
                self.capacity)
        self._advance(B)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _indices(self, shape):
        if self.index_mode == "host":
            # numpy leaves go straight to the jitted gather (fastpath)
            return self.rng.integers(0, self.size, size=shape)
        if len(shape) == 2:
            self._key, idx = _draw_block(self._key, self.size, *shape)
        else:
            self._key, idx = _draw_block(self._key, self.size, 1, shape[0])
            idx = idx[0]
        return idx

    def sample(self, batch: int) -> Dict[str, jnp.ndarray]:
        if self.size == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        return _gather(self._store, self._indices((batch,)))

    def sample_block(self, iters: int, batch: int) -> Dict[str, jnp.ndarray]:
        """``iters`` update batches in one draw + one device gather: dict
        of (iters, batch, ...) DEVICE arrays, fed straight to
        ``update_block`` without host materialization.  In jax index
        mode draw + gather fuse into a single dispatch (the index stream
        matches ``_draw_block`` exactly — same split, same randint)."""
        if self.size == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        if self.index_mode == "jax":
            self._key, blk = _sample_block_jax(self._store, self._key,
                                               self.size, iters, batch)
            return blk
        return _gather(self._store, self._indices((iters, batch)))

    def __len__(self) -> int:
        return self.size
