"""Training/evaluation loops for the federation agents + paper baselines.

Replicates the paper's protocol: off-policy agents (SAC/TD3) interact with
the trace env and update from the replay buffer; PPO collects on-policy
rollouts; at the end of every epoch the agent is evaluated deterministically
on the held-out test episode (corpus AP50 + average cost + per-provider
selection counts — the columns of Tab. II).  Baselines: Random-1, Random-N,
Ensemble-N, and the brute-force Upper Bound (Algo. 2).

Evaluation rides the memoized subset-evaluation core: ``evaluate_policy``
computes all test-split actions in ONE agent forward pass (the MLP heads
are batch-polymorphic) and reuses cached (image, subset) ensembles across
epochs; ``upper_bound`` enumerates subsets in popcount order through the
cache, paying for each image's IoU table exactly once instead of once per
candidate subset.
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.ppo import PPO
from repro.core.replay_buffer import ReplayBuffer
from repro.ensemble.metrics import ap50, coco_map
from repro.federation.env import ArmolEnv
from repro.federation.evaluation import mask_to_action, popcount_masks


# ---------------------------------------------------------------------------
# Evaluation (one "test episode" = the whole test split)
# ---------------------------------------------------------------------------

def agent_policy(agent, *, deterministic: bool = True
                 ) -> Callable[[np.ndarray], np.ndarray]:
    """Wrap an agent as a state->action policy with a batched fast path.

    The returned callable maps one state to one binary action (the seed
    contract); its ``select_batch`` attribute maps a (T, D) state matrix to
    (T, N) actions in a single jitted forward pass.  Falls back to row-wise
    calls when the agent's action head is not batch-polymorphic (e.g.
    Wolpertinger re-ranking)."""
    def single(s: np.ndarray) -> np.ndarray:
        return agent.select_action(s, deterministic=deterministic)[0]

    def select_batch(states: np.ndarray) -> np.ndarray:
        try:
            a = np.asarray(
                agent.select_action(states, deterministic=deterministic)[0])
            if a.ndim == 2 and a.shape[0] == len(states):
                return a
        except (TypeError, ValueError):
            # non-batch-polymorphic action head (e.g. PPO's scalar logp,
            # Wolpertinger re-ranking); anything else should propagate
            pass
        return np.stack([single(s) for s in states])

    single.select_batch = select_batch
    return single


def _policy_actions(select_fn, env: ArmolEnv,
                    img_indices: np.ndarray) -> np.ndarray:
    """All actions for a set of images — one batched forward when the
    policy supports it, else the seed's per-image calls."""
    batch = getattr(select_fn, "select_batch", None)
    if batch is not None:
        return np.asarray(batch(env.features[img_indices]), np.float32)
    return np.stack([np.asarray(select_fn(env.features[img]), np.float32)
                     for img in img_indices])


def evaluate_policy(select_fn: Callable[[np.ndarray], np.ndarray],
                    env: ArmolEnv, *, against: str = "gt") -> Dict:
    """select_fn(state) -> binary action.  Corpus AP vs the TRUE ground truth
    (evaluation always uses GT even for w/o-gt-trained agents, as in the
    paper's Tab. II)."""
    actions = _policy_actions(select_fn, env, env.test_idx)
    env.core.precompute(env.test_idx)
    dts, gts = {}, {}
    counts = np.zeros(env.n_providers, np.int64)
    total_cost = 0.0
    for img, a in zip(env.test_idx, actions):
        counts += (a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (a > 0.5)))
        dts[int(img)] = env.core.ensemble(int(img), env.core.mask_of(a))
        gts[int(img)] = env.traces.gts[int(img)]
    n = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / n,
            "counts": counts.tolist(), "n_images": n}


# ---------------------------------------------------------------------------
# Off-policy driver (SAC / TD3)
# ---------------------------------------------------------------------------

def run_off_policy(agent, env: ArmolEnv, *, epochs: int = 5,
                   steps_per_epoch: int = 500, batch_size: int = 256,
                   start_steps: int = 200, update_after: int = 300,
                   update_every: int = 50, update_iters: int = 50,
                   buffer_capacity: int = 100_000, seed: int = 0,
                   log: Optional[Callable[[str], None]] = print) -> List[Dict]:
    rng = np.random.default_rng(seed)
    buf = ReplayBuffer(buffer_capacity, env.state_dim, env.n_providers,
                       seed=seed)
    history = []
    s = env.reset(split="train")
    total = 0
    for epoch in range(epochs):
        t0 = time.time()
        for _ in range(steps_per_epoch):
            if total < start_steps:
                a = rng.integers(0, 2, env.n_providers).astype(np.float32)
                if a.sum() == 0:
                    a[rng.integers(env.n_providers)] = 1.0
            else:
                a, _ = agent.select_action(s)
            s2, r, done, info = env.step(a)
            buf.add(s, a, r, s2, float(done))
            s = env.reset(split="train") if done else s2
            total += 1
            if total >= update_after and total % update_every == 0:
                for _ in range(update_iters):
                    agent.update(buf.sample(batch_size))
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "steps": total,
                    "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[{type(agent).__name__}] epoch {epoch}: "
                f"AP50={res['ap50']:.2f} mAP={res['map']:.2f} "
                f"cost={res['cost']:.3f} counts={res['counts']}")
    return history


# ---------------------------------------------------------------------------
# On-policy driver (PPO)
# ---------------------------------------------------------------------------

def run_ppo(agent: PPO, env: ArmolEnv, *, epochs: int = 5,
            steps_per_epoch: int = 500, seed: int = 0,
            log: Optional[Callable[[str], None]] = print) -> List[Dict]:
    history = []
    s = env.reset(split="train")
    for epoch in range(epochs):
        t0 = time.time()
        S, P, LP, R, D, V = [], [], [], [], [], []
        for _ in range(steps_per_epoch):
            a, proto, logp, v = agent.select_action(s)
            s2, r, done, info = env.step(a)
            S.append(s)
            P.append(proto)
            LP.append(logp)
            R.append(r)
            D.append(float(done))
            V.append(v)
            s = env.reset(split="train") if done else s2
        _, _, _, last_v = agent.select_action(s)
        adv, ret = agent.gae(np.asarray(R, np.float32),
                             np.asarray(V, np.float32),
                             np.asarray(D, np.float32), last_v)
        rollout = {"s": np.asarray(S, np.float32),
                   "proto": np.asarray(P, np.float32),
                   "logp": np.asarray(LP, np.float32),
                   "adv": adv, "ret": ret}
        agent.update_from_rollout(rollout)
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[PPO] epoch {epoch}: AP50={res['ap50']:.2f} "
                f"cost={res['cost']:.3f}")
    return history


# ---------------------------------------------------------------------------
# Baselines (Tab. II)
# ---------------------------------------------------------------------------

def random1_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = np.zeros(env.n_providers, np.float32)
        a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def randomN_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = rng.integers(0, 2, env.n_providers).astype(np.float32)
        if a.sum() == 0:
            a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def ensembleN_policy(env: ArmolEnv):
    def f(_s):
        return np.ones(env.n_providers, np.float32)
    return f


def enumeration_actions(n: int) -> List[np.ndarray]:
    """The Algo.-2 candidate list: all non-empty binary vectors, stable-
    sorted by popcount (ties keep itertools.product order, matching the
    seed's tie-breaking toward cheaper-first enumeration)."""
    actions = [np.asarray(a, np.float32)
               for a in itertools.product([0, 1], repeat=n) if any(a)]
    actions.sort(key=lambda a: (a.sum(),))
    return actions


def upper_bound(env: ArmolEnv) -> Dict:
    """Brute force (Algo. 2): per test image, the best action by per-image
    AP50; ties broken toward the cheaper subset (enumeration in increasing
    popcount order, strict improvement required).

    Enumerates through the subset-evaluation cache: each image pays for its
    IoU table once, every subset's ensemble is an O(1) slice + grouping,
    and single-provider entries seed the memo for later callers.
    """
    n = env.n_providers
    masks = popcount_masks(n)
    action_of = {m: mask_to_action(m, n) for m in masks}
    env.core.precompute(env.test_idx)
    dts, gts = {}, {}
    counts = np.zeros(n, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        best_m, _ = env.core.best_subset(int(img), masks, against="gt")
        best_a = action_of[best_m]
        counts += (best_a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (best_a > 0.5)))
        dts[int(img)] = env.core.ensemble(int(img), best_m)
        gts[int(img)] = env.traces.gts[int(img)]
    m = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / m, "counts": counts.tolist(), "n_images": m}
