"""Training/evaluation loops for the federation agents + paper baselines.

Replicates the paper's protocol: off-policy agents (SAC/TD3) interact with
the trace env and update from the replay buffer; PPO collects on-policy
rollouts; at the end of every epoch the agent is evaluated deterministically
on the held-out test episode (corpus AP50 + average cost + per-provider
selection counts — the columns of Tab. II).  Baselines: Random-1, Random-N,
Ensemble-N, and the brute-force Upper Bound (Algo. 2).

Evaluation rides the memoized subset-evaluation core: ``evaluate_policy``
computes all test-split actions in ONE agent forward pass (the MLP heads
are batch-polymorphic) and reuses cached (image, subset) ensembles across
epochs; ``upper_bound`` enumerates subsets in popcount order through the
cache, paying for each image's IoU table exactly once instead of once per
candidate subset.

Training comes in two flavours per algorithm family:

  * ``run_offpolicy_sequential`` / ``run_ppo_sequential`` — the seed's
    scalar drivers, kept frozen as the parity reference: one ``env.step``
    per transition, one ``buf.add`` per transition, one jitted
    ``agent.update`` dispatch per gradient step.
  * ``run_off_policy`` / ``run_ppo`` — multi-lane drivers: L parallel
    episode lanes stepped through ``ArmolEnv.step_lanes`` (one batched
    agent forward + one batched subset evaluation per tick), transitions
    written with ``ReplayBuffer.add_batch``, and gradient steps fused into
    jitted ``lax.scan`` blocks fed by a pre-sampled index matrix
    (``sample_block``), so the host touches the device once per block.

At ``lanes=1`` the multi-lane drivers consume every rng stream (env
shuffles, exploration draws, buffer sampling, agent keys) in exactly the
sequential order and keep the sequential array shapes on the act path, so
their transition streams and evaluation histories are bit-identical to
the reference drivers — ``tests/test_train_drivers.py`` asserts this.
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.ppo import PPO
from repro.core.replay_buffer import ReplayBuffer
from repro.ensemble.metrics import ap50, coco_map
from repro.federation.env import ArmolEnv
from repro.federation.evaluation import mask_to_action, popcount_masks


# ---------------------------------------------------------------------------
# Evaluation (one "test episode" = the whole test split)
# ---------------------------------------------------------------------------

def _make_batch_select(agent, *, deterministic: bool):
    """(T, D) states -> (T, N) actions in one forward when possible.

    Prefers a dedicated ``select_action_batch`` (PPO's scalar-logp
    ``select_action`` can't batch); otherwise probes whether the plain
    action head is batch-polymorphic — at most once, since a failed probe
    wastes a forward AND consumes an agent rng key — and falls back to
    row-wise calls (e.g. Wolpertinger re-ranking)."""
    batch_fn = getattr(agent, "select_action_batch", None)
    batched = None

    def select(states: np.ndarray) -> np.ndarray:
        nonlocal batched
        if batch_fn is not None:
            return np.asarray(
                batch_fn(states, deterministic=deterministic)[0],
                np.float32)
        if batched is None or batched:
            try:
                a = np.asarray(
                    agent.select_action(
                        states, deterministic=deterministic)[0], np.float32)
                if a.ndim == 2 and a.shape[0] == len(states):
                    batched = True
                    return a
            except (TypeError, ValueError):
                pass
            batched = False
        return np.stack([
            np.asarray(agent.select_action(
                s, deterministic=deterministic)[0], np.float32)
            for s in states])
    return select


def agent_policy(agent, *, deterministic: bool = True
                 ) -> Callable[[np.ndarray], np.ndarray]:
    """Wrap an agent as a state->action policy with a batched fast path.

    The returned callable maps one state to one binary action (the seed
    contract); its ``select_batch`` attribute maps a (T, D) state matrix
    to (T, N) actions in a single jitted forward pass, with a row-wise
    fallback for non-batch-polymorphic action heads."""
    def single(s: np.ndarray) -> np.ndarray:
        return agent.select_action(s, deterministic=deterministic)[0]

    single.select_batch = _make_batch_select(agent,
                                             deterministic=deterministic)
    return single


def _policy_actions(select_fn, env: ArmolEnv,
                    img_indices: np.ndarray) -> np.ndarray:
    """All actions for a set of images — one batched forward when the
    policy supports it, else the seed's per-image calls."""
    batch = getattr(select_fn, "select_batch", None)
    if batch is not None:
        return np.asarray(batch(env.features[img_indices]), np.float32)
    return np.stack([np.asarray(select_fn(env.features[img]), np.float32)
                     for img in img_indices])


def evaluate_policy(select_fn: Callable[[np.ndarray], np.ndarray],
                    env: ArmolEnv, *, against: str = "gt") -> Dict:
    """select_fn(state) -> binary action.  Corpus AP vs the TRUE ground truth
    (evaluation always uses GT even for w/o-gt-trained agents, as in the
    paper's Tab. II)."""
    actions = _policy_actions(select_fn, env, env.test_idx)
    env.core.precompute(env.test_idx)
    dts, gts = {}, {}
    bits = actions > 0.5
    counts = bits.sum(axis=0).astype(np.int64)
    # one fee matvec over the whole action matrix; the per-row reduction
    # matches the old per-action np.sum bit for bit, and the python
    # accumulation keeps the old sequential summation order
    total_cost = 0.0
    for c in (env.costs * bits).sum(axis=1):
        total_cost += float(c)
    for img, a in zip(env.test_idx, actions):
        dts[int(img)] = env.core.ensemble(int(img), env.core.mask_of(a))
        gts[int(img)] = env.traces.gts[int(img)]
    n = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / n,
            "counts": counts.tolist(), "n_images": n}


# ---------------------------------------------------------------------------
# Off-policy drivers (SAC / TD3)
# ---------------------------------------------------------------------------

def run_offpolicy_sequential(agent, env: ArmolEnv, *, epochs: int = 5,
                             steps_per_epoch: int = 500,
                             batch_size: int = 256,
                             start_steps: int = 200, update_after: int = 300,
                             update_every: int = 50, update_iters: int = 50,
                             buffer_capacity: int = 100_000, seed: int = 0,
                             log: Optional[Callable[[str], None]] = print,
                             buffer: Optional[ReplayBuffer] = None
                             ) -> List[Dict]:
    """The seed's scalar off-policy driver — FROZEN as the parity
    reference for ``run_off_policy``: one env step, one buffer add, and
    one jitted update dispatch per transition/gradient step."""
    rng = np.random.default_rng(seed)
    buf = buffer if buffer is not None else \
        ReplayBuffer(buffer_capacity, env.state_dim, env.n_providers,
                     seed=seed)
    history = []
    s = env.reset(split="train")
    total = 0
    for epoch in range(epochs):
        t0 = time.time()
        for _ in range(steps_per_epoch):
            if total < start_steps:
                a = rng.integers(0, 2, env.n_providers).astype(np.float32)
                if a.sum() == 0:
                    a[rng.integers(env.n_providers)] = 1.0
            else:
                a, _ = agent.select_action(s)
            s2, r, done, info = env.step(a)
            buf.add(s, a, r, s2, float(done))
            s = env.reset(split="train") if done else s2
            total += 1
            if total >= update_after and total % update_every == 0:
                for _ in range(update_iters):
                    agent.update(buf.sample(batch_size))
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "steps": total,
                    "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[{type(agent).__name__}] epoch {epoch}: "
                f"AP50={res['ap50']:.2f} mAP={res['map']:.2f} "
                f"cost={res['cost']:.3f} counts={res['counts']}")
    return history


def run_off_policy(agent, env: ArmolEnv, *, lanes: int = 1, epochs: int = 5,
                   steps_per_epoch: int = 500, batch_size: int = 256,
                   start_steps: int = 200, update_after: int = 300,
                   update_every: int = 50, update_iters: int = 50,
                   buffer_capacity: int = 100_000, seed: int = 0,
                   log: Optional[Callable[[str], None]] = print,
                   buffer: Optional[ReplayBuffer] = None,
                   obs=None) -> List[Dict]:
    """Multi-lane off-policy driver.

    ``lanes`` parallel episode cursors advance through
    ``ArmolEnv.step_lanes`` (one batched agent forward + one batched
    subset evaluation per tick), transitions land in the buffer via one
    ``add_batch`` write, and each ``update_iters`` block of gradient
    steps runs as a single jitted ``lax.scan`` (``agent.update_block``)
    over a pre-sampled index matrix.  ``steps_per_epoch`` counts
    transitions (rounded up to whole ticks), so the trained workload
    matches the sequential driver at any lane count.  With ``lanes=1``
    the transition stream and history are bit-identical to
    ``run_offpolicy_sequential``.

    Passing a ``DeviceReplayBuffer`` as ``buffer`` makes the hot path
    device-resident: replay writes are donated device scatters (with a
    feature table attached, state rows are assembled ON DEVICE from the
    image indices ``step_lanes`` reports), ``sample_block`` gathers into
    device arrays that ``update_block`` consumes directly, and the
    driver skips the per-block metric sync — no host materialization
    between collect and update.  In the buffer's ``index_mode="host"``
    the whole run stays bit-identical to the numpy-buffer path.
    """
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    # observability (repro.obs.Obs): tick latency, update_block
    # throughput and replay occupancy — reads clocks and copies values
    # only, so training results are bit-identical with obs on or off
    _obs_on = obs is not None and obs.enabled
    if _obs_on:
        _h_tick = obs.metrics.histogram("train.tick_ms")
        _h_blk = obs.metrics.histogram("train.update_block_ms")
        _g_occ = obs.metrics.gauge("train.replay_occupancy")
        _c_upd = obs.metrics.counter("train.update_iters")
    rng = np.random.default_rng(seed)
    buf = buffer if buffer is not None else \
        ReplayBuffer(buffer_capacity, env.state_dim, env.n_providers,
                     seed=seed)
    update_block = getattr(agent, "update_block", None)
    device_buf = bool(getattr(buf, "device_resident", False))
    indexed_writes = bool(getattr(buf, "indexed", False))
    select_many = _make_batch_select(agent, deterministic=False)
    n = env.n_providers
    history = []
    states = env.reset_lanes(lanes, split="train")
    total = 0
    for epoch in range(epochs):
        t0 = time.time()
        for _ in range(-(-steps_per_epoch // lanes)):
            _tick_t0 = time.monotonic() if _obs_on else 0.0
            explore = (total + np.arange(lanes)) < start_steps
            acts = np.zeros((lanes, n), np.float32)
            for lane in np.flatnonzero(explore):
                a = rng.integers(0, 2, n).astype(np.float32)
                if a.sum() == 0:
                    a[rng.integers(n)] = 1.0
                acts[lane] = a
            on_policy = np.flatnonzero(~explore)
            if len(on_policy) == lanes == 1:
                # keep the sequential (D,) act shape: matvec and matmul
                # round differently, and L=1 parity is bitwise
                acts[0] = np.asarray(agent.select_action(states[0])[0],
                                     np.float32)
            elif len(on_policy):
                acts[on_policy] = select_many(states[on_policy])
            nxt, r, dones, infos, carry = env.step_lanes(acts)
            d = dones.astype(np.float32)
            if indexed_writes and "next_image" in infos:
                # states == features[infos["image"]] and
                # nxt == features[infos["next_image"]] by step_lanes'
                # contract, so gathering those rows from the buffer's
                # device feature table is bitwise the same write — only
                # the index/reward vectors cross the host boundary
                buf.add_batch_indexed(infos["image"], acts, r,
                                      infos["next_image"], d)
            else:
                buf.add_batch(states, acts, r, nxt, d)
            states = carry
            prev, total = total, total + lanes
            for k in range(prev // update_every + 1,
                           total // update_every + 1):
                if k * update_every < update_after:
                    continue
                if len(buf) == 0:
                    raise ValueError(
                        "cannot sample from an empty replay buffer: an "
                        f"update is scheduled at step {k * update_every} "
                        "but no transitions have been stored "
                        f"(update_after={update_after})")
                _blk_t0 = time.monotonic() if _obs_on else 0.0
                if update_block is not None:
                    blk = buf.sample_block(update_iters, batch_size)
                    if device_buf:
                        # device buffers feed update_block device arrays;
                        # skipping the per-block metric sync keeps the
                        # collect->update chain free of host round trips
                        update_block(blk, sync=False)
                    else:
                        update_block(blk)
                else:
                    for _ in range(update_iters):
                        agent.update(buf.sample(batch_size))
                if _obs_on:
                    _c_upd.inc(update_iters)
                    _h_blk.observe((time.monotonic() - _blk_t0) * 1e3)
            if _obs_on:
                _g_occ.set(len(buf))
                _h_tick.observe((time.monotonic() - _tick_t0) * 1e3)
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "steps": total,
                    "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if _obs_on:
            obs.event("epoch", epoch=epoch, steps=total,
                      ap50=res["ap50"], cost=res["cost"],
                      wall_s=res["wall_s"])
        if log:
            log(f"[{type(agent).__name__}x{lanes}] epoch {epoch}: "
                f"AP50={res['ap50']:.2f} mAP={res['map']:.2f} "
                f"cost={res['cost']:.3f} counts={res['counts']}")
    return history


# ---------------------------------------------------------------------------
# On-policy drivers (PPO)
# ---------------------------------------------------------------------------

def run_ppo_sequential(agent: PPO, env: ArmolEnv, *, epochs: int = 5,
                       steps_per_epoch: int = 500, seed: int = 0,
                       log: Optional[Callable[[str], None]] = print
                       ) -> List[Dict]:
    """The seed's scalar PPO driver — FROZEN as the parity reference for
    ``run_ppo``."""
    history = []
    s = env.reset(split="train")
    for epoch in range(epochs):
        t0 = time.time()
        S, P, LP, R, D, V = [], [], [], [], [], []
        for _ in range(steps_per_epoch):
            a, proto, logp, v = agent.select_action(s)
            s2, r, done, info = env.step(a)
            S.append(s)
            P.append(proto)
            LP.append(logp)
            R.append(r)
            D.append(float(done))
            V.append(v)
            s = env.reset(split="train") if done else s2
        _, _, _, last_v = agent.select_action(s)
        adv, ret = agent.gae(np.asarray(R, np.float32),
                             np.asarray(V, np.float32),
                             np.asarray(D, np.float32), last_v)
        rollout = {"s": np.asarray(S, np.float32),
                   "proto": np.asarray(P, np.float32),
                   "logp": np.asarray(LP, np.float32),
                   "adv": adv, "ret": ret}
        agent.update_from_rollout(rollout)
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[PPO] epoch {epoch}: AP50={res['ap50']:.2f} "
                f"cost={res['cost']:.3f}")
    return history


def run_ppo(agent: PPO, env: ArmolEnv, *, lanes: int = 1, epochs: int = 5,
            steps_per_epoch: int = 500,
            log: Optional[Callable[[str], None]] = print) -> List[Dict]:
    """Multi-lane PPO driver: L lanes collected tick-by-tick through one
    batched act + one batched env evaluation, per-lane GAE against each
    lane's own done flags, and the whole rollout fused into one scanned
    update (``PPO.update_from_rollout``).  Rollout rows are flattened
    time-major, so ``lanes=1`` reproduces ``run_ppo_sequential``
    bit-for-bit.  Reproducibility is governed by the env and agent seeds
    (the driver itself draws no randomness)."""
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    n = env.n_providers
    history = []
    states = env.reset_lanes(lanes, split="train")
    for epoch in range(epochs):
        t0 = time.time()
        ticks = -(-steps_per_epoch // lanes)
        S = np.zeros((ticks, lanes, env.state_dim), np.float32)
        P = np.zeros((ticks, lanes, n), np.float32)
        LP = np.zeros((ticks, lanes), np.float32)
        R = np.zeros((ticks, lanes), np.float32)
        D = np.zeros((ticks, lanes), np.float32)
        V = np.zeros((ticks, lanes), np.float32)
        for t in range(ticks):
            S[t] = states
            if lanes == 1:
                a, P[t, 0], LP[t, 0], V[t, 0] = agent.select_action(
                    states[0])
                acts = a[None]
            else:
                acts, P[t], LP[t], V[t] = agent.select_action_batch(states)
            nxt, r, dones, infos, carry = env.step_lanes(acts)
            R[t] = r
            D[t] = dones
            states = carry
        if lanes == 1:
            last_v = np.asarray([agent.select_action(states[0])[3]],
                                np.float32)
        else:
            last_v = np.asarray(agent.select_action_batch(states)[3],
                                np.float32)
        adv = np.zeros((ticks, lanes), np.float32)
        ret = np.zeros((ticks, lanes), np.float32)
        for lane in range(lanes):
            adv[:, lane], ret[:, lane] = agent.gae(
                R[:, lane], V[:, lane], D[:, lane], float(last_v[lane]))
        rollout = {"s": S.reshape(ticks * lanes, -1),
                   "proto": P.reshape(ticks * lanes, -1),
                   "logp": LP.reshape(-1),
                   "adv": adv.reshape(-1), "ret": ret.reshape(-1)}
        agent.update_from_rollout(rollout)
        res = evaluate_policy(agent_policy(agent), env)
        res.update({"epoch": epoch, "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[PPOx{lanes}] epoch {epoch}: AP50={res['ap50']:.2f} "
                f"cost={res['cost']:.3f}")
    return history


# ---------------------------------------------------------------------------
# Baselines (Tab. II)
# ---------------------------------------------------------------------------

def random1_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = np.zeros(env.n_providers, np.float32)
        a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def randomN_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = rng.integers(0, 2, env.n_providers).astype(np.float32)
        if a.sum() == 0:
            a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def ensembleN_policy(env: ArmolEnv):
    def f(_s):
        return np.ones(env.n_providers, np.float32)
    return f


def enumeration_actions(n: int) -> List[np.ndarray]:
    """The Algo.-2 candidate list: all non-empty binary vectors, stable-
    sorted by popcount (ties keep itertools.product order, matching the
    seed's tie-breaking toward cheaper-first enumeration)."""
    actions = [np.asarray(a, np.float32)
               for a in itertools.product([0, 1], repeat=n) if any(a)]
    actions.sort(key=lambda a: (a.sum(),))
    return actions


def upper_bound(env: ArmolEnv) -> Dict:
    """Brute force (Algo. 2): per test image, the best action by per-image
    AP50; ties broken toward the cheaper subset (enumeration in increasing
    popcount order, strict improvement required).

    Enumerates through the full-lattice path: each image pays for its IoU
    table once, then ONE vectorized ``evaluate_lattice`` pass scores all
    2^N - 1 subsets — the first-occurrence argmax over the popcount-ordered
    AP rows reproduces the per-bitmask strict-improvement scan exactly,
    and the lattice rows back-fill the memo for later callers.  This is
    what makes the exact oracle reachable at N >= 10 rosters.
    """
    n = env.n_providers
    action_of = {m: mask_to_action(m, n) for m in popcount_masks(n)}
    env.core.precompute(env.test_idx)
    dts, gts = {}, {}
    counts = np.zeros(n, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        lat = env.core.evaluate_lattice(int(img), against="gt")
        best_m = int(lat.masks[int(np.argmax(lat.ap))])
        best_a = action_of[best_m]
        counts += (best_a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (best_a > 0.5)))
        dts[int(img)] = env.core.ensemble(int(img), best_m)
        gts[int(img)] = env.traces.gts[int(img)]
    m = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / m, "counts": counts.tolist(), "n_images": m}
