"""Training/evaluation loops for the federation agents + paper baselines.

Replicates the paper's protocol: off-policy agents (SAC/TD3) interact with
the trace env and update from the replay buffer; PPO collects on-policy
rollouts; at the end of every epoch the agent is evaluated deterministically
on the held-out test episode (corpus AP50 + average cost + per-provider
selection counts — the columns of Tab. II).  Baselines: Random-1, Random-N,
Ensemble-N, and the brute-force Upper Bound (Algo. 2).
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.ppo import PPO
from repro.core.replay_buffer import ReplayBuffer
from repro.ensemble.metrics import ap50, coco_map, image_ap50
from repro.federation.env import ArmolEnv


# ---------------------------------------------------------------------------
# Evaluation (one "test episode" = the whole test split)
# ---------------------------------------------------------------------------

def evaluate_policy(select_fn: Callable[[np.ndarray], np.ndarray],
                    env: ArmolEnv, *, against: str = "gt") -> Dict:
    """select_fn(state) -> binary action.  Corpus AP vs the TRUE ground truth
    (evaluation always uses GT even for w/o-gt-trained agents, as in the
    paper's Tab. II)."""
    dts, gts = {}, {}
    counts = np.zeros(env.n_providers, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        s = env.features[img]
        a = select_fn(s)
        counts += (a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (a > 0.5)))
        dts[int(img)] = env.ensemble_for(int(img), a)
        gts[int(img)] = env.traces.gts[int(img)]
    n = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / n,
            "counts": counts.tolist(), "n_images": n}


# ---------------------------------------------------------------------------
# Off-policy driver (SAC / TD3)
# ---------------------------------------------------------------------------

def run_off_policy(agent, env: ArmolEnv, *, epochs: int = 5,
                   steps_per_epoch: int = 500, batch_size: int = 256,
                   start_steps: int = 200, update_after: int = 300,
                   update_every: int = 50, update_iters: int = 50,
                   buffer_capacity: int = 100_000, seed: int = 0,
                   log: Optional[Callable[[str], None]] = print) -> List[Dict]:
    rng = np.random.default_rng(seed)
    buf = ReplayBuffer(buffer_capacity, env.state_dim, env.n_providers,
                       seed=seed)
    history = []
    s = env.reset(split="train")
    total = 0
    for epoch in range(epochs):
        t0 = time.time()
        for _ in range(steps_per_epoch):
            if total < start_steps:
                a = rng.integers(0, 2, env.n_providers).astype(np.float32)
                if a.sum() == 0:
                    a[rng.integers(env.n_providers)] = 1.0
            else:
                a, _ = agent.select_action(s)
            s2, r, done, info = env.step(a)
            buf.add(s, a, r, s2, float(done))
            s = env.reset(split="train") if done else s2
            total += 1
            if total >= update_after and total % update_every == 0:
                for _ in range(update_iters):
                    agent.update(buf.sample(batch_size))
        res = evaluate_policy(
            lambda st: agent.select_action(st, deterministic=True)[0], env)
        res.update({"epoch": epoch, "steps": total,
                    "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[{type(agent).__name__}] epoch {epoch}: "
                f"AP50={res['ap50']:.2f} mAP={res['map']:.2f} "
                f"cost={res['cost']:.3f} counts={res['counts']}")
    return history


# ---------------------------------------------------------------------------
# On-policy driver (PPO)
# ---------------------------------------------------------------------------

def run_ppo(agent: PPO, env: ArmolEnv, *, epochs: int = 5,
            steps_per_epoch: int = 500, seed: int = 0,
            log: Optional[Callable[[str], None]] = print) -> List[Dict]:
    history = []
    s = env.reset(split="train")
    for epoch in range(epochs):
        t0 = time.time()
        S, P, LP, R, D, V = [], [], [], [], [], []
        for _ in range(steps_per_epoch):
            a, proto, logp, v = agent.select_action(s)
            s2, r, done, info = env.step(a)
            S.append(s)
            P.append(proto)
            LP.append(logp)
            R.append(r)
            D.append(float(done))
            V.append(v)
            s = env.reset(split="train") if done else s2
        _, _, _, last_v = agent.select_action(s)
        adv, ret = agent.gae(np.asarray(R, np.float32),
                             np.asarray(V, np.float32),
                             np.asarray(D, np.float32), last_v)
        rollout = {"s": np.asarray(S, np.float32),
                   "proto": np.asarray(P, np.float32),
                   "logp": np.asarray(LP, np.float32),
                   "adv": adv, "ret": ret}
        agent.update_from_rollout(rollout)
        res = evaluate_policy(
            lambda st: agent.select_action(st, deterministic=True)[0], env)
        res.update({"epoch": epoch, "wall_s": round(time.time() - t0, 1)})
        history.append(res)
        if log:
            log(f"[PPO] epoch {epoch}: AP50={res['ap50']:.2f} "
                f"cost={res['cost']:.3f}")
    return history


# ---------------------------------------------------------------------------
# Baselines (Tab. II)
# ---------------------------------------------------------------------------

def random1_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = np.zeros(env.n_providers, np.float32)
        a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def randomN_policy(env: ArmolEnv, seed: int = 0):
    rng = np.random.default_rng(seed)

    def f(_s):
        a = rng.integers(0, 2, env.n_providers).astype(np.float32)
        if a.sum() == 0:
            a[rng.integers(env.n_providers)] = 1.0
        return a
    return f


def ensembleN_policy(env: ArmolEnv):
    def f(_s):
        return np.ones(env.n_providers, np.float32)
    return f


def upper_bound(env: ArmolEnv) -> Dict:
    """Brute force (Algo. 2): per test image, the best action by per-image
    AP50; ties broken toward the cheaper subset (enumeration in increasing
    popcount order, strict improvement required)."""
    n = env.n_providers
    actions = []
    for a in itertools.product([0, 1], repeat=n):
        if any(a):
            actions.append(np.asarray(a, np.float32))
    actions.sort(key=lambda a: (a.sum(),))
    dts, gts = {}, {}
    counts = np.zeros(n, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        best_v, best_a, best_d = -1.0, None, None
        gt = env.traces.gts[int(img)]
        for a in actions:
            d = env.ensemble_for(int(img), a)
            v = image_ap50(d, gt)
            if v > best_v:
                best_v, best_a, best_d = v, a, d
        counts += (best_a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (best_a > 0.5)))
        dts[int(img)] = best_d
        gts[int(img)] = gt
    m = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts), "map": 100.0 * coco_map(dts, gts),
            "cost": total_cost / m, "counts": counts.tolist(), "n_images": m}
