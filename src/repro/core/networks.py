"""MLP actor/critic networks (pure-pytree) and the state feature extractor.

The paper uses two-hidden-layer FCNs for the actor and both Q-networks, and
a pretrained MobileNet for image features.  The feature extractor here is a
fixed-seed depthwise-separable conv stack (same role: image -> feature
vector; no torch / no downloaded weights offline).
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

LOG_STD_MIN, LOG_STD_MAX = -20.0, 2.0


def _linear_init(key, fan_in, fan_out):
    k1, k2 = jax.random.split(key)
    lim = 1.0 / math.sqrt(fan_in)
    return {"w": jax.random.uniform(k1, (fan_in, fan_out), jnp.float32,
                                    -lim, lim),
            "b": jax.random.uniform(k2, (fan_out,), jnp.float32, -lim, lim)}


def init_mlp(key, sizes: Sequence[int]):
    keys = jax.random.split(key, len(sizes) - 1)
    return [_linear_init(k, sizes[i], sizes[i + 1])
            for i, k in enumerate(keys)]


def apply_mlp(params, x, *, final_act=None):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# Squashed-Gaussian actor (SAC): proto action in (0,1)^N
# ---------------------------------------------------------------------------

def init_actor(key, state_dim: int, n_providers: int, hidden=(256, 256)):
    return init_mlp(key, (state_dim, *hidden, 2 * n_providers))


def actor_dist(params, state):
    out = apply_mlp(params, state)
    mu, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, LOG_STD_MIN, LOG_STD_MAX)
    return mu, log_std


def sample_action(params, state, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reparameterised sample; returns (proto in (0,1)^N, log_prob)."""
    mu, log_std = actor_dist(params, state)
    std = jnp.exp(log_std)
    u = mu + std * jax.random.normal(key, mu.shape)
    t = jnp.tanh(u)
    proto = 0.5 * (t + 1.0)
    # N(u; mu, std) log-density
    logp = -0.5 * (((u - mu) / std) ** 2 + 2 * log_std
                   + jnp.log(2 * jnp.pi))
    # change of variables: proto = (tanh(u)+1)/2  =>  d proto/du = (1-t^2)/2
    logdet = jnp.log(jnp.maximum((1 - t ** 2) * 0.5, 1e-9))
    return proto, jnp.sum(logp - logdet, axis=-1)


def mean_action(params, state):
    mu, _ = actor_dist(params, state)
    return 0.5 * (jnp.tanh(mu) + 1.0)


# ---------------------------------------------------------------------------
# Deterministic actor (TD3)
# ---------------------------------------------------------------------------

def init_det_actor(key, state_dim: int, n_providers: int, hidden=(256, 256)):
    return init_mlp(key, (state_dim, *hidden, n_providers))


def det_action(params, state):
    return apply_mlp(params, state, final_act=jax.nn.sigmoid)


# ---------------------------------------------------------------------------
# Q and V critics
# ---------------------------------------------------------------------------

def init_q(key, state_dim: int, n_providers: int, hidden=(256, 256)):
    return init_mlp(key, (state_dim + n_providers, *hidden, 1))


def q_value(params, state, action):
    x = jnp.concatenate([state, action], axis=-1)
    return apply_mlp(params, x)[..., 0]


def init_v(key, state_dim: int, hidden=(256, 256)):
    return init_mlp(key, (state_dim, *hidden, 1))


def v_value(params, state):
    return apply_mlp(params, state)[..., 0]


# ---------------------------------------------------------------------------
# Feature extractor ("MobileNet" role): image (H,W,3) -> (feat_dim,)
# ---------------------------------------------------------------------------

def init_feature_extractor(key, *, channels=(8, 16, 32), feat_dim=64):
    params = []
    c_in = 3
    for i, c_out in enumerate(channels):
        k1, k2, key = jax.random.split(key, 3)
        params.append({
            "dw": jax.random.normal(k1, (3, 3, 1, c_in), jnp.float32)
            * (1.0 / 3.0),
            "pw": jax.random.normal(k2, (1, 1, c_in, c_out), jnp.float32)
            * (1.0 / math.sqrt(c_in)),
        })
        c_in = c_out
    k1, _ = jax.random.split(key)
    head = _linear_init(k1, c_in, feat_dim)
    return {"convs": params, "head": head}


def extract_features(params, img):
    """img: (H, W, 3) float32 in [0,1] -> (feat_dim,)."""
    x = img[None]                                     # NHWC
    for layer in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x, layer["dw"], window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1])
        x = jax.lax.conv_general_dilated(
            x, layer["pw"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x)
    feat = jnp.mean(x, axis=(1, 2))[0]                # global average pool
    h = feat @ params["head"]["w"] + params["head"]["b"]
    return jnp.tanh(h)
