"""PPO baseline (Armol-P): clipped-surrogate on-policy policy gradient.

Squashed-Gaussian actor over the proto-action hypercube + V critic, GAE
advantages, minibatched epochs over each collected rollout.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as nets
from repro.core.action_space import threshold_map
from repro.core.blocks import scan_update_block
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


@dataclass(frozen=True)
class PPOConfig:
    state_dim: int
    n_providers: int
    hidden: tuple = (256, 256)
    lr: float = 1e-4
    gamma: float = 0.9
    lam: float = 0.95
    clip: float = 0.2
    entropy_coef: float = 0.01
    update_epochs: int = 4
    minibatch: int = 256
    seed: int = 0


class PPOState(NamedTuple):
    actor: Any
    critic: Any
    opt_actor: AdamWState
    opt_critic: AdamWState
    key: jnp.ndarray


def _init_state(cfg: PPOConfig) -> PPOState:
    k = jax.random.PRNGKey(cfg.seed)
    ka, kc, kr = jax.random.split(k, 3)
    actor = nets.init_actor(ka, cfg.state_dim, cfg.n_providers, cfg.hidden)
    critic = nets.init_v(kc, cfg.state_dim, cfg.hidden)
    return PPOState(actor, critic, adamw_init(actor), adamw_init(critic), kr)


def _logp(actor, s, proto):
    """Log-density of a stored proto action under the current policy."""
    mu, log_std = nets.actor_dist(actor, s)
    std = jnp.exp(log_std)
    t = jnp.clip(2.0 * proto - 1.0, -1 + 1e-6, 1 - 1e-6)
    u = jnp.arctanh(t)
    logp = -0.5 * (((u - mu) / std) ** 2 + 2 * log_std + jnp.log(2 * jnp.pi))
    logdet = jnp.log(jnp.maximum((1 - t ** 2) * 0.5, 1e-9))
    return jnp.sum(logp - logdet, axis=-1)


@partial(jax.jit, static_argnums=0)
def _minibatch_update(cfg: PPOConfig, state: PPOState, mb):
    """One clipped-surrogate step.  ``mb`` may carry a 0/1 row-weight
    vector ``w`` (uniform-shape padding for the scanned update block);
    with all-ones weights every weighted mean reduces to the plain mean,
    so the masked path is numerically the seed path."""
    s, proto, logp_old, adv, ret = mb["s"], mb["proto"], mb["logp"], \
        mb["adv"], mb["ret"]
    w = mb["w"] if "w" in mb else jnp.ones_like(adv)
    wsum = jnp.sum(w)

    def wmean(x):
        return jnp.sum(x * w) / wsum
    mu_adv = wmean(adv)
    std_adv = jnp.sqrt(wmean((adv - mu_adv) ** 2))
    adv = (adv - mu_adv) / (std_adv + 1e-8)

    def pi_loss(ap):
        logp = _logp(ap, s, proto)
        ratio = jnp.exp(logp - logp_old)
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip)
        ent = -wmean(logp)
        return -wmean(jnp.minimum(ratio * adv, clipped * adv)) \
            - cfg.entropy_coef * ent
    pl, pg = jax.value_and_grad(pi_loss)(state.actor)
    actor, opt_actor = adamw_update(state.actor, pg, state.opt_actor,
                                    lr=cfg.lr)

    def v_loss(cp):
        return wmean((nets.v_value(cp, s) - ret) ** 2)
    vl, vg = jax.value_and_grad(v_loss)(state.critic)
    critic, opt_critic = adamw_update(state.critic, vg, state.opt_critic,
                                      lr=cfg.lr)
    return PPOState(actor, critic, opt_actor, opt_critic, state.key), \
        {"pi_loss": pl, "v_loss": vl}


# all minibatch steps of one rollout update fused in a lax.scan over
# stacked (K, mb, ...) arrays — one host->device transfer per rollout
# instead of one per minibatch; see repro.core.blocks
_update_rollout_block = scan_update_block(_minibatch_update)


@partial(jax.jit, static_argnums=0)
def _act(cfg: PPOConfig, state: PPOState, s, deterministic: bool):
    key, sub = jax.random.split(state.key)
    proto_s, logp = nets.sample_action(state.actor, s, sub)
    proto_d = nets.mean_action(state.actor, s)
    proto = jnp.where(deterministic, proto_d, proto_s)
    v = nets.v_value(state.critic, s)
    return threshold_map(proto), proto, logp, v, state._replace(key=key)


class PPO:
    def __init__(self, cfg: PPOConfig):
        self.cfg = cfg
        self.state = _init_state(cfg)

    def select_action(self, s: np.ndarray, *, deterministic=False):
        a, proto, logp, v, self.state = _act(self.cfg, self.state,
                                             jnp.asarray(s), deterministic)
        return np.asarray(a), np.asarray(proto), float(logp), float(v)

    def select_action_batch(self, s: np.ndarray, *, deterministic=False):
        """Batched act for the multi-lane driver: (L, D) states -> arrays
        (a (L, N), proto (L, N), logp (L,), v (L,)); one key split per
        call, like the scalar path."""
        a, proto, logp, v, self.state = _act(self.cfg, self.state,
                                             jnp.asarray(s), deterministic)
        return (np.asarray(a), np.asarray(proto), np.asarray(logp),
                np.asarray(v))

    def gae(self, rewards, values, dones, last_value):
        cfg = self.cfg
        T = len(rewards)
        adv = np.zeros(T, np.float32)
        lastgaelam = 0.0
        for t in reversed(range(T)):
            nonterminal = 1.0 - dones[t]
            nextv = last_value if t == T - 1 else values[t + 1]
            delta = rewards[t] + cfg.gamma * nextv * nonterminal - values[t]
            lastgaelam = delta + cfg.gamma * cfg.lam * nonterminal \
                * lastgaelam
            adv[t] = lastgaelam
        ret = adv + np.asarray(values, np.float32)
        return adv, ret

    def _minibatch_plan(self, n: int):
        """Host-side (K, mb) index matrix + 0/1 weights covering
        ``update_epochs`` shuffled passes; the short trailing slice of
        each pass is padded (weight 0) to keep shapes scan-uniform."""
        cfg = self.cfg
        mb = min(cfg.minibatch, n)
        rng = np.random.default_rng(0)
        idx_rows, w_rows = [], []
        for _ in range(cfg.update_epochs):
            perm = rng.permutation(n)
            for i in range(0, n, mb):
                sl = perm[i:i + mb]
                w = np.ones(mb, np.float32)
                if len(sl) < mb:
                    w[len(sl):] = 0.0
                    sl = np.concatenate(
                        [sl, np.zeros(mb - len(sl), sl.dtype)])
                idx_rows.append(sl)
                w_rows.append(w)
        return np.stack(idx_rows), np.stack(w_rows)

    def update_from_rollout(self, rollout: Dict[str, np.ndarray]):
        idx, w = self._minibatch_plan(len(rollout["s"]))
        # ship each (T, ...) rollout array once and gather the (K, mb, ...)
        # minibatch stack ON DEVICE — gathers are pure selection, so this
        # is bitwise the old host-side fancy-indexing, minus the K-fold
        # transfer blow-up
        jidx = jnp.asarray(idx)
        mbs = {k: jnp.asarray(np.asarray(v))[jidx]
               for k, v in rollout.items()}
        mbs["w"] = jnp.asarray(w)
        self.state, metrics = _update_rollout_block(self.cfg, self.state,
                                                    mbs)
        return {k: float(np.asarray(v)[-1]) for k, v in metrics.items()}

    def update_minibatch(self, mb: Dict[str, np.ndarray]):
        """One eager minibatch step (reference path for the scan-parity
        regression tests)."""
        jb = {k: jnp.asarray(v) for k, v in mb.items()}
        self.state, metrics = _minibatch_update(self.cfg, self.state, jb)
        return {k: float(v) for k, v in metrics.items()}

    def update_minibatches(self, mbs: Dict[str, np.ndarray]):
        """Fused scan over pre-stacked (K, mb, ...) minibatches."""
        jb = {k: jnp.asarray(v) for k, v in mbs.items()}
        self.state, metrics = _update_rollout_block(self.cfg, self.state,
                                                    jb)
        return {k: float(np.asarray(v)[-1]) for k, v in metrics.items()}
