"""Circular replay buffer (host-side numpy; batches feed jitted updates)."""
from __future__ import annotations

from typing import Dict

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.state = np.zeros((capacity, state_dim), np.float32)
        self.action = np.zeros((capacity, action_dim), np.float32)
        self.reward = np.zeros((capacity,), np.float32)
        self.next_state = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def add(self, s, a, r, s2, d) -> None:
        i = self.ptr
        self.state[i] = s
        self.action[i] = a
        self.reward[i] = r
        self.next_state[i] = s2
        self.done[i] = d
        self.ptr = (i + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, batch: int) -> Dict[str, np.ndarray]:
        idx = self.rng.integers(0, self.size, size=batch)
        return {"s": self.state[idx], "a": self.action[idx],
                "r": self.reward[idx], "s2": self.next_state[idx],
                "d": self.done[idx]}

    def __len__(self) -> int:
        return self.size
