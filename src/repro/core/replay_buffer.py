"""Circular replay buffer (host-side numpy; batches feed jitted updates).

``add_batch`` writes a whole lane-batch of transitions in one vectorized
circular write (wraparound included) and ``sample_block`` draws the index
matrix for a fused block of gradient steps in one rng call — both are
bit-equivalent to loops of the scalar ``add`` / ``sample`` calls, which the
multi-lane training drivers rely on for L=1 parity with the sequential
reference drivers.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.state = np.zeros((capacity, state_dim), np.float32)
        self.action = np.zeros((capacity, action_dim), np.float32)
        self.reward = np.zeros((capacity,), np.float32)
        self.next_state = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0
        self.rng = np.random.default_rng(seed)

    def add(self, s, a, r, s2, d) -> None:
        i = self.ptr
        self.state[i] = s
        self.action[i] = a
        self.reward[i] = r
        self.next_state[i] = s2
        self.done[i] = d
        self.ptr = (i + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def add_batch(self, s, a, r, s2, d) -> None:
        """Vectorized circular write of B transitions; matches B scalar
        ``add`` calls exactly, including wraparound and the B > capacity
        case (only the last ``capacity`` rows survive)."""
        s = np.asarray(s, np.float32).reshape(-1, self.state.shape[1])
        a = np.asarray(a, np.float32).reshape(-1, self.action.shape[1])
        r = np.asarray(r, np.float32).reshape(-1)
        s2 = np.asarray(s2, np.float32).reshape(-1, self.state.shape[1])
        d = np.asarray(d, np.float32).reshape(-1)
        B = len(r)
        if B == 0:
            return
        skip = max(0, B - self.capacity)     # rows a scalar loop overwrites
        idx = (self.ptr + skip + np.arange(B - skip)) % self.capacity
        self.state[idx] = s[skip:]
        self.action[idx] = a[skip:]
        self.reward[idx] = r[skip:]
        self.next_state[idx] = s2[skip:]
        self.done[idx] = d[skip:]
        self.ptr = (self.ptr + B) % self.capacity
        self.size = min(self.size + B, self.capacity)

    def sample(self, batch: int) -> Dict[str, np.ndarray]:
        if self.size == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        idx = self.rng.integers(0, self.size, size=batch)
        return {"s": self.state[idx], "a": self.action[idx],
                "r": self.reward[idx], "s2": self.next_state[idx],
                "d": self.done[idx]}

    def sample_block(self, iters: int, batch: int) -> Dict[str, np.ndarray]:
        """Pre-sample ``iters`` update batches in one draw: dict of
        (iters, batch, ...) arrays.  The (iters, batch) index matrix comes
        from a single ``rng.integers`` call, which consumes the generator
        stream identically to ``iters`` successive ``sample`` calls."""
        if self.size == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        idx = self.rng.integers(0, self.size, size=(iters, batch))
        return {"s": self.state[idx], "a": self.action[idx],
                "r": self.reward[idx], "s2": self.next_state[idx],
                "d": self.done[idx]}

    def __len__(self) -> int:
        return self.size
