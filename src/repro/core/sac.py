"""Soft Actor-Critic for combinatorial MLaaS provider selection (Algo. 1).

Faithful to the paper's setup: twin soft-Q networks + squashed-Gaussian
actor, fixed entropy weight alpha=0.2, gamma=0.9, lr=1e-4, Polyak-averaged
target Q networks, no separate value function (Sec. IV-B).  The critic takes
the *binary* executed action from the replay buffer (Eq. 8); the actor
update back-propagates through the continuous proto action (Eq. 9).
Everything is jitted; the agent object just holds state.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as nets
from repro.core.action_space import threshold_map
from repro.core.blocks import scan_update_block
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


@dataclass(frozen=True)
class SACConfig:
    state_dim: int
    n_providers: int
    hidden: tuple = (256, 256)
    lr: float = 1e-4
    gamma: float = 0.9
    alpha: float = 0.2
    polyak: float = 0.995
    seed: int = 0
    # beyond-paper: Wolpertinger-style critic re-ranking over the k nearest
    # codebook actions instead of plain tau (0 = paper-faithful threshold)
    wolpertinger_k: int = 0


class SACState(NamedTuple):
    actor: Any
    q1: Any
    q2: Any
    q1_targ: Any
    q2_targ: Any
    opt_actor: AdamWState
    opt_q1: AdamWState
    opt_q2: AdamWState
    key: jnp.ndarray


def _init_state(cfg: SACConfig) -> SACState:
    k = jax.random.PRNGKey(cfg.seed)
    ka, k1, k2, kr = jax.random.split(k, 4)
    actor = nets.init_actor(ka, cfg.state_dim, cfg.n_providers, cfg.hidden)
    q1 = nets.init_q(k1, cfg.state_dim, cfg.n_providers, cfg.hidden)
    q2 = nets.init_q(k2, cfg.state_dim, cfg.n_providers, cfg.hidden)
    return SACState(actor, q1, q2,
                    jax.tree.map(jnp.copy, q1), jax.tree.map(jnp.copy, q2),
                    adamw_init(actor), adamw_init(q1), adamw_init(q2), kr)


@partial(jax.jit, static_argnums=0)
def _update(cfg: SACConfig, state: SACState, batch) -> tuple:
    key, k1, k2 = jax.random.split(state.key, 3)
    s, a, r, s2, d = batch["s"], batch["a"], batch["r"], batch["s2"], \
        batch["d"]

    # --- target (Eq. 6): a'~pi(.|s'), min of target Qs, entropy bonus
    a2, logp2 = nets.sample_action(state.actor, s2, k1)
    q1t = nets.q_value(state.q1_targ, s2, a2)
    q2t = nets.q_value(state.q2_targ, s2, a2)
    y = r + cfg.gamma * (1.0 - d) * (jnp.minimum(q1t, q2t)
                                     - cfg.alpha * logp2)
    y = jax.lax.stop_gradient(y)

    # --- critic updates (Eq. 8)
    def q_loss(qp):
        q = nets.q_value(qp, s, a)
        return jnp.mean((q - y) ** 2)
    l1, grads1 = jax.value_and_grad(q_loss)(state.q1)
    l2, grads2 = jax.value_and_grad(q_loss)(state.q2)
    q1, opt_q1 = adamw_update(state.q1, grads1, state.opt_q1, lr=cfg.lr)
    q2, opt_q2 = adamw_update(state.q2, grads2, state.opt_q2, lr=cfg.lr)

    # --- actor update (Eq. 9)
    def pi_loss(ap):
        at, logp = nets.sample_action(ap, s, k2)
        q = jnp.minimum(nets.q_value(q1, s, at), nets.q_value(q2, s, at))
        return jnp.mean(cfg.alpha * logp - q)
    gl, pl = jax.value_and_grad(pi_loss)(state.actor)
    actor, opt_actor = adamw_update(state.actor, pl, state.opt_actor,
                                    lr=cfg.lr)

    # --- Polyak target update (Eq. 10)
    rho = cfg.polyak
    q1_targ = jax.tree.map(lambda t, n: rho * t + (1 - rho) * n,
                           state.q1_targ, q1)
    q2_targ = jax.tree.map(lambda t, n: rho * t + (1 - rho) * n,
                           state.q2_targ, q2)
    new = SACState(actor, q1, q2, q1_targ, q2_targ, opt_actor, opt_q1,
                   opt_q2, key)
    metrics = {"q1_loss": l1, "q2_loss": l2, "pi_loss": gl,
               "q_mean": jnp.mean(nets.q_value(q1, s, a))}
    return new, metrics


# fused block of K gradient steps; see repro.core.blocks
_update_block = scan_update_block(_update)


@partial(jax.jit, static_argnums=0)
def _act(cfg: SACConfig, state: SACState, s, deterministic: bool):
    key, sub = jax.random.split(state.key)
    proto_s, _ = nets.sample_action(state.actor, s, sub)
    proto_d = nets.mean_action(state.actor, s)
    proto = jnp.where(deterministic, proto_d, proto_s)
    if cfg.wolpertinger_k:
        def q_fn(st, actions):
            sr = jnp.broadcast_to(st, (actions.shape[0], st.shape[-1]))
            return jnp.minimum(nets.q_value(state.q1, sr, actions),
                               nets.q_value(state.q2, sr, actions))
        from repro.core.action_space import wolpertinger_select
        a = wolpertinger_select(proto, s, q_fn, k=cfg.wolpertinger_k)
        return a, proto, state._replace(key=key)
    return threshold_map(proto), proto, state._replace(key=key)


class SAC:
    """Stateful wrapper: select_action / update / checkpointable state."""

    def __init__(self, cfg: SACConfig):
        self.cfg = cfg
        self.state = _init_state(cfg)

    def select_action(self, s: np.ndarray, *, deterministic=False):
        a, proto, self.state = _act(self.cfg, self.state, jnp.asarray(s),
                                    deterministic)
        return np.asarray(a), np.asarray(proto)

    def update(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        self.state, metrics = _update(self.cfg, self.state, jb)
        return {k: float(v) for k, v in metrics.items()}

    def update_block(self, batches: Dict[str, np.ndarray], *,
                     sync: bool = True) -> Dict[str, Any]:
        """K fused gradient steps from pre-sampled (K, B, ...) batches
        (``ReplayBuffer.sample_block``); returns the last step's metrics,
        matching what an eager K-iteration loop would report.

        ``jnp.asarray`` is a no-op on device arrays, so batches from a
        ``DeviceReplayBuffer`` feed the scan zero-copy; ``sync=False``
        returns the raw (K,) per-step metric traces as device arrays —
        no host sync and no extra op dispatches (the device-resident
        driver discards them; index ``[-1]`` lazily if you need the
        last step)."""
        jb = {k: jnp.asarray(v) for k, v in batches.items()}
        self.state, metrics = _update_block(self.cfg, self.state, jb)
        if not sync:
            return dict(metrics)
        return {k: float(np.asarray(v)[-1]) for k, v in metrics.items()}
