"""TD3 baseline (Armol-T): twin delayed deterministic policy gradient.

Deterministic sigmoid actor over the proto-action hypercube, target policy
smoothing, twin critics, delayed actor/target updates (Fujimoto et al.).
Exploration adds Gaussian noise to the proto action before tau.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as nets
from repro.core.action_space import threshold_map
from repro.core.blocks import scan_update_block
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


@dataclass(frozen=True)
class TD3Config:
    state_dim: int
    n_providers: int
    hidden: tuple = (256, 256)
    lr: float = 1e-4
    gamma: float = 0.9
    polyak: float = 0.995
    act_noise: float = 0.1
    target_noise: float = 0.2
    noise_clip: float = 0.5
    policy_delay: int = 2
    seed: int = 0


class TD3State(NamedTuple):
    actor: Any
    actor_targ: Any
    q1: Any
    q2: Any
    q1_targ: Any
    q2_targ: Any
    opt_actor: AdamWState
    opt_q1: AdamWState
    opt_q2: AdamWState
    step: jnp.ndarray
    key: jnp.ndarray


def _init_state(cfg: TD3Config) -> TD3State:
    k = jax.random.PRNGKey(cfg.seed)
    ka, k1, k2, kr = jax.random.split(k, 4)
    actor = nets.init_det_actor(ka, cfg.state_dim, cfg.n_providers,
                                cfg.hidden)
    q1 = nets.init_q(k1, cfg.state_dim, cfg.n_providers, cfg.hidden)
    q2 = nets.init_q(k2, cfg.state_dim, cfg.n_providers, cfg.hidden)
    cp = lambda t: jax.tree.map(jnp.copy, t)  # noqa: E731
    return TD3State(actor, cp(actor), q1, q2, cp(q1), cp(q2),
                    adamw_init(actor), adamw_init(q1), adamw_init(q2),
                    jnp.zeros((), jnp.int32), kr)


@partial(jax.jit, static_argnums=0)
def _update(cfg: TD3Config, state: TD3State, batch):
    key, kn = jax.random.split(state.key)
    s, a, r, s2, d = batch["s"], batch["a"], batch["r"], batch["s2"], \
        batch["d"]

    # target action with clipped smoothing noise, clipped to [0,1]
    noise = jnp.clip(cfg.target_noise * jax.random.normal(kn, a.shape),
                     -cfg.noise_clip, cfg.noise_clip)
    a2 = jnp.clip(nets.det_action(state.actor_targ, s2) + noise, 0.0, 1.0)
    q1t = nets.q_value(state.q1_targ, s2, a2)
    q2t = nets.q_value(state.q2_targ, s2, a2)
    y = jax.lax.stop_gradient(r + cfg.gamma * (1 - d)
                              * jnp.minimum(q1t, q2t))

    def q_loss(qp):
        return jnp.mean((nets.q_value(qp, s, a) - y) ** 2)
    l1, g1 = jax.value_and_grad(q_loss)(state.q1)
    l2, g2 = jax.value_and_grad(q_loss)(state.q2)
    q1, opt_q1 = adamw_update(state.q1, g1, state.opt_q1, lr=cfg.lr)
    q2, opt_q2 = adamw_update(state.q2, g2, state.opt_q2, lr=cfg.lr)

    def pi_loss(ap):
        return -jnp.mean(nets.q_value(q1, s, nets.det_action(ap, s)))
    pl, pg = jax.value_and_grad(pi_loss)(state.actor)

    do_pi = (state.step % cfg.policy_delay) == 0
    actor_new, opt_actor_new = adamw_update(state.actor, pg,
                                            state.opt_actor, lr=cfg.lr)
    pick = lambda n, o: jax.tree.map(  # noqa: E731
        lambda x, yv: jnp.where(do_pi, x, yv), n, o)
    actor = pick(actor_new, state.actor)
    opt_actor = jax.tree.map(lambda x, yv: jnp.where(do_pi, x, yv),
                             opt_actor_new, state.opt_actor)
    rho = cfg.polyak
    pol = lambda t, n: jax.tree.map(  # noqa: E731
        lambda tv, nv: jnp.where(do_pi, rho * tv + (1 - rho) * nv, tv), t, n)
    new = TD3State(actor, pol(state.actor_targ, actor), q1, q2,
                   pol(state.q1_targ, q1), pol(state.q2_targ, q2),
                   opt_actor, opt_q1, opt_q2, state.step + 1, key)
    return new, {"q1_loss": l1, "q2_loss": l2, "pi_loss": pl}


# fused block of K gradient steps (the delayed-policy counter rides
# along in the scanned carry); see repro.core.blocks
_update_block = scan_update_block(_update)


@partial(jax.jit, static_argnums=0)
def _act(cfg: TD3Config, state: TD3State, s, deterministic: bool):
    key, kn = jax.random.split(state.key)
    proto = nets.det_action(state.actor, s)
    noise = cfg.act_noise * jax.random.normal(kn, proto.shape)
    proto = jnp.where(deterministic, proto,
                      jnp.clip(proto + noise, 0.0, 1.0))
    return threshold_map(proto), proto, state._replace(key=key)


class TD3:
    def __init__(self, cfg: TD3Config):
        self.cfg = cfg
        self.state = _init_state(cfg)

    def select_action(self, s: np.ndarray, *, deterministic=False):
        a, proto, self.state = _act(self.cfg, self.state, jnp.asarray(s),
                                    deterministic)
        return np.asarray(a), np.asarray(proto)

    def update(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        self.state, metrics = _update(self.cfg, self.state, jb)
        return {k: float(v) for k, v in metrics.items()}

    def update_block(self, batches: Dict[str, np.ndarray], *,
                     sync: bool = True) -> Dict[str, Any]:
        """K fused gradient steps from pre-sampled (K, B, ...) batches;
        ``sync=False`` returns the raw (K,) per-step metric traces as
        device arrays — no host sync, no extra op dispatches (the
        device-resident driver path)."""
        jb = {k: jnp.asarray(v) for k, v in batches.items()}
        self.state, metrics = _update_block(self.cfg, self.state, jb)
        if not sync:
            return dict(metrics)
        return {k: float(np.asarray(v)[-1]) for k, v in metrics.items()}
