from repro.data.pipeline import synthetic_lm_batches, batch_for  # noqa: F401
