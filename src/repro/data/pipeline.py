"""Deterministic synthetic data pipeline.

Produces LM token batches (and the stub modality inputs for vlm/audio archs)
with a seeded generator.  ``batch_for`` builds one concrete batch matching an
(arch, shape) pair — the runnable twin of ``launch.specs.input_specs``.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def synthetic_lm_batches(cfg: ArchConfig, batch: int, seq: int, *,
                         seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Endless stream of (tokens, labels) with a learnable bigram structure."""
    rng = _rng(seed)
    V = cfg.vocab_size
    # fixed random bigram table => the loss is actually reducible
    trans = rng.integers(0, V, size=(min(V, 4096),), dtype=np.int64)
    step = 0
    while True:
        toks = np.empty((batch, seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, V, size=(batch,))
        noise = rng.random((batch, seq)) < 0.15
        rnd = rng.integers(0, V, size=(batch, seq))
        for t in range(seq):
            nxt = trans[toks[:, t] % len(trans)]
            toks[:, t + 1] = np.where(noise[:, t], rnd[:, t], nxt)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
        _add_modalities(out, cfg, batch, rng)
        step += 1
        yield out


def _add_modalities(out, cfg: ArchConfig, batch: int, rng):
    if cfg.family == "vlm":
        out["image_embeds"] = rng.standard_normal(
            (batch, cfg.num_image_tokens, cfg.d_vision)).astype(np.float32)
    if cfg.family == "audio":
        out["audio_frames"] = rng.standard_normal(
            (batch, cfg.num_audio_frames, cfg.d_model)).astype(np.float32)


def batch_for(cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0,
              override_batch: int = 0, override_seq: int = 0):
    """One concrete batch for (arch, shape) — used by smoke tests/examples."""
    B = override_batch or shape.global_batch
    S = override_seq or shape.seq_len
    gen = synthetic_lm_batches(cfg, B, S, seed=seed)
    return next(gen)
