from repro.ensemble.boxes import Detections, iou_matrix  # noqa: F401
from repro.ensemble.voting import group_detections, vote_filter  # noqa: F401
from repro.ensemble.ablation import nms, soft_nms, wbf  # noqa: F401
from repro.ensemble.pipeline import (ensemble_detections,  # noqa: F401
                                     ensemble_detections_batch,
                                     ensemble_from_arrays, PATHWAYS)
from repro.ensemble.metrics import (average_precision, ap50, coco_map,  # noqa: F401
                                    image_ap50)
