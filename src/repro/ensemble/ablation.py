"""Ablation stage: NMS, Soft-NMS, and Weighted Boxes Fusion (paper Fig. 5).

NMS keeps the top-scoring box of each overlap cluster; Soft-NMS decays
scores by overlap instead of deleting; WBF fuses each *group* into one box
whose coordinates are the confidence-weighted average of the members and
whose score is the members' mean — the paper picks WBF because the three
cloud providers return scattered boxes around the same object.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix

# popcount lookup for distinct-provider counting (provider ids < 11 cover
# the paper's regimes; larger pools fall back to np.unique)
_POPCNT = np.asarray([bin(i).count("1") for i in range(2048)], np.int64)


def nms(dets: Detections, *, iou_thr: float = 0.5) -> Detections:
    n = len(dets)
    if n == 0:
        return dets
    order = np.argsort(-dets.scores, kind="stable")
    iou = iou_matrix(dets.boxes, dets.boxes)
    keep = []
    suppressed = np.zeros(n, bool)
    for i in order:
        if suppressed[i]:
            continue
        keep.append(i)
        same = dets.labels == dets.labels[i]
        suppressed |= same & (iou[i] > iou_thr)
    return dets.take(np.asarray(keep, np.int64))


def soft_nms(dets: Detections, *, sigma: float = 0.5,
             score_thr: float = 0.001) -> Detections:
    """Gaussian Soft-NMS (Bodla et al. 2017)."""
    n = len(dets)
    if n == 0:
        return dets
    boxes = dets.boxes.copy()
    scores = dets.scores.copy()
    labels = dets.labels.copy()
    provs = (dets.providers.copy() if dets.providers is not None
             else np.zeros(n, np.int32))
    out_idx = []
    out_scores = []
    alive = np.ones(n, bool)
    while alive.any():
        i = int(np.argmax(np.where(alive, scores, -1.0)))
        if scores[i] < score_thr:
            break
        out_idx.append(i)
        out_scores.append(scores[i])
        alive[i] = False
        ious = iou_matrix(boxes[i:i + 1], boxes)[0]
        decay = np.exp(-(ious ** 2) / sigma)
        mask = alive & (labels == labels[i])
        scores[mask] = scores[mask] * decay[mask]
    idx = np.asarray(out_idx, np.int64)
    d = Detections(boxes[idx], np.asarray(out_scores, np.float32),
                   labels[idx], provs[idx])
    return d


def wbf(dets: Detections, groups: List[np.ndarray], *,
        n_models: int = 0) -> Detections:
    """Weighted Boxes Fusion over pre-computed groups (Solovyev et al.).

    Fused box = confidence-weighted average of member boxes; fused score =
    mean member score, rescaled by min(T, N)/N when ``n_models`` (= number
    of federated providers) is given — the WBF paper's correction that
    down-weights boxes confirmed by fewer models.  Within a single image
    the rescale preserves per-provider ranking, but corpus-wide it pushes
    single-provider strays below multi-provider consensus boxes.

    Vectorized over groups with segment reductions — this is the per-subset
    hot loop of the federation reward path, called once per (image, action).
    """
    if not groups:
        return Detections.empty()
    sizes = np.asarray([len(g) for g in groups], np.int64)
    flat = np.concatenate(groups)
    starts = np.concatenate([[0], np.cumsum(sizes[:-1])])
    gid = np.repeat(np.arange(len(groups)), sizes)
    s = dets.scores[flat]                               # (F,) float32
    gsum = np.add.reduceat(s, starts)                   # (G,) per-group sums
    denom = np.maximum(gsum.astype(np.float64), 1e-12).astype(np.float32)
    w = s / denom[gid]
    fused = np.add.reduceat(dets.boxes[flat] * w[:, None], starts, axis=0)
    sc = (gsum / sizes.astype(np.float32)).astype(np.float64)
    if n_models > 1:
        if dets.providers is not None:
            provs_flat = dets.providers[flat].astype(np.int64)
            if len(provs_flat) == 0 or int(provs_flat.max()) < 11:
                ormask = np.bitwise_or.reduceat(
                    np.left_shift(1, provs_flat), starts)
                t = _POPCNT[ormask]
            else:
                stride = int(provs_flat.max()) + 2
                t = np.bincount(
                    np.unique(gid * stride + provs_flat) // stride,
                    minlength=len(groups))
        else:
            t = sizes
        sc = sc * (np.minimum(t, n_models) / n_models)
    first = flat[starts]
    provs = (dets.providers[first] if dets.providers is not None
             else np.zeros(len(groups), np.int32))
    return Detections.fast(fused.astype(np.float32),
                           sc.astype(np.float32),
                           dets.labels[first].astype(np.int32), provs)
