"""Box utilities and the Detections container.

Boxes are (n, 4) float32 ``[x1, y1, x2, y2]`` in [0,1] image coordinates.
The hot pairwise-IoU computation has a Pallas TPU kernel twin in
``repro.kernels.iou_matrix`` (this numpy version doubles as its oracle's
reference semantics).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Detections:
    boxes: np.ndarray                     # (n, 4) float32
    scores: np.ndarray                    # (n,) float32
    labels: np.ndarray                    # (n,) int32 canonical group ids
    providers: Optional[np.ndarray] = None  # (n,) int32, filled by ensemble

    def __post_init__(self):
        self.boxes = np.asarray(self.boxes, np.float32).reshape(-1, 4)
        self.scores = np.asarray(self.scores, np.float32).reshape(-1)
        self.labels = np.asarray(self.labels, np.int32).reshape(-1)
        if self.providers is not None:
            self.providers = np.asarray(self.providers, np.int32).reshape(-1)

    def __len__(self) -> int:
        return len(self.scores)

    @staticmethod
    def fast(boxes: np.ndarray, scores: np.ndarray, labels: np.ndarray,
             providers: Optional[np.ndarray] = None) -> "Detections":
        """No-validation constructor for hot paths: arrays must already be
        float32 (n,4) / float32 (n,) / int32 (n,) [/ int32 (n,)]."""
        d = object.__new__(Detections)
        d.boxes, d.scores, d.labels, d.providers = boxes, scores, labels, \
            providers
        return d

    @staticmethod
    def empty() -> "Detections":
        return Detections(np.zeros((0, 4), np.float32),
                          np.zeros((0,), np.float32),
                          np.zeros((0,), np.int32),
                          np.zeros((0,), np.int32))

    @staticmethod
    def concat(dets: list) -> "Detections":
        if not dets:
            return Detections.empty()
        provs = [d.providers if d.providers is not None
                 else np.zeros(len(d), np.int32) for d in dets]
        return Detections(np.concatenate([d.boxes for d in dets], axis=0),
                          np.concatenate([d.scores for d in dets]),
                          np.concatenate([d.labels for d in dets]),
                          np.concatenate(provs))

    def take(self, idx) -> "Detections":
        return Detections(self.boxes[idx], self.scores[idx],
                          self.labels[idx],
                          None if self.providers is None
                          else self.providers[idx])


def box_area(boxes: np.ndarray) -> np.ndarray:
    w = np.maximum(0.0, boxes[:, 2] - boxes[:, 0])
    h = np.maximum(0.0, boxes[:, 3] - boxes[:, 1])
    return w * h


def iou_matrix(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise IoU, (m, 4) x (n, 4) -> (m, n)."""
    a = np.asarray(a, np.float32).reshape(-1, 4)
    b = np.asarray(b, np.float32).reshape(-1, 4)
    x1 = np.maximum(a[:, None, 0], b[None, :, 0])
    y1 = np.maximum(a[:, None, 1], b[None, :, 1])
    x2 = np.minimum(a[:, None, 2], b[None, :, 2])
    y2 = np.minimum(a[:, None, 3], b[None, :, 3])
    inter = np.maximum(0.0, x2 - x1) * np.maximum(0.0, y2 - y1)
    union = box_area(a)[:, None] + box_area(b)[None, :] - inter
    return np.where(union > 0, inter / np.maximum(union, 1e-12), 0.0)
