"""COCO-style average precision (101-point interpolation).

``average_precision`` evaluates a corpus {image_id: Detections} against
{image_id: ground truth Detections} at one IoU threshold, per category,
and averages.  ``coco_map`` averages AP over IoU .50:.05:.95.  The paper
trains on per-image AP50 rewards and reports corpus AP50/mAP.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix

RECALL_POINTS = np.linspace(0.0, 1.0, 101)


def _match_image(dt: Detections, gt: Detections, label: int,
                 iou_thr: float):
    """Greedy matching for one image+class: returns (scores, tp_flags, n_gt)."""
    di = np.where(dt.labels == label)[0]
    gi = np.where(gt.labels == label)[0]
    if len(di) == 0:
        return np.zeros(0), np.zeros(0, bool), len(gi)
    order = di[np.argsort(-dt.scores[di], kind="stable")]
    tp = np.zeros(len(order), bool)
    if len(gi):
        iou = iou_matrix(dt.boxes[order], gt.boxes[gi])
        taken = np.zeros(len(gi), bool)
        for r in range(len(order)):
            best, bj = iou_thr, -1
            for c in range(len(gi)):
                if not taken[c] and iou[r, c] >= best:
                    best, bj = iou[r, c], c
            if bj >= 0:
                taken[bj] = True
                tp[r] = True
    return dt.scores[order], tp, len(gi)


def average_precision(dts: Dict[int, Detections], gts: Dict[int, Detections],
                      *, iou_thr: float = 0.5,
                      labels: Optional[Iterable[int]] = None) -> float:
    """Mean AP over categories present in the ground truth."""
    if labels is None:
        labs = set()
        for g in gts.values():
            labs.update(np.unique(g.labels).tolist())
        labels = sorted(labs)
    aps = []
    for lab in labels:
        scores, tps, n_gt = [], [], 0
        for img, gt in gts.items():
            dt = dts.get(img, Detections.empty())
            s, t, n = _match_image(dt, gt, lab, iou_thr)
            scores.append(s)
            tps.append(t)
            n_gt += n
        if n_gt == 0:
            continue
        scores = np.concatenate(scores)
        tps = np.concatenate(tps)
        order = np.argsort(-scores, kind="stable")
        tps = tps[order]
        tp_cum = np.cumsum(tps)
        fp_cum = np.cumsum(~tps)
        recall = tp_cum / n_gt
        precision = tp_cum / np.maximum(tp_cum + fp_cum, 1e-12)
        # monotone precision envelope + 101-pt interpolation
        for i in range(len(precision) - 2, -1, -1):
            precision[i] = max(precision[i], precision[i + 1])
        ap = 0.0
        for r in RECALL_POINTS:
            idx = np.searchsorted(recall, r, side="left")
            ap += precision[idx] if idx < len(precision) else 0.0
        aps.append(ap / len(RECALL_POINTS))
    return float(np.mean(aps)) if aps else 0.0


def ap50(dts, gts, **kw) -> float:
    return average_precision(dts, gts, iou_thr=0.5, **kw)


def coco_map(dts, gts, **kw) -> float:
    thrs = np.arange(0.5, 0.96, 0.05)
    return float(np.mean([average_precision(dts, gts, iou_thr=t, **kw)
                          for t in thrs]))


def image_ap50(dt: Detections, gt: Detections) -> float:
    """Per-image AP50 — the paper's reward signal v_t."""
    return average_precision({0: dt}, {0: gt}, iou_thr=0.5)
