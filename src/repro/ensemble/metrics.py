"""COCO-style average precision (101-point interpolation).

``average_precision`` evaluates a corpus {image_id: Detections} against
{image_id: ground truth Detections} at one IoU threshold, per category,
and averages.  ``coco_map`` averages AP over IoU .50:.05:.95.  The paper
trains on per-image AP50 rewards and reports corpus AP50/mAP.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix

RECALL_POINTS = np.linspace(0.0, 1.0, 101)


def _seq_mean(vals) -> float:
    """Sequential-order mean (deterministic summation order shared by the
    corpus and per-image AP paths so they stay bit-identical)."""
    s = 0.0
    for v in vals:
        s += v
    return float(s / len(vals))


def _match_image(dt: Detections, gt: Detections, label: int,
                 iou_thr: float):
    """Greedy matching for one image+class: returns (scores, tp_flags, n_gt).

    Each detection (descending score) claims the unclaimed GT box with the
    highest IoU >= thr; among exact IoU ties the highest GT index wins (the
    running ``>=`` max of the original scan).
    """
    di = np.where(dt.labels == label)[0]
    gi = np.where(gt.labels == label)[0]
    if len(di) == 0:
        return np.zeros(0), np.zeros(0, bool), len(gi)
    order = di[np.argsort(-dt.scores[di], kind="stable")]
    tp = np.zeros(len(order), bool)
    if len(gi):
        iou = iou_matrix(dt.boxes[order], gt.boxes[gi])
        taken = np.zeros(len(gi), bool)
        for r in range(len(order)):
            cand = np.where(taken, -1.0, iou[r])
            bj = len(gi) - 1 - int(np.argmax(cand[::-1]))
            if cand[bj] >= iou_thr:
                taken[bj] = True
                tp[r] = True
    return dt.scores[order], tp, len(gi)


def _ap_from_matches(scores: np.ndarray, tps: np.ndarray,
                     n_gt: int) -> float:
    """101-point interpolated AP from pooled (score, tp) pairs."""
    if len(scores) == 0:
        return 0.0
    order = np.argsort(-scores, kind="stable")
    tps = tps[order]
    tp_cum = np.cumsum(tps)
    fp_cum = np.cumsum(~tps)
    recall = tp_cum / n_gt
    precision = tp_cum / np.maximum(tp_cum + fp_cum, 1e-12)
    # monotone precision envelope
    precision = np.maximum.accumulate(precision[::-1])[::-1]
    # closed-form 101-pt interpolation: the grid point r contributes the
    # envelope at the first rank with recall >= r, which is always rank 0
    # (for r=0) or a TP rank — so sum envelope[k] * (#grid points landing
    # on k) over those ranks only, instead of walking all 101 points
    tp_pos = np.flatnonzero(tps)
    if len(tp_pos) == 0 or tp_pos[0] != 0:
        tp_pos = np.concatenate([[0], tp_pos])
    cnt = np.searchsorted(RECALL_POINTS, recall[tp_pos], side="right")
    prev = np.concatenate([[0], cnt[:-1]])
    contrib = precision[tp_pos] * (cnt - prev)
    ap = 0.0
    for p in contrib:               # sequential adds (stable summation order)
        ap += p
    return ap / len(RECALL_POINTS)


def average_precision(dts: Dict[int, Detections], gts: Dict[int, Detections],
                      *, iou_thr: float = 0.5,
                      labels: Optional[Iterable[int]] = None) -> float:
    """Mean AP over categories present in the ground truth."""
    if labels is None:
        labs = set()
        for g in gts.values():
            labs.update(np.unique(g.labels).tolist())
        labels = sorted(labs)
    aps = []
    for lab in labels:
        scores, tps, n_gt = [], [], 0
        for img, gt in gts.items():
            dt = dts.get(img, Detections.empty())
            s, t, n = _match_image(dt, gt, lab, iou_thr)
            scores.append(s)
            tps.append(t)
            n_gt += n
        if n_gt == 0:
            continue
        aps.append(_ap_from_matches(np.concatenate(scores),
                                    np.concatenate(tps), n_gt))
    return _seq_mean(aps) if aps else 0.0


def ap50(dts, gts, **kw) -> float:
    return average_precision(dts, gts, iou_thr=0.5, **kw)


def coco_map(dts, gts, **kw) -> float:
    thrs = np.arange(0.5, 0.96, 0.05)
    return float(np.mean([average_precision(dts, gts, iou_thr=t, **kw)
                          for t in thrs]))


def image_ap50(dt: Detections, gt: Detections) -> float:
    """Per-image AP50 — the paper's reward signal v_t.

    Python-scalar fast path for the tiny per-image problem (tens of boxes,
    a handful of categories): bit-identical to
    ``average_precision({0: dt}, {0: gt}, iou_thr=0.5)`` but ~5x faster —
    this sits inside the per-(image, action) reward loop.
    """
    return _image_ap(dt, gt, 0.5)


_RECALL_LIST = RECALL_POINTS.tolist()


def _image_ap(dt: Detections, gt: Detections, iou_thr: float) -> float:
    from bisect import bisect_right
    gt_labels = gt.labels.tolist()
    labels = sorted(set(gt_labels))
    if not labels:
        return 0.0
    n_dt = len(dt)
    if n_dt:
        iou_rows = iou_matrix(dt.boxes, gt.boxes).tolist()
        dt_labels = dt.labels.tolist()
        dt_scores = dt.scores.tolist()
    aps = []
    for lab in labels:
        gi = [c for c, l in enumerate(gt_labels) if l == lab]
        n_gt = len(gi)
        di = ([r for r, l in enumerate(dt_labels) if l == lab]
              if n_dt else [])
        if not di:
            aps.append(0.0)
            continue
        order = sorted(di, key=lambda r: -dt_scores[r])     # stable
        taken = [False] * n_gt
        tp = []
        for r in order:
            row = iou_rows[r]
            best, bj = iou_thr, -1
            for k in range(n_gt):
                if not taken[k] and row[gi[k]] >= best:
                    best, bj = row[gi[k]], k
            if bj >= 0:
                taken[bj] = True
                tp.append(True)
            else:
                tp.append(False)
        tpc = 0
        recall, precision = [], []
        for k, flag in enumerate(tp):
            tpc += flag
            recall.append(tpc / n_gt)
            precision.append(tpc / (k + 1))
        for k in range(len(precision) - 2, -1, -1):
            if precision[k + 1] > precision[k]:
                precision[k] = precision[k + 1]
        # closed-form interpolation over rank 0 + TP ranks (see
        # _ap_from_matches) — identical summation order, python scalars
        ks = [k for k, flag in enumerate(tp) if flag]
        if not ks or ks[0] != 0:
            ks = [0] + ks
        ap, prev = 0.0, 0
        for k in ks:
            cnt = bisect_right(_RECALL_LIST, recall[k])
            ap += precision[k] * (cnt - prev)
            prev = cnt
        aps.append(ap / len(_RECALL_LIST))
    return _seq_mean(aps)
