"""The 12 ensemble pathways: {affirmative,consensus,unanimous} voting x
{none,nms,soft-nms,wbf} ablation.  Paper default: Affirmative-WBF.

Two entry points:

  * ``ensemble_detections``        — one image, a list of per-provider
    ``Detections`` (the seed API, kept verbatim for callers and tests).
  * ``ensemble_detections_batch``  — many images in one call, array-first:
    merged arrays + one (optionally Pallas-kernel-backed) pairwise-IoU
    matrix per image, shared across the grouping/voting/ablation stages.

Both funnel into ``ensemble_from_arrays``, the array-first core used by the
subset-evaluation cache (``repro.federation.evaluation``) which slices a
single per-image IoU matrix across all candidate provider subsets.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

import numpy as np

from repro.ensemble.ablation import nms, soft_nms, wbf
from repro.ensemble.boxes import Detections
from repro.ensemble.voting import group_detections, vote_filter

VOTING = ("affirmative", "consensus", "unanimous")
ABLATION = ("none", "nms", "softnms", "wbf")
PATHWAYS = [(v, a) for v in VOTING for a in ABLATION]
DEFAULT = ("affirmative", "wbf")


def resolve_use_kernel(use_kernel: Union[bool, str]) -> bool:
    """``"auto"`` -> Pallas IoU kernel on accelerator backends, numpy twin
    on CPU (where interpret-mode Pallas is orders of magnitude slower and
    the numpy reference is the kernel's bitwise oracle anyway)."""
    if isinstance(use_kernel, str):
        if use_kernel != "auto":
            # a typo like "atuo" must not silently coerce to True (any
            # non-empty string is truthy) and flip the dispatch
            raise ValueError(
                f"use_kernel must be a bool or 'auto', got {use_kernel!r}")
        import jax
        return jax.default_backend() != "cpu"
    return bool(use_kernel)


def ensemble_from_arrays(boxes: np.ndarray, scores: np.ndarray,
                         labels: np.ndarray, providers: np.ndarray,
                         n_selected: int, *, voting: str = "affirmative",
                         ablation: str = "wbf", iou_thr: float = 0.5,
                         use_kernel: bool = False,
                         iou: Optional[np.ndarray] = None) -> Detections:
    """Array-first ensemble core: merged per-image arrays in, fused out.

    ``providers`` tags each detection with its position in the selected
    subset (0..n_selected-1); ``iou`` optionally supplies the precomputed
    pairwise IoU of ``boxes`` so batched/cached callers pay for it once.
    Arrays must already be normalized (float32 boxes/scores, int32 labels/
    providers) — every caller slices or concatenates normalized
    ``Detections`` storage.
    """
    merged = Detections.fast(boxes, scores, labels, providers)
    if len(merged) == 0:
        return merged
    groups = group_detections(merged, iou_thr=iou_thr,
                              use_kernel=use_kernel, iou=iou)
    groups = vote_filter(merged, groups, method=voting,
                         n_selected=n_selected)
    if ablation == "wbf":
        return wbf(merged, groups, n_models=n_selected)
    if not groups:
        return Detections.empty()
    kept = merged.take(np.concatenate(groups))
    if ablation == "none":
        return kept
    if ablation == "nms":
        return nms(kept, iou_thr=iou_thr)
    if ablation == "softnms":
        return soft_nms(kept)
    raise ValueError(ablation)


def merge_provider_detections(per_provider: Sequence[Detections]):
    """Concat per-provider detections into merged arrays, tagging each row
    with its position in the selection (the single source of truth for the
    merged-array layout shared by the direct, batched, and cached paths).
    Returns (boxes, scores, labels, providers); ``per_provider`` must be
    non-empty."""
    boxes = np.concatenate([d.boxes for d in per_provider], axis=0)
    scores = np.concatenate([d.scores for d in per_provider])
    labels = np.concatenate([d.labels for d in per_provider])
    providers = np.repeat(np.arange(len(per_provider), dtype=np.int32),
                          [len(d) for d in per_provider])
    return boxes, scores, labels, providers


def ensemble_detections(per_provider: Sequence[Detections], *,
                        voting: str = "affirmative", ablation: str = "wbf",
                        iou_thr: float = 0.5,
                        use_kernel: bool = False) -> Detections:
    """Merge detections from the selected providers (paper Sec. IV-D).

    ``per_provider[i]`` is provider i's detections for one image, with
    labels already mapped to canonical group ids by the word-grouping stage.
    """
    if not per_provider:
        return Detections.empty()
    boxes, scores, labels, providers = \
        merge_provider_detections(per_provider)
    return ensemble_from_arrays(boxes, scores, labels, providers,
                                len(per_provider), voting=voting,
                                ablation=ablation, iou_thr=iou_thr,
                                use_kernel=use_kernel)


def batch_iou_matrices(boxes_list: Sequence[np.ndarray], *,
                       use_kernel: Union[bool, str] = "auto"
                       ) -> List[np.ndarray]:
    """Pairwise self-IoU for a batch of images in one launch.

    Kernel path pads every image's boxes to the batch max and runs a single
    vmapped Pallas call (one compile per padded shape); numpy path computes
    per image (padding would cost more than it saves on CPU).
    """
    from repro.ensemble.boxes import iou_matrix
    if not boxes_list:
        return []
    if resolve_use_kernel(use_kernel):
        import jax
        import jax.numpy as jnp
        from repro.kernels.iou_matrix.kernel import iou_matrix_pallas
        nmax = max(int(b.shape[0]) for b in boxes_list)
        if nmax == 0:
            return [np.zeros((0, 0), np.float32) for _ in boxes_list]
        padded = np.zeros((len(boxes_list), nmax, 4), np.float32)
        for i, b in enumerate(boxes_list):
            padded[i, :len(b)] = b
        interpret = jax.default_backend() == "cpu"
        full = jax.vmap(lambda b: iou_matrix_pallas(
            b, b, interpret=interpret))(jnp.asarray(padded))
        full = np.asarray(full)
        return [full[i, :len(b), :len(b)] for i, b in enumerate(boxes_list)]
    return [iou_matrix(b, b) if len(b) else np.zeros((0, 0), np.float32)
            for b in boxes_list]


def ensemble_detections_batch(per_image: Sequence[Sequence[Detections]], *,
                              voting: str = "affirmative",
                              ablation: str = "wbf", iou_thr: float = 0.5,
                              use_kernel: Union[bool, str] = "auto"
                              ) -> List[Detections]:
    """Ensemble a whole split of images in one call.

    ``per_image[t]`` is the list of selected providers' ``Detections`` for
    image t.  All pairwise-IoU matrices are computed up front in one batched
    launch (Pallas kernel on accelerators), then the grouping greedy runs
    over each precomputed matrix.
    """
    merged_arrays = []
    for sel in per_image:
        if sel:
            boxes, scores, labels, provs = merge_provider_detections(sel)
        else:
            e = Detections.empty()
            boxes, scores, labels, provs = e.boxes, e.scores, e.labels, \
                e.providers
        merged_arrays.append((boxes, scores, labels, provs, len(sel)))
    ious = batch_iou_matrices([m[0] for m in merged_arrays],
                              use_kernel=use_kernel)
    return [ensemble_from_arrays(b, s, l, p, k, voting=voting,
                                 ablation=ablation, iou_thr=iou_thr, iou=iou)
            for (b, s, l, p, k), iou in zip(merged_arrays, ious)]
