"""The 12 ensemble pathways: {affirmative,consensus,unanimous} voting x
{none,nms,soft-nms,wbf} ablation.  Paper default: Affirmative-WBF."""
from __future__ import annotations

from typing import List, Sequence

from repro.ensemble.ablation import nms, soft_nms, wbf
from repro.ensemble.boxes import Detections
from repro.ensemble.voting import group_detections, vote_filter

VOTING = ("affirmative", "consensus", "unanimous")
ABLATION = ("none", "nms", "softnms", "wbf")
PATHWAYS = [(v, a) for v in VOTING for a in ABLATION]
DEFAULT = ("affirmative", "wbf")


def ensemble_detections(per_provider: Sequence[Detections], *,
                        voting: str = "affirmative", ablation: str = "wbf",
                        iou_thr: float = 0.5,
                        use_kernel: bool = False) -> Detections:
    """Merge detections from the selected providers (paper Sec. IV-D).

    ``per_provider[i]`` is provider i's detections for one image, with
    labels already mapped to canonical group ids by the word-grouping stage.
    """
    tagged = []
    for i, d in enumerate(per_provider):
        t = Detections(d.boxes, d.scores, d.labels)
        import numpy as np
        t.providers = np.full(len(t), i, np.int32)
        tagged.append(t)
    merged = Detections.concat(tagged)
    if len(merged) == 0:
        return merged
    groups = group_detections(merged, iou_thr=iou_thr, use_kernel=use_kernel)
    groups = vote_filter(merged, groups, method=voting,
                         n_selected=len(per_provider))
    if ablation == "wbf":
        return wbf(merged, groups, n_models=len(per_provider))
    import numpy as np
    if not groups:
        return Detections.empty()
    kept = merged.take(np.concatenate(groups))
    if ablation == "none":
        return kept
    if ablation == "nms":
        return nms(kept, iou_thr=iou_thr)
    if ablation == "softnms":
        return soft_nms(kept)
    raise ValueError(ablation)
