"""Voting stage (paper Sec. IV-D): group then filter.

Detections from the selected providers are clustered into groups G =
[g_1..g_r]: two detections join the same group iff IoU > 0.5 and same
canonical label.  Groups are then kept by the voting rule:

  affirmative — keep every group (any provider's say-so counts)
  consensus   — keep groups seen by > N/2 distinct providers
  unanimous   — keep groups seen by all N selected providers
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix

IOU_GROUP_THR = 0.5


def group_detections(dets: Detections, *, iou_thr: float = IOU_GROUP_THR,
                     use_kernel: bool = False,
                     iou: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Greedy clustering by (label, IoU>thr).  Returns index arrays.

    Detections are visited in descending score order; each joins the first
    existing group whose *representative* (highest-score member) matches.
    ``iou`` supplies a precomputed (n, n) pairwise IoU matrix (the batched
    subset-evaluation core slices one kernel-backed matrix per image across
    all candidate subsets); otherwise it is computed here. ``use_kernel=True``
    routes that computation through the Pallas kernel wrapper (interpret
    mode on CPU).
    """
    n = len(dets)
    if n == 0:
        return []
    order = np.argsort(-dets.scores, kind="stable").tolist()
    if iou is None:
        if use_kernel:
            from repro.kernels.iou_matrix.ops import iou_matrix_op
            iou = np.asarray(iou_matrix_op(dets.boxes, dets.boxes))
        else:
            iou = iou_matrix(dets.boxes, dets.boxes)
    # per-subset merged sets are small (tens of boxes): python-scalar greedy
    # over list-converted rows beats numpy-indexed scalars ~10x here
    iou_rows = iou.tolist()
    labels = dets.labels.tolist()
    thr = float(iou_thr)
    groups: List[List[int]] = []
    reps: List[int] = []
    rep_labels: List[int] = []
    for i in order:
        li = labels[i]
        row = iou_rows[i]
        placed = False
        for gi in range(len(reps)):
            if rep_labels[gi] == li and row[reps[gi]] > thr:
                groups[gi].append(i)
                placed = True
                break
        if not placed:
            groups.append([i])
            reps.append(i)
            rep_labels.append(li)
    return [np.asarray(g, np.int64) for g in groups]


def vote_filter(dets: Detections, groups: List[np.ndarray], *, method: str,
                n_selected: int) -> List[np.ndarray]:
    if method == "affirmative":
        return groups
    out = []
    for g in groups:
        provs = dets.providers[g] if dets.providers is not None else \
            np.zeros(len(g))
        distinct = len(np.unique(provs))
        if method == "consensus" and distinct > n_selected / 2.0:
            out.append(g)
        elif method == "unanimous" and distinct == n_selected:
            out.append(g)
    return out
