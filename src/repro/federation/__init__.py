from repro.federation.vocab import WordGrouper, COCO_TEMPLATE  # noqa: F401
from repro.federation.providers import ProviderProfile, default_providers, \
    scalability_providers  # noqa: F401
from repro.federation.traces import TraceSet, generate_traces  # noqa: F401
from repro.federation.env import ArmolEnv  # noqa: F401
from repro.federation.evaluation import (SubsetEvaluationCore,  # noqa: F401
                                         action_to_mask, mask_to_action,
                                         popcount_masks)
