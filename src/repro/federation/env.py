"""Gym-style trace-driven environment for MLaaS federation (paper Sec. III).

State  : feature vector of the current image (conv extractor, "MobileNet"
         role), precomputed for the whole trace set.
Action : binary provider-subset vector a in {0,1}^N (a != 0).
Reward : r_t = v_t + beta * c_t  with v_t = per-image AP50 of the ensembled
         prediction and c_t the summed provider fees (milli-USD);
         r_t = -1 when the selection returns no predictions (Eq. 5).
Modes  : "gt"   — AP against ground truth (Armol-w/ gt)
         "nogt" — AP against the pseudo ground truth: the ensemble of ALL
                  providers' predictions (Armol-w/o gt).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.networks import extract_features, init_feature_extractor
from repro.ensemble.boxes import Detections
from repro.ensemble.metrics import image_ap50
from repro.ensemble.pipeline import ensemble_detections
from repro.federation.traces import TraceSet

FEATURE_SEED = 7


class ArmolEnv:
    def __init__(self, traces: TraceSet, *, mode: str = "gt",
                 beta: float = 0.0, voting: str = "affirmative",
                 ablation: str = "wbf", train_frac: float = 0.7,
                 seed: int = 0, feat_dim: int = 64):
        assert mode in ("gt", "nogt")
        self.traces = traces
        self.mode = mode
        self.beta = beta
        self.voting = voting
        self.ablation = ablation
        self.rng = np.random.default_rng(seed)
        self.n_providers = traces.n_providers
        self.costs = traces.costs()

        # --- state features (precomputed once, like the paper's MobileNet):
        # conv-stack embedding + category-sensitive matched-filter responses
        # (the "pretrained backbone" signal; see traces.category_features)
        fkey = jax.random.PRNGKey(FEATURE_SEED)
        fparams = init_feature_extractor(fkey, feat_dim=feat_dim)
        feats = jax.vmap(lambda im: extract_features(fparams, im))(
            traces.images)
        from repro.federation.traces import category_features
        cat_feats = category_features(traces.images, len(traces.categories))
        self.features = np.concatenate(
            [np.asarray(feats, np.float32), cat_feats], axis=1)
        self.state_dim = self.features.shape[1]

        n = len(traces)
        split = int(n * train_frac)
        self.train_idx = np.arange(0, split)
        self.test_idx = np.arange(split, n)

        # pseudo ground truth cache (ensemble of all providers)
        self._pseudo: Dict[int, Detections] = {}
        self._order: np.ndarray = self.train_idx
        self._t = 0

    # ------------------------------------------------------------------
    def pseudo_gt(self, img_idx: int) -> Detections:
        if img_idx not in self._pseudo:
            self._pseudo[img_idx] = ensemble_detections(
                self.traces.dets[img_idx], voting=self.voting,
                ablation=self.ablation)
        return self._pseudo[img_idx]

    def reference_gt(self, img_idx: int) -> Detections:
        if self.mode == "gt":
            return self.traces.gts[img_idx]
        return self.pseudo_gt(img_idx)

    def ensemble_for(self, img_idx: int, action: np.ndarray) -> Detections:
        sel = [self.traces.dets[img_idx][i]
               for i in range(self.n_providers) if action[i] > 0.5]
        if not sel:
            return Detections.empty()
        return ensemble_detections(sel, voting=self.voting,
                                   ablation=self.ablation)

    def evaluate_action(self, img_idx: int,
                        action: np.ndarray) -> Tuple[float, float, float]:
        """Returns (reward, v=AP50, cost_milli_usd) for one image."""
        ens = self.ensemble_for(img_idx, action)
        cost = float(np.sum(self.costs * (action > 0.5)))
        if len(ens) == 0:
            return -1.0, 0.0, cost
        v = image_ap50(ens, self.reference_gt(img_idx))
        return v + self.beta * cost, v, cost

    # ------------------------------------------------------------------
    def reset(self, *, split: str = "train",
              shuffle: bool = True) -> np.ndarray:
        idx = self.train_idx if split == "train" else self.test_idx
        self._order = self.rng.permutation(idx) if shuffle else idx.copy()
        self._t = 0
        return self.features[self._order[0]]

    @property
    def current_image(self) -> int:
        return int(self._order[self._t])

    def step(self, action: np.ndarray):
        img = self.current_image
        reward, v, cost = self.evaluate_action(img, action)
        self._t += 1
        done = self._t >= len(self._order)
        nxt = self.features[self._order[min(self._t, len(self._order) - 1)]]
        return nxt, reward, done, {"ap50": v, "cost": cost, "image": img}
