"""Gym-style trace-driven environment for MLaaS federation (paper Sec. III).

State  : feature vector of the current image (conv extractor, "MobileNet"
         role), precomputed for the whole trace set.
Action : binary provider-subset vector a in {0,1}^N (a != 0).
Reward : r_t = v_t + beta * c_t  with v_t = per-image AP50 of the ensembled
         prediction and c_t the summed provider fees (milli-USD);
         r_t = -1 when the selection returns no predictions (Eq. 5).
Modes  : "gt"   — AP against ground truth (Armol-w/ gt)
         "nogt" — AP against the pseudo ground truth: the ensemble of ALL
                  providers' predictions (Armol-w/o gt).

All subset evaluation goes through the memoized ``SubsetEvaluationCore``
(``repro.federation.evaluation``): repeated (image, action) pairs — the
normal case over a multi-epoch training run — cost one dict lookup, and the
vectorized ``evaluate_actions`` / ``step_batch`` paths evaluate whole
batches against precomputed per-image IoU tables.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.networks import extract_features, init_feature_extractor
from repro.ensemble.boxes import Detections
from repro.federation.evaluation import SubsetEvaluationCore
from repro.federation.traces import TraceSet

FEATURE_SEED = 7


class ArmolEnv:
    def __init__(self, traces: TraceSet, *, mode: str = "gt",
                 beta: float = 0.0, voting: str = "affirmative",
                 ablation: str = "wbf", train_frac: float = 0.7,
                 seed: int = 0, feat_dim: int = 64,
                 use_kernel: Union[bool, str] = "auto",
                 core: Optional[SubsetEvaluationCore] = None):
        assert mode in ("gt", "nogt")
        self.traces = traces
        self.mode = mode
        self.beta = beta
        self.voting = voting
        self.ablation = ablation
        self.rng = np.random.default_rng(seed)
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        # callers holding a pre-warmed core (e.g. a scenario pool's
        # segment-0 core) inject it instead of building a cold one
        self.core = core if core is not None else SubsetEvaluationCore(
            traces, voting=voting, ablation=ablation, use_kernel=use_kernel)

        # --- state features (precomputed once, like the paper's MobileNet):
        # conv-stack embedding + category-sensitive matched-filter responses
        # (the "pretrained backbone" signal; see traces.category_features)
        fkey = jax.random.PRNGKey(FEATURE_SEED)
        fparams = init_feature_extractor(fkey, feat_dim=feat_dim)
        feats = jax.vmap(lambda im: extract_features(fparams, im))(
            traces.images)
        from repro.federation.traces import category_features
        cat_feats = category_features(traces.images, len(traces.categories))
        self.features = np.concatenate(
            [np.asarray(feats, np.float32), cat_feats], axis=1)
        self.state_dim = self.features.shape[1]

        n = len(traces)
        split = int(n * train_frac)
        self.train_idx = np.arange(0, split)
        self.test_idx = np.arange(split, n)

        self._order: np.ndarray = self.train_idx
        self._t = 0
        self._lane_orders: list = []
        self._lane_t = np.zeros(0, np.int64)
        self._lane_split = ("train", True)
        self._features_dev = None
        self._costs_dev = None

    # ------------------------------------------------------------------
    # device mirrors: per-image state features and the provider fee
    # vector as jax arrays, built lazily and cached.  The device-resident
    # training path assembles replay rows from these on device
    # (``DeviceReplayBuffer.add_batch_indexed``), so per-tick host
    # traffic shrinks to small index/reward vectors.
    # ------------------------------------------------------------------
    def device_features(self):
        if self._features_dev is None:
            import jax.numpy as jnp
            self._features_dev = jnp.asarray(self.features, jnp.float32)
        return self._features_dev

    def device_costs(self):
        if self._costs_dev is None:
            import jax.numpy as jnp
            self._costs_dev = jnp.asarray(self.costs, jnp.float32)
        return self._costs_dev

    @property
    def _against(self) -> str:
        return "gt" if self.mode == "gt" else "pseudo"

    # ------------------------------------------------------------------
    def pseudo_gt(self, img_idx: int) -> Detections:
        return self.core.pseudo_gt(img_idx)

    def reference_gt(self, img_idx: int) -> Detections:
        if self.mode == "gt":
            return self.traces.gts[img_idx]
        return self.pseudo_gt(img_idx)

    def ensemble_for(self, img_idx: int, action: np.ndarray) -> Detections:
        return self.core.ensemble(img_idx, self.core.mask_of(action))

    def evaluate_action(self, img_idx: int,
                        action: np.ndarray) -> Tuple[float, float, float]:
        """Returns (reward, v=AP50, cost_milli_usd) for one image."""
        return self.core.evaluate(img_idx, action, beta=self.beta,
                                  against=self._against)

    def evaluate_actions(self, img_indices: Sequence[int],
                         actions: np.ndarray) -> Dict[str, np.ndarray]:
        """Vectorized evaluate_action over a batch of (image, action)
        pairs: returns {"reward", "ap50", "cost", "mask"} arrays of shape
        (B,).  Per-image IoU tables are precomputed in one batched launch
        on the kernel path and cached for later single-pair calls."""
        return self.core.evaluate_batch(img_indices, actions,
                                        beta=self.beta,
                                        against=self._against)

    # ------------------------------------------------------------------
    def _episode_order(self, idx: np.ndarray, shuffle: bool) -> np.ndarray:
        """One episode's image visit order — the single override point for
        request-distribution dynamics (a non-stationary env reweights it
        under demand shifts).  Draws from ``self.rng`` exactly as the
        historical inline permutation did."""
        return self.rng.permutation(idx) if shuffle else idx.copy()

    def reset(self, *, split: str = "train",
              shuffle: bool = True) -> np.ndarray:
        idx = self.train_idx if split == "train" else self.test_idx
        self._order = self._episode_order(idx, shuffle)
        self._t = 0
        return self.features[self._order[0]]

    @property
    def current_image(self) -> int:
        return int(self._order[self._t])

    def step(self, action: np.ndarray):
        img = self.current_image
        reward, v, cost = self.evaluate_action(img, action)
        self._t += 1
        done = self._t >= len(self._order)
        nxt = self.features[self._order[min(self._t, len(self._order) - 1)]]
        return nxt, reward, done, {"ap50": v, "cost": cost, "image": img}

    # ------------------------------------------------------------------
    # Parallel lanes: L independent episode cursors over the same trace
    # split, evaluated through one batched subset-evaluation call per tick.
    # Lane 0 with L=1 consumes self.rng identically to reset()/step(), so
    # the multi-lane training drivers are bit-compatible with the
    # sequential reference at L=1.
    # ------------------------------------------------------------------
    def reset_lanes(self, n_lanes: int = 1, *, split: str = "train",
                    shuffle: bool = True) -> np.ndarray:
        idx = self.train_idx if split == "train" else self.test_idx
        self._lane_split = (split, shuffle)
        self._lane_orders = [self._episode_order(idx, shuffle)
                             for _ in range(n_lanes)]
        self._lane_t = np.zeros(n_lanes, np.int64)
        return self.features[[int(o[0]) for o in self._lane_orders]]

    @property
    def n_lanes(self) -> int:
        return len(self._lane_orders)

    def lane_states(self) -> np.ndarray:
        return self.features[
            [int(o[t]) for o, t in zip(self._lane_orders, self._lane_t)]]

    def step_lanes(self, actions: np.ndarray):
        """Advance every lane one step with one batched evaluation.

        Returns (nxt, rewards, dones, infos, carry): ``nxt`` (L, D) follows
        ``step``'s next-state convention (episode-end clamps to the last
        image — what the replay buffer stores), while ``carry`` (L, D) is
        the state to act on next tick (finished lanes auto-reset onto a
        fresh permutation, drawn from self.rng in lane order).
        """
        L = len(self._lane_orders)
        actions = np.asarray(actions, np.float32).reshape(L,
                                                          self.n_providers)
        imgs = np.asarray([int(o[t]) for o, t in
                           zip(self._lane_orders, self._lane_t)], np.int64)
        out = self.evaluate_actions(imgs, actions)
        self._lane_t += 1
        lens = np.asarray([len(o) for o in self._lane_orders])
        dones = self._lane_t >= lens
        nxt_pos = np.minimum(self._lane_t, lens - 1)
        nxt_imgs = np.asarray([int(o[p]) for o, p in
                               zip(self._lane_orders, nxt_pos)], np.int64)
        nxt = self.features[nxt_imgs]
        split, shuffle = self._lane_split
        idx = self.train_idx if split == "train" else self.test_idx
        for lane in np.flatnonzero(dones):
            self._lane_orders[lane] = self._episode_order(idx, shuffle)
            self._lane_t[lane] = 0
        # "image"/"next_image" are the row indices of ``states``/``nxt``
        # in the feature table — the device-resident buffer writes
        # transitions from these instead of the materialized rows
        infos = {"ap50": out["ap50"], "cost": out["cost"], "image": imgs,
                 "next_image": nxt_imgs}
        return nxt, out["reward"], dones, infos, self.lane_states()

    def step_batch(self, actions: np.ndarray):
        """Consume the next B steps of the episode in one vectorized call.

        ``actions`` is (B, N); B is clipped to the steps remaining in the
        episode.  Returns (next_states (B', D), rewards (B',), dones (B',),
        infos) where infos carries per-step arrays like ``step``'s dict.
        """
        actions = np.asarray(actions, np.float32).reshape(
            -1, self.n_providers)
        remaining = len(self._order) - self._t
        B = min(len(actions), remaining)
        imgs = self._order[self._t:self._t + B]
        out = self.evaluate_actions(imgs, actions[:B])
        self._t += B
        done_t = np.arange(self._t - B + 1, self._t + 1) >= len(self._order)
        nxt_pos = np.minimum(np.arange(self._t - B + 1, self._t + 1),
                             len(self._order) - 1)
        nxt = self.features[self._order[nxt_pos]]
        infos = {"ap50": out["ap50"], "cost": out["cost"],
                 "image": np.asarray(imgs, np.int64)}
        return nxt, out["reward"], done_t, infos
