"""Batched, memoized subset-evaluation core — the hot path of Armol.

Every layer of the system (env rewards, policy evaluation, the Algo.-2
upper bound, the serving fan-out, benchmarks) ultimately asks the same
question: *for image t and provider subset S, what are the ensembled
detections, the per-image AP50, and the cost?*  The seed answered it from
scratch each time — re-tagging Detections, recomputing the pairwise IoU of
the merged boxes, regrouping, re-fusing — per image, per action, in Python.

This module computes each distinct answer once:

  * per image, ONE concatenated detection table over all N providers and
    ONE pairwise IoU matrix (Pallas kernel on accelerators, numpy twin on
    CPU); every subset's merged arrays and IoU submatrix are O(1) slices,
  * per (image, subset-bitmask), the ensembled ``Detections`` and per-image
    AP50 (vs GT and/or pseudo-GT) are memoized,
  * a batch API evaluates whole splits of images x actions in one call,
    with all IoU matrices precomputed in one batched kernel launch.

Subsets are keyed by bitmask: bit i set <=> provider i selected, so the
2^N - 1 actions of the paper's combinatorial space index a flat dict.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix
from repro.ensemble.metrics import image_ap50
from repro.ensemble.pipeline import (ensemble_from_arrays,
                                     merge_provider_detections,
                                     resolve_use_kernel)
from repro.federation.traces import TraceSet


def action_to_mask(action: np.ndarray) -> int:
    """Binary action vector -> subset bitmask (bit i = provider i)."""
    bits = np.asarray(action).reshape(-1) > 0.5
    return int(np.sum(np.left_shift(1, np.nonzero(bits)[0])))


def mask_to_action(mask: int, n: int) -> np.ndarray:
    return np.asarray([(mask >> i) & 1 for i in range(n)], np.float32)


def popcount_masks(n: int) -> List[int]:
    """All non-empty subset masks of {0..n-1} in increasing popcount order.

    Within one popcount, masks keep the order of the seed's Algo.-2
    enumeration (lexicographic over the action tuple, stable-sorted by
    popcount) so tie-breaking matches the uncached upper bound exactly.
    """
    masks = []
    for m in range(1, 1 << n):
        # the seed enumerates itertools.product tuples a=(a_0..a_{n-1});
        # tuple order corresponds to the integer with a_0 as the HIGH bit
        masks.append(m)
    # reconstruct seed order: product order == ascending on reversed bits
    def revbits(m: int) -> int:
        return int(sum(((m >> i) & 1) << (n - 1 - i) for i in range(n)))
    masks.sort(key=lambda m: (bin(m).count("1"), revbits(m)))
    return masks


@dataclass
class _ImageTable:
    """Per-image precompute shared by every subset of that image."""
    boxes: np.ndarray          # (n_all, 4) all providers, provider order
    scores: np.ndarray         # (n_all,)
    labels: np.ndarray         # (n_all,)
    lengths: np.ndarray        # (N,) detections per provider
    row_provider: np.ndarray   # (n_all,) owning provider of each row
    iou: np.ndarray            # (n_all, n_all) pairwise IoU, computed once

    def subset_indices(self, bits: np.ndarray) -> np.ndarray:
        """Rows belonging to the selected providers (ascending, i.e. the
        same provider-block order as a fresh concat)."""
        return np.flatnonzero(bits[self.row_provider])


class SubsetEvaluationCore:
    """Cache + batch evaluator for (image, provider-subset) ensembles.

    One instance per (traces, voting, ablation, iou_thr) configuration —
    exactly the knobs that change the ensemble output.  ``use_kernel`` is
    ``"auto"`` (Pallas IoU kernel on accelerator backends, numpy twin on
    CPU), or an explicit bool.
    """

    def __init__(self, traces: TraceSet, *, voting: str = "affirmative",
                 ablation: str = "wbf", iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto"):
        self.traces = traces
        self.voting = voting
        self.ablation = ablation
        self.iou_thr = iou_thr
        self.use_kernel = resolve_use_kernel(use_kernel)
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1
        self._tables: Dict[int, _ImageTable] = {}
        self._masks: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._ens: Dict[Tuple[int, int], Detections] = {}
        self._ap: Dict[Tuple[int, int, str], float] = {}
        self._cost: Dict[int, float] = {}
        self.stats = {"ens_hits": 0, "ens_misses": 0,
                      "ap_hits": 0, "ap_misses": 0, "tables": 0}

    # -- per-image table ------------------------------------------------
    def _full_iou(self, boxes: np.ndarray) -> np.ndarray:
        if len(boxes) == 0:
            return np.zeros((0, 0), np.float32)
        if self.use_kernel:
            from repro.kernels.iou_matrix.ops import iou_matrix_op
            return np.asarray(iou_matrix_op(boxes, boxes))
        return iou_matrix(boxes, boxes)

    def _build_table(self, img_idx: int,
                     iou: Optional[np.ndarray] = None) -> _ImageTable:
        dets = self.traces.dets[img_idx]
        lengths = np.asarray([len(d) for d in dets], np.int64)
        # full-set merge: positional tags coincide with true provider ids
        boxes, scores, labels, row_provider = \
            merge_provider_detections(dets)
        if iou is None:
            iou = self._full_iou(boxes)
        self.stats["tables"] += 1
        return _ImageTable(boxes, scores, labels, lengths, row_provider, iou)

    def table(self, img_idx: int) -> _ImageTable:
        t = self._tables.get(img_idx)
        if t is None:
            t = self._tables[img_idx] = self._build_table(img_idx)
        return t

    def precompute(self, img_indices: Sequence[int]) -> None:
        """Build tables for many images; IoU matrices go through one batched
        kernel launch on the kernel path."""
        missing = [int(i) for i in img_indices if int(i) not in self._tables]
        if not missing:
            return
        if self.use_kernel:
            from repro.ensemble.pipeline import batch_iou_matrices
            boxes_list = [
                np.concatenate([d.boxes for d in self.traces.dets[i]],
                               axis=0) for i in missing]
            ious = batch_iou_matrices(boxes_list, use_kernel=True)
            for i, iou in zip(missing, ious):
                self._tables[i] = self._build_table(i, iou=iou)
        else:
            for i in missing:
                self._tables[i] = self._build_table(i)

    # -- memoized single-pair evaluation --------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def _mask_info(self, mask: int) -> Tuple[np.ndarray, np.ndarray]:
        """(selected provider ids, N-length bool bits) — memoized per mask."""
        hit = self._masks.get(mask)
        if hit is None:
            bits = np.asarray([(mask >> i) & 1
                               for i in range(self.n_providers)], bool)
            hit = self._masks[mask] = (np.flatnonzero(bits), bits)
        return hit

    def selected(self, mask: int) -> np.ndarray:
        return self._mask_info(mask)[0]

    def cost(self, mask: int) -> float:
        c = self._cost.get(mask)
        if c is None:
            bits = self._mask_info(mask)[1]
            c = self._cost[mask] = float(np.sum(self.costs * bits))
        return c

    def ensemble(self, img_idx: int, mask: int) -> Detections:
        key = (img_idx, mask)
        hit = self._ens.get(key)
        if hit is not None:
            self.stats["ens_hits"] += 1
            return hit
        self.stats["ens_misses"] += 1
        if mask == 0:
            ens = Detections.empty()
        else:
            t = self.table(img_idx)
            sel, bits = self._mask_info(mask)
            idx = t.subset_indices(bits)
            providers = np.repeat(
                np.arange(len(sel), dtype=np.int32), t.lengths[sel])
            ens = ensemble_from_arrays(
                t.boxes[idx], t.scores[idx], t.labels[idx], providers,
                len(sel), voting=self.voting, ablation=self.ablation,
                iou_thr=self.iou_thr, iou=t.iou[idx[:, None], idx])
        self._ens[key] = ens
        return ens

    def pseudo_gt(self, img_idx: int) -> Detections:
        """Ensemble of ALL providers — the w/o-gt reference (paper Sec. III)."""
        return self.ensemble(img_idx, self.full_mask)

    def reference(self, img_idx: int, against: str) -> Detections:
        if against == "gt":
            return self.traces.gts[img_idx]
        if against == "pseudo":
            return self.pseudo_gt(img_idx)
        raise ValueError(against)

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt") -> float:
        key = (img_idx, mask, against)
        hit = self._ap.get(key)
        if hit is not None:
            self.stats["ap_hits"] += 1
            return hit
        self.stats["ap_misses"] += 1
        ens = self.ensemble(img_idx, mask)
        v = (image_ap50(ens, self.reference(img_idx, against))
             if len(ens) else 0.0)
        self._ap[key] = v
        return v

    def evaluate(self, img_idx: int, action: np.ndarray, *,
                 beta: float = 0.0,
                 against: str = "gt") -> Tuple[float, float, float]:
        """(reward, v=AP50, cost) with Eq.-5 semantics: r=-1 on empty."""
        mask = self.mask_of(action)
        cost = self.cost(mask)
        ens = self.ensemble(img_idx, mask)
        if len(ens) == 0:
            return -1.0, 0.0, cost
        v = self.ap50(img_idx, mask, against=against)
        return v + beta * cost, v, cost

    # -- batch APIs ------------------------------------------------------
    def evaluate_batch(self, img_indices: Sequence[int],
                       actions: np.ndarray, *, beta: float = 0.0,
                       against: str = "gt") -> Dict[str, np.ndarray]:
        """Evaluate action[t] on image img_indices[t] for a whole batch.

        Returns dict of (B,) arrays: reward, ap50, cost, plus the per-pair
        subset masks.  Tables for all images are precomputed first (one
        batched IoU launch on the kernel path); repeated (image, mask)
        pairs hit the memo.
        """
        imgs = [int(i) for i in img_indices]
        if not imgs:
            z = np.zeros(0, np.float64)
            return {"reward": z, "ap50": z.copy(), "cost": z.copy(),
                    "mask": np.zeros(0, np.int64)}
        actions = np.asarray(actions, np.float32).reshape(len(imgs), -1)
        self.precompute(imgs)
        B = len(imgs)
        reward = np.zeros(B, np.float64)
        ap = np.zeros(B, np.float64)
        cost = np.zeros(B, np.float64)
        masks = np.zeros(B, np.int64)
        for t, (img, a) in enumerate(zip(imgs, actions)):
            r, v, c = self.evaluate(img, a, beta=beta, against=against)
            reward[t], ap[t], cost[t], masks[t] = r, v, c, \
                self.mask_of(a)
        return {"reward": reward, "ap50": ap, "cost": cost, "mask": masks}

    def ensemble_rows(self, img_indices: Sequence[int],
                      masks: Sequence[int]) -> List[Tuple[np.ndarray, ...]]:
        """Wire contract of the serving shards: (boxes, scores, labels,
        providers) array tuples for each (image, mask) pair, tables
        precomputed in one batch first.  A worker process sends exactly
        these rows back over its pipe; the parent rewraps them with
        ``Detections.fast`` — raw arrays, because ``Detections`` validation
        and object overhead have no place on the IPC hot path."""
        imgs = [int(i) for i in img_indices]
        self.precompute([i for i, m in zip(imgs, masks) if int(m)])
        rows = []
        for img, m in zip(imgs, masks):
            ens = self.ensemble(img, int(m))
            rows.append((ens.boxes, ens.scores, ens.labels, ens.providers))
        return rows

    def __getstate__(self):
        """Pickle = configuration + traces, never the memo caches: a core
        crossing a process boundary arrives cold and shared-nothing (the
        caches are derivable, per-process, and would dwarf the payload).
        The serving shards ship TraceSets + snapshot recipes rather than
        whole cores, so this is the safety net for ANY future transport
        (and for user code) — not a path the process plane relies on."""
        state = dict(self.__dict__)
        state["_tables"] = {}
        state["_masks"] = {}
        state["_ens"] = {}
        state["_ap"] = {}
        state["_cost"] = {}
        state["stats"] = {k: 0 for k in self.stats}
        return state

    def ensemble_batch(self, img_indices: Sequence[int],
                       actions: np.ndarray) -> List[Detections]:
        imgs = [int(i) for i in img_indices]
        if not imgs:
            return []
        actions = np.asarray(actions, np.float32).reshape(len(imgs), -1)
        self.precompute(imgs)
        return [self.ensemble(img, self.mask_of(a))
                for img, a in zip(imgs, actions)]

    def best_subset(self, img_idx: int, masks: Sequence[int], *,
                    against: str = "gt") -> Tuple[int, float]:
        """First strict-improvement argmax over ``masks`` (Algo.-2 order):
        enumerate in the given order, keep a candidate only when its AP50
        strictly beats the incumbent — cheaper subsets (earlier in popcount
        order) win ties."""
        best_v, best_m = -1.0, masks[0]
        for m in masks:
            v = self.ap50(img_idx, m, against=against)
            if v > best_v:
                best_v, best_m = v, m
        return best_m, best_v

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Drop every cached artifact touching the given images (table,
        ensembles, AP entries) — the hook for in-place trace mutation,
        e.g. a scenario segment rewriting one provider's detections.
        Returns the number of tables actually dropped."""
        drop = {int(i) for i in img_indices}
        dropped = 0
        for i in drop:
            if self._tables.pop(i, None) is not None:
                dropped += 1
        if drop:
            # pop the doomed keys instead of rebuilding the dicts: a
            # single-image invalidation must not cost O(total cache)
            for k in [k for k in self._ens if k[0] in drop]:
                del self._ens[k]
            for k in [k for k in self._ap if k[0] in drop]:
                del self._ap[k]
        return dropped

    def cache_sizes(self) -> Dict[str, int]:
        return {"tables": len(self._tables), "ensembles": len(self._ens),
                "ap_entries": len(self._ap)}

    def config(self) -> Dict[str, object]:
        """The knobs that change ensemble output — enough to build an
        equivalent core (see ``ShardedSubsetEvaluationCore.like``)."""
        return {"voting": self.voting, "ablation": self.ablation,
                "iou_thr": self.iou_thr, "use_kernel": self.use_kernel}

    def cached_images(self) -> List[int]:
        return sorted(self._tables)


class ShardedSubsetEvaluationCore:
    """W shared-nothing ``SubsetEvaluationCore`` shards keyed by
    ``img_idx % W``.

    Each shard owns its own table/ensemble/AP dicts, so W worker threads
    (one per shard) can serve concurrent flushes without a lock and
    without ever contending on one dict.  The lookup path is merge-free:
    an image's home shard is a modulo, never a search, and since the
    assignment is total and deterministic no entry is ever duplicated
    across shards — aggregate memory equals the unsharded core's.

    The sharded core intentionally exposes the same single-pair surface
    (``ensemble`` / ``ap50`` / ``cost`` / ``evaluate`` / ``precompute``)
    as ``SubsetEvaluationCore`` by delegation, so callers can hold either.
    Thread safety is *by partition*: it is safe for different threads to
    touch different shards concurrently; two threads touching the same
    shard must be externally serialized (the async service runs one
    single-thread executor per shard).
    """

    def __init__(self, traces: TraceSet, *, n_shards: int = 4,
                 voting: str = "affirmative", ablation: str = "wbf",
                 iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto"):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.shards = [
            SubsetEvaluationCore(traces, voting=voting, ablation=ablation,
                                 iou_thr=iou_thr, use_kernel=use_kernel)
            for _ in range(self.n_shards)]
        self.traces = traces
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1

    @classmethod
    def like(cls, core: SubsetEvaluationCore,
             n_shards: int) -> "ShardedSubsetEvaluationCore":
        """A sharded core with the same ensemble configuration as ``core``
        (fresh, empty caches — sharding is a layout, not a migration)."""
        return cls(core.traces, n_shards=n_shards, **core.config())

    # -- shard addressing (the merge-free lookup path) -------------------
    def shard_id(self, img_idx: int) -> int:
        return int(img_idx) % self.n_shards

    def shard_of(self, img_idx: int) -> SubsetEvaluationCore:
        return self.shards[int(img_idx) % self.n_shards]

    def partition(self, img_indices: Sequence[int]
                  ) -> Dict[int, List[int]]:
        """shard id -> that shard's images, preserving request order.
        ``shard_id`` is the single source of the assignment rule."""
        groups: Dict[int, List[int]] = {}
        for i in img_indices:
            groups.setdefault(self.shard_id(i), []).append(int(i))
        return groups

    # -- delegated evaluation surface ------------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def precompute(self, img_indices: Sequence[int]) -> None:
        for sid, imgs in self.partition(img_indices).items():
            self.shards[sid].precompute(imgs)

    def ensemble(self, img_idx: int, mask: int) -> Detections:
        return self.shard_of(img_idx).ensemble(img_idx, mask)

    def pseudo_gt(self, img_idx: int) -> Detections:
        return self.shard_of(img_idx).pseudo_gt(img_idx)

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt") -> float:
        return self.shard_of(img_idx).ap50(img_idx, mask, against=against)

    def cost(self, mask: int) -> float:
        # mask costs are image-independent; shard 0 is their (sole) home
        return self.shards[0].cost(mask)

    def evaluate(self, img_idx: int, action: np.ndarray, *,
                 beta: float = 0.0,
                 against: str = "gt") -> Tuple[float, float, float]:
        return self.shard_of(img_idx).evaluate(img_idx, action, beta=beta,
                                               against=against)

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Per-shard invalidation through the same partition rule as every
        other delegated call, so entries are dropped exactly where they
        live."""
        dropped = 0
        for sid, imgs in self.partition(img_indices).items():
            dropped += self.shards[sid].invalidate_images(imgs)
        return dropped

    # -- aggregate introspection ----------------------------------------
    def cache_sizes(self) -> Dict[str, int]:
        agg = {"tables": 0, "ensembles": 0, "ap_entries": 0}
        for s in self.shards:
            for k, v in s.cache_sizes().items():
                agg[k] += v
        return agg

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def shard_images(self) -> List[List[int]]:
        """Per-shard cached image ids — the corruption-check surface: every
        entry of ``shard_images()[s]`` must satisfy ``img % W == s``."""
        return [s.cached_images() for s in self.shards]
