"""Batched, memoized subset-evaluation core — the hot path of Armol.

Every layer of the system (env rewards, policy evaluation, the Algo.-2
upper bound, the serving fan-out, benchmarks) ultimately asks the same
question: *for image t and provider subset S, what are the ensembled
detections, the per-image AP50, and the cost?*  The seed answered it from
scratch each time — re-tagging Detections, recomputing the pairwise IoU of
the merged boxes, regrouping, re-fusing — per image, per action, in Python.

This module computes each distinct answer once:

  * per image, ONE concatenated detection table over all N providers and
    ONE pairwise IoU matrix (Pallas kernel on accelerators, numpy twin on
    CPU); every subset's merged arrays and IoU submatrix are O(1) slices,
  * per (image, subset-bitmask), the ensembled ``Detections`` and per-image
    AP50 (vs GT and/or pseudo-GT) are memoized,
  * a batch API evaluates whole splits of images x actions in one call,
    with all IoU matrices precomputed in one batched kernel launch.

Subsets are keyed by bitmask: bit i set <=> provider i selected, so the
2^N - 1 actions of the paper's combinatorial space index a flat dict.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ensemble.boxes import Detections, iou_matrix
from repro.ensemble.metrics import RECALL_POINTS, image_ap50
from repro.ensemble.pipeline import (ensemble_from_arrays,
                                     merge_provider_detections,
                                     resolve_use_kernel)
from repro.federation.traces import TraceSet


def action_to_mask(action: np.ndarray) -> int:
    """Binary action vector -> subset bitmask (bit i = provider i)."""
    bits = np.asarray(action).reshape(-1) > 0.5
    return int(np.sum(np.left_shift(1, np.nonzero(bits)[0])))


def mask_to_action(mask: int, n: int) -> np.ndarray:
    return np.asarray([(mask >> i) & 1 for i in range(n)], np.float32)


def popcount_masks(n: int) -> List[int]:
    """All non-empty subset masks of {0..n-1} in increasing popcount order.

    Within one popcount, masks keep the order of the seed's Algo.-2
    enumeration (lexicographic over the action tuple, stable-sorted by
    popcount) so tie-breaking matches the uncached upper bound exactly.
    """
    masks = []
    for m in range(1, 1 << n):
        # the seed enumerates itertools.product tuples a=(a_0..a_{n-1});
        # tuple order corresponds to the integer with a_0 as the HIGH bit
        masks.append(m)
    # reconstruct seed order: product order == ascending on reversed bits
    def revbits(m: int) -> int:
        return int(sum(((m >> i) & 1) << (n - 1 - i) for i in range(n)))
    masks.sort(key=lambda m: (bin(m).count("1"), revbits(m)))
    return masks


@dataclass
class LatticeResult:
    """Every subset's answer for one image: the full 2^N-1 lattice.

    Rows follow ``popcount_masks(n)`` order (Algo.-2 enumeration: ascending
    popcount, seed tie-break), so a first-occurrence argmax over ``ap``
    reproduces ``best_subset``'s strict-improvement scan exactly.  Fused
    detections for all subsets live in ONE set of concatenated arrays
    sliced by ``offsets`` — ``detections(mask)`` rewraps a slice with
    ``Detections.fast``, bit-identical to the per-bitmask path's output.
    """
    masks: np.ndarray       # (M,) int64 — popcount_masks order
    row_of: np.ndarray      # (2^N,) int64 — mask -> row, -1 for mask 0
    ap: np.ndarray          # (M,) float64 per-image AP50 vs ``against``
    cost: np.ndarray        # (M,) float64 — the memoized cost() values
    n_dets: np.ndarray      # (M,) int64 fused detections per subset
    offsets: np.ndarray     # (M+1,) int64 slice bounds into the arrays below
    boxes: np.ndarray       # (F, 4) float32
    scores: np.ndarray      # (F,) float32
    labels: np.ndarray      # (F,) int32
    providers: np.ndarray   # (F,) int32 subset-relative provider ids
    against: str

    def __len__(self) -> int:
        return len(self.masks)

    def index_of(self, mask: int) -> int:
        row = int(self.row_of[int(mask)])
        if row < 0:
            raise KeyError(f"mask {mask} not in lattice")
        return row

    def detections(self, mask: int) -> Detections:
        lo, hi = self.slice_of(self.index_of(mask))
        return Detections.fast(self.boxes[lo:hi], self.scores[lo:hi],
                               self.labels[lo:hi], self.providers[lo:hi])

    def slice_of(self, row: int) -> Tuple[int, int]:
        return int(self.offsets[row]), int(self.offsets[row + 1])

    def ap_of(self, mask: int) -> float:
        return float(self.ap[self.index_of(mask)])

    def to_wire(self) -> Tuple[np.ndarray, ...]:
        """Flat array tuple for the serving shards' pipe (one lattice RPC
        instead of 2^N-1 per-subset RPCs); rebuild with ``from_wire``."""
        return (self.masks, self.row_of, self.ap, self.cost, self.n_dets,
                self.offsets, self.boxes, self.scores, self.labels,
                self.providers)

    @classmethod
    def from_wire(cls, wire: Sequence[np.ndarray],
                  against: str) -> "LatticeResult":
        return cls(*wire, against=against)


@dataclass
class _ImageTable:
    """Per-image precompute shared by every subset of that image."""
    boxes: np.ndarray          # (n_all, 4) all providers, provider order
    scores: np.ndarray         # (n_all,)
    labels: np.ndarray         # (n_all,)
    lengths: np.ndarray        # (N,) detections per provider
    row_provider: np.ndarray   # (n_all,) owning provider of each row
    iou: np.ndarray            # (n_all, n_all) pairwise IoU, computed once

    def subset_indices(self, bits: np.ndarray) -> np.ndarray:
        """Rows belonging to the selected providers (ascending, i.e. the
        same provider-block order as a fresh concat)."""
        return np.flatnonzero(bits[self.row_provider])


class SubsetEvaluationCore:
    """Cache + batch evaluator for (image, provider-subset) ensembles.

    One instance per (traces, voting, ablation, iou_thr) configuration —
    exactly the knobs that change the ensemble output.  ``use_kernel`` is
    ``"auto"`` (Pallas IoU kernel on accelerator backends, numpy twin on
    CPU), or an explicit bool.
    """

    def __init__(self, traces: TraceSet, *, voting: str = "affirmative",
                 ablation: str = "wbf", iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto"):
        self.traces = traces
        self.voting = voting
        self.ablation = ablation
        self.iou_thr = iou_thr
        self.use_kernel = resolve_use_kernel(use_kernel)
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1
        self._tables: Dict[int, _ImageTable] = {}
        self._masks: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._ens: Dict[Tuple[int, int], Detections] = {}
        self._ap: Dict[Tuple[int, int, str], float] = {}
        self._cost: Dict[int, float] = {}
        self._lattice: Dict[Tuple[int, str], LatticeResult] = {}
        self._lattice_order: Optional[np.ndarray] = None
        self._lattice_row_of: Optional[np.ndarray] = None
        self._lattice_cost: Optional[np.ndarray] = None
        self.stats = {"ens_hits": 0, "ens_misses": 0,
                      "ap_hits": 0, "ap_misses": 0, "tables": 0,
                      "lattice_hits": 0, "lattice_misses": 0}

    # -- per-image table ------------------------------------------------
    def _full_iou(self, boxes: np.ndarray) -> np.ndarray:
        if len(boxes) == 0:
            return np.zeros((0, 0), np.float32)
        if self.use_kernel:
            from repro.kernels.iou_matrix.ops import iou_matrix_op
            return np.asarray(iou_matrix_op(boxes, boxes))
        return iou_matrix(boxes, boxes)

    def _build_table(self, img_idx: int,
                     iou: Optional[np.ndarray] = None) -> _ImageTable:
        dets = self.traces.dets[img_idx]
        lengths = np.asarray([len(d) for d in dets], np.int64)
        # full-set merge: positional tags coincide with true provider ids
        boxes, scores, labels, row_provider = \
            merge_provider_detections(dets)
        if iou is None:
            iou = self._full_iou(boxes)
        self.stats["tables"] += 1
        return _ImageTable(boxes, scores, labels, lengths, row_provider, iou)

    def table(self, img_idx: int) -> _ImageTable:
        t = self._tables.get(img_idx)
        if t is None:
            t = self._tables[img_idx] = self._build_table(img_idx)
        return t

    def precompute(self, img_indices: Sequence[int]) -> None:
        """Build tables for many images; IoU matrices go through one batched
        kernel launch on the kernel path."""
        missing = [int(i) for i in img_indices if int(i) not in self._tables]
        if not missing:
            return
        if self.use_kernel:
            from repro.ensemble.pipeline import batch_iou_matrices
            boxes_list = [
                np.concatenate([d.boxes for d in self.traces.dets[i]],
                               axis=0) for i in missing]
            ious = batch_iou_matrices(boxes_list, use_kernel=True)
            for i, iou in zip(missing, ious):
                self._tables[i] = self._build_table(i, iou=iou)
        else:
            for i in missing:
                self._tables[i] = self._build_table(i)

    # -- memoized single-pair evaluation --------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def _mask_info(self, mask: int) -> Tuple[np.ndarray, np.ndarray]:
        """(selected provider ids, N-length bool bits) — memoized per mask."""
        hit = self._masks.get(mask)
        if hit is None:
            bits = np.asarray([(mask >> i) & 1
                               for i in range(self.n_providers)], bool)
            hit = self._masks[mask] = (np.flatnonzero(bits), bits)
        return hit

    def selected(self, mask: int) -> np.ndarray:
        return self._mask_info(mask)[0]

    def cost(self, mask: int) -> float:
        c = self._cost.get(mask)
        if c is None:
            bits = self._mask_info(mask)[1]
            c = self._cost[mask] = float(np.sum(self.costs * bits))
        return c

    def _lattice_row(self, img_idx: int) -> Optional[LatticeResult]:
        """Any cached lattice for this image — fused detections are
        ``against``-independent, so either reference's lattice serves."""
        for against in ("gt", "pseudo"):
            lat = self._lattice.get((img_idx, against))
            if lat is not None:
                return lat
        return None

    def ensemble(self, img_idx: int, mask: int) -> Detections:
        key = (img_idx, mask)
        hit = self._ens.get(key)
        if hit is not None:
            self.stats["ens_hits"] += 1
            return hit
        if mask:
            lat = self._lattice_row(img_idx)
            if lat is not None:
                # lattice rows back-fill the per-bitmask memo on demand:
                # warm-path callers see an ordinary cache hit
                self.stats["ens_hits"] += 1
                ens = self._ens[key] = lat.detections(mask)
                return ens
        self.stats["ens_misses"] += 1
        if mask == 0:
            ens = Detections.empty()
        else:
            t = self.table(img_idx)
            sel, bits = self._mask_info(mask)
            idx = t.subset_indices(bits)
            providers = np.repeat(
                np.arange(len(sel), dtype=np.int32), t.lengths[sel])
            ens = ensemble_from_arrays(
                t.boxes[idx], t.scores[idx], t.labels[idx], providers,
                len(sel), voting=self.voting, ablation=self.ablation,
                iou_thr=self.iou_thr, iou=t.iou[idx[:, None], idx])
        self._ens[key] = ens
        return ens

    def pseudo_gt(self, img_idx: int) -> Detections:
        """Ensemble of ALL providers — the w/o-gt reference (paper Sec. III)."""
        return self.ensemble(img_idx, self.full_mask)

    def reference(self, img_idx: int, against: str) -> Detections:
        if against == "gt":
            return self.traces.gts[img_idx]
        if against == "pseudo":
            return self.pseudo_gt(img_idx)
        raise ValueError(against)

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt") -> float:
        key = (img_idx, mask, against)
        hit = self._ap.get(key)
        if hit is not None:
            self.stats["ap_hits"] += 1
            return hit
        if mask:
            lat = self._lattice.get((img_idx, against))
            if lat is not None:
                self.stats["ap_hits"] += 1
                v = self._ap[key] = lat.ap_of(mask)
                return v
        self.stats["ap_misses"] += 1
        ens = self.ensemble(img_idx, mask)
        v = (image_ap50(ens, self.reference(img_idx, against))
             if len(ens) else 0.0)
        self._ap[key] = v
        return v

    def evaluate(self, img_idx: int, action: np.ndarray, *,
                 beta: float = 0.0,
                 against: str = "gt") -> Tuple[float, float, float]:
        """(reward, v=AP50, cost) with Eq.-5 semantics: r=-1 on empty."""
        mask = self.mask_of(action)
        cost = self.cost(mask)
        ens = self.ensemble(img_idx, mask)
        if len(ens) == 0:
            return -1.0, 0.0, cost
        v = self.ap50(img_idx, mask, against=against)
        return v + beta * cost, v, cost

    # -- batch APIs ------------------------------------------------------
    def evaluate_batch(self, img_indices: Sequence[int],
                       actions: np.ndarray, *, beta: float = 0.0,
                       against: str = "gt") -> Dict[str, np.ndarray]:
        """Evaluate action[t] on image img_indices[t] for a whole batch.

        Returns dict of (B,) arrays: reward, ap50, cost, plus the per-pair
        subset masks.  Tables for all images are precomputed first (one
        batched IoU launch on the kernel path); repeated (image, mask)
        pairs hit the memo.
        """
        imgs = [int(i) for i in img_indices]
        if not imgs:
            z = np.zeros(0, np.float64)
            return {"reward": z, "ap50": z.copy(), "cost": z.copy(),
                    "mask": np.zeros(0, np.int64)}
        actions = np.asarray(actions, np.float32).reshape(len(imgs), -1)
        self.precompute(imgs)
        B = len(imgs)
        reward = np.zeros(B, np.float64)
        ap = np.zeros(B, np.float64)
        cost = np.zeros(B, np.float64)
        masks = np.zeros(B, np.int64)
        for t, (img, a) in enumerate(zip(imgs, actions)):
            r, v, c = self.evaluate(img, a, beta=beta, against=against)
            reward[t], ap[t], cost[t], masks[t] = r, v, c, \
                self.mask_of(a)
        return {"reward": reward, "ap50": ap, "cost": cost, "mask": masks}

    def ensemble_rows(self, img_indices: Sequence[int],
                      masks: Sequence[int]) -> List[Tuple[np.ndarray, ...]]:
        """Wire contract of the serving shards: (boxes, scores, labels,
        providers) array tuples for each (image, mask) pair, tables
        precomputed in one batch first.  A worker process sends exactly
        these rows back over its pipe; the parent rewraps them with
        ``Detections.fast`` — raw arrays, because ``Detections`` validation
        and object overhead have no place on the IPC hot path."""
        imgs = [int(i) for i in img_indices]
        self.precompute([i for i, m in zip(imgs, masks) if int(m)])
        rows = []
        for img, m in zip(imgs, masks):
            ens = self.ensemble(img, int(m))
            rows.append((ens.boxes, ens.scores, ens.labels, ens.providers))
        return rows

    def __getstate__(self):
        """Pickle = configuration + traces, never the memo caches: a core
        crossing a process boundary arrives cold and shared-nothing (the
        caches are derivable, per-process, and would dwarf the payload).
        The serving shards ship TraceSets + snapshot recipes rather than
        whole cores, so this is the safety net for ANY future transport
        (and for user code) — not a path the process plane relies on."""
        state = dict(self.__dict__)
        state["_tables"] = {}
        state["_masks"] = {}
        state["_ens"] = {}
        state["_ap"] = {}
        state["_cost"] = {}
        state["_lattice"] = {}
        state["_lattice_order"] = None
        state["_lattice_row_of"] = None
        state["_lattice_cost"] = None
        state["stats"] = {k: 0 for k in self.stats}
        return state

    def ensemble_batch(self, img_indices: Sequence[int],
                       actions: np.ndarray) -> List[Detections]:
        imgs = [int(i) for i in img_indices]
        if not imgs:
            return []
        actions = np.asarray(actions, np.float32).reshape(len(imgs), -1)
        self.precompute(imgs)
        return [self.ensemble(img, self.mask_of(a))
                for img, a in zip(imgs, actions)]

    def best_subset(self, img_idx: int, masks: Sequence[int], *,
                    against: str = "gt") -> Tuple[int, float]:
        """First strict-improvement argmax over ``masks`` (Algo.-2 order):
        enumerate in the given order, keep a candidate only when its AP50
        strictly beats the incumbent — cheaper subsets (earlier in popcount
        order) win ties."""
        best_v, best_m = -1.0, masks[0]
        for m in masks:
            v = self.ap50(img_idx, m, against=against)
            if v > best_v:
                best_v, best_m = v, m
        return best_m, best_v

    # -- full-lattice evaluation -----------------------------------------
    def lattice_masks(self) -> np.ndarray:
        """All 2^N-1 subset masks in ``popcount_masks`` order (cached)."""
        if self._lattice_order is None:
            order = np.asarray(popcount_masks(self.n_providers), np.int64)
            row_of = np.full(1 << self.n_providers, -1, np.int64)
            row_of[order] = np.arange(len(order))
            self._lattice_order, self._lattice_row_of = order, row_of
        return self._lattice_order

    def _lattice_costs(self) -> np.ndarray:
        """(M,) per-row costs — the SAME memoized ``cost()`` floats the
        per-bitmask path hands out, so lattice consumers composing
        ap + beta * cost stay bit-identical to the loop path."""
        if self._lattice_cost is None:
            self._lattice_cost = np.asarray(
                [self.cost(int(m)) for m in self.lattice_masks()],
                np.float64)
        return self._lattice_cost

    def evaluate_lattice(self, img_idx: int, *,
                         against: str = "gt") -> LatticeResult:
        """Ensembles + AP50 + cost for ALL 2^N-1 subsets of one image in
        one vectorized pass (memoized per (image, against)).

        Subsets are laid out as a (2^N-1, N) bitmask matrix over the
        image's shared table; grouping, voting, WBF and the AP50 matching
        run as padded array ops with segment reductions over the subset
        axis.  Every row is bit-identical to the per-bitmask path
        (``ensemble`` / ``ap50``), and rows back-fill that memo lazily, so
        warm-path semantics are unchanged.  Non-WBF ablations fall back to
        the per-bitmask loop internally (same result shape).
        """
        img_idx = int(img_idx)
        key = (img_idx, against)
        hit = self._lattice.get(key)
        if hit is not None:
            self.stats["lattice_hits"] += 1
            return hit
        self.stats["lattice_misses"] += 1
        prior = self._lattice_row(img_idx)
        if prior is not None:
            ens_part = (prior.n_dets, prior.offsets, prior.boxes,
                        prior.scores, prior.labels, prior.providers)
        elif self.ablation == "wbf":
            ens_part = self._lattice_ensembles(img_idx)
        else:
            ens_part = self._lattice_ensembles_slow(img_idx)
        ap = self._lattice_ap(img_idx, ens_part, against)
        lat = LatticeResult(self.lattice_masks(), self._lattice_row_of,
                            ap, self._lattice_costs(), *ens_part,
                            against=against)
        self._lattice[key] = lat
        return lat

    def _lattice_ensembles_slow(self, img_idx: int):
        """Per-bitmask fallback (non-WBF ablations): still one call, still
        a full lattice, just built through the memoized scalar path."""
        rows = [self.ensemble(img_idx, int(m)) for m in self.lattice_masks()]
        n_dets = np.asarray([len(r) for r in rows], np.int64)
        offsets = np.concatenate([[0], np.cumsum(n_dets)])
        if len(rows):
            boxes = np.concatenate([r.boxes for r in rows], axis=0)
            scores = np.concatenate([r.scores for r in rows])
            labels = np.concatenate([r.labels for r in rows])
            provs = np.concatenate(
                [r.providers if r.providers is not None
                 else np.zeros(len(r), np.int32) for r in rows])
        else:       # pragma: no cover - n_providers >= 1 always
            e = Detections.empty()
            boxes, scores, labels, provs = e.boxes, e.scores, e.labels, \
                e.providers
        return n_dets, offsets, boxes, scores, labels, provs

    def _lattice_ensembles(self, img_idx: int):
        """Vectorized grouping + voting + WBF for every subset at once.

        The greedy grouping visits the image's merged rows ONCE in the
        full-table descending-score order (a subset's visit order is
        exactly that order filtered to its rows), tracking per (subset,
        row) representative flags and group ids; fusion then runs as one
        ``np.add.reduceat`` over (subset, group, member)-sorted segments —
        the same per-segment contents, in the same member order, as the
        per-bitmask ``wbf`` call, hence bit-identical fused arrays.
        """
        t = self.table(img_idx)
        masks = self.lattice_masks()
        M = len(masks)
        N = self.n_providers
        bits = ((masks[:, None] >> np.arange(N)) & 1).astype(bool)  # (M, N)
        popc = np.bitwise_count(masks)                              # (M,)
        n_all = len(t.scores)
        if n_all == 0:
            return (np.zeros(M, np.int64),
                    np.zeros(M + 1, np.int64),
                    np.zeros((0, 4), np.float32), np.zeros(0, np.float32),
                    np.zeros(0, np.int32), np.zeros(0, np.int32))
        visit = np.argsort(-t.scores, kind="stable")
        rank_of = np.empty(n_all, np.int64)
        rank_of[visit] = np.arange(n_all)
        # connectivity in float64, like the scalar greedy's tolist() floats
        conn = np.equal.outer(t.labels, t.labels) & \
            (t.iou.astype(np.float64) > float(self.iou_thr))
        present = bits[:, t.row_provider]                   # (M, n_all)
        rep = np.zeros((M, n_all), bool)
        grp = np.zeros((M, n_all), np.int64)
        n_groups = np.zeros(M, np.int64)
        for pos, i in enumerate(visit):
            seen = visit[:pos]
            js = seen[conn[i, seen]]        # matching reps, creation order
            has = present[:, i]
            if len(js):
                cand = rep[:, js]
                anyc = cand.any(axis=1)
                jsel = js[np.argmax(cand, axis=1)]
                joins = np.flatnonzero(has & anyc)
                grp[joins, i] = grp[joins, jsel[joins]]
                creates = np.flatnonzero(has & ~anyc)
            else:
                creates = np.flatnonzero(has)
            rep[creates, i] = True
            grp[creates, i] = n_groups[creates]
            n_groups[creates] += 1
        # flatten to (subset, group, visit-rank) order: one reduceat pass
        s_ids, i_ids = np.nonzero(present)
        g_ids = grp[s_ids, i_ids]
        order = np.lexsort((rank_of[i_ids], g_ids, s_ids))
        fs, fg, fi = s_ids[order], g_ids[order], i_ids[order]
        new_seg = np.empty(len(fs), bool)
        new_seg[0] = True
        new_seg[1:] = (fs[1:] != fs[:-1]) | (fg[1:] != fg[:-1])
        starts = np.flatnonzero(new_seg)
        sizes = np.diff(np.append(starts, len(fs)))
        seg_s = fs[starts]                          # owning subset per group
        sflat = t.scores[fi]
        gsum = np.add.reduceat(sflat, starts)
        denom = np.maximum(gsum.astype(np.float64), 1e-12).astype(np.float32)
        gid_flat = np.repeat(np.arange(len(starts)), sizes)
        w = sflat / denom[gid_flat]
        fused = np.add.reduceat(t.boxes[fi] * w[:, None], starts, axis=0)
        sc = (gsum / sizes.astype(np.float32)).astype(np.float64)
        # distinct providers per group (T) for the WBF correction + voting
        ormask = np.bitwise_or.reduceat(
            np.left_shift(np.int64(1), t.row_provider[fi].astype(np.int64)),
            starts)
        T = np.bitwise_count(ormask)
        nm = popc[seg_s]
        sc = np.where(nm > 1, sc * (np.minimum(T, nm) / nm), sc)
        first = fi[starts]
        flabels = t.labels[first].astype(np.int32)
        # subset-relative provider id of the first member, as ensemble()
        # tags rows with their position in the selected subset
        excl = np.cumsum(bits, axis=1) - bits               # (M, N)
        fprovs = excl[seg_s, t.row_provider[first]].astype(np.int32)
        if self.voting == "affirmative":
            keep = slice(None)
            kept_s = seg_s
        else:
            if self.voting == "consensus":
                keep = np.flatnonzero(T > nm / 2.0)
            elif self.voting == "unanimous":
                keep = np.flatnonzero(T == nm)
            else:
                raise ValueError(self.voting)
            kept_s = seg_s[keep]
        n_dets = np.bincount(kept_s, minlength=M).astype(np.int64)
        offsets = np.concatenate([[0], np.cumsum(n_dets)])
        return (n_dets, offsets, fused.astype(np.float32)[keep],
                sc.astype(np.float32)[keep], flabels[keep], fprovs[keep])

    def _lattice_ap(self, img_idx: int, ens_part, against: str
                    ) -> np.ndarray:
        """(M,) per-image AP50 for every lattice row, mirroring
        ``metrics._image_ap`` op for op (float64 scalars there, float64
        lanes here; sequential adds become exact +0.0-padded lane adds)."""
        n_dets, offsets, boxes, scores, labels, _ = ens_part
        M = len(n_dets)
        if against == "pseudo":
            full_row = int(self._lattice_row_of[self.full_mask])
            lo, hi = int(offsets[full_row]), int(offsets[full_row + 1])
            ref = Detections.fast(boxes[lo:hi], scores[lo:hi],
                                  labels[lo:hi], None)
        else:
            ref = self.reference(img_idx, against)
        gt_labels = ref.labels
        lab_list = sorted(set(gt_labels.tolist()))
        acc = np.zeros(M, np.float64)
        if not lab_list:
            return acc
        F = len(scores)
        if F:
            iou_all = iou_matrix(boxes, ref.boxes).astype(np.float64)
            sub_of = np.repeat(np.arange(M), n_dets)
        ranks = None
        for lab in lab_list:
            gi = np.flatnonzero(gt_labels == lab)
            n_lab = len(gi)
            sel = np.flatnonzero(labels == lab) if F else \
                np.zeros(0, np.int64)
            if len(sel) == 0:
                continue                    # every lane adds exactly 0.0
            sub_sel = sub_of[sel]
            o = np.lexsort((np.arange(len(sel)),
                            -scores[sel].astype(np.float64), sub_sel))
            ssub = sub_sel[o]
            counts = np.bincount(sub_sel, minlength=M)
            offs = np.concatenate([[0], np.cumsum(counts)])
            rank = np.arange(len(sel)) - offs[ssub]
            K = int(counts.max())
            P = np.full((M, K), -1, np.int64)
            P[ssub, rank] = sel[o]
            active = P >= 0
            rows = np.where(active, P, 0)
            taken = np.zeros((M, n_lab), bool)
            tp = np.zeros((M, K), bool)
            for r in range(K):
                cand = np.where(taken, -1.0, iou_all[rows[:, r]][:, gi])
                bj = n_lab - 1 - np.argmax(cand[:, ::-1], axis=1)
                matched = active[:, r] & \
                    (cand[np.arange(M), bj] >= 0.5)
                mi = np.flatnonzero(matched)
                taken[mi, bj[mi]] = True
                tp[:, r] = matched
            if ranks is None or len(ranks) < K:
                ranks = np.arange(1, K + 1, dtype=np.int64)
            tpc = np.cumsum(tp, axis=1).astype(np.int64)
            prec = np.where(active, tpc / ranks[:K], 0.0)
            prec = np.maximum.accumulate(prec[:, ::-1], axis=1)[:, ::-1]
            recall = tpc / n_lab
            inc = tp.copy()
            inc[:, 0] = True
            inc &= active
            cnt = np.searchsorted(RECALL_POINTS, recall, side="right")
            idxm = np.where(inc, np.arange(K)[None, :], -1)
            last = np.maximum.accumulate(idxm, axis=1)
            previdx = np.concatenate(
                [np.full((M, 1), -1, np.int64), last[:, :-1]], axis=1)
            prevcnt = np.where(
                previdx >= 0,
                np.take_along_axis(cnt, np.maximum(previdx, 0), axis=1), 0)
            contrib = np.where(inc, prec * (cnt - prevcnt), 0.0)
            apacc = np.zeros(M, np.float64)
            for r in range(K):      # sequential adds (stable summation)
                apacc = apacc + contrib[:, r]
            acc = acc + apacc / len(RECALL_POINTS)
        return acc / len(lab_list)

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Drop every cached artifact touching the given images (table,
        ensembles, AP entries, lattices) — the hook for in-place trace
        mutation, e.g. a scenario segment rewriting one provider's
        detections.  Returns the number of tables actually dropped."""
        drop = {int(i) for i in img_indices}
        dropped = 0
        for i in drop:
            if self._tables.pop(i, None) is not None:
                dropped += 1
        if drop:
            # pop the doomed keys instead of rebuilding the dicts: a
            # single-image invalidation must not cost O(total cache)
            for k in [k for k in self._ens if k[0] in drop]:
                del self._ens[k]
            for k in [k for k in self._ap if k[0] in drop]:
                del self._ap[k]
            # lattice rows also back-fill _ens/_ap lazily: the lattice
            # itself must go too, or a post-invalidation ensemble() would
            # resurrect stale rows from it
            for k in [k for k in self._lattice if k[0] in drop]:
                del self._lattice[k]
        return dropped

    def cache_sizes(self) -> Dict[str, int]:
        return {"tables": len(self._tables), "ensembles": len(self._ens),
                "ap_entries": len(self._ap), "lattices": len(self._lattice)}

    def config(self) -> Dict[str, object]:
        """The knobs that change ensemble output — enough to build an
        equivalent core (see ``ShardedSubsetEvaluationCore.like``)."""
        return {"voting": self.voting, "ablation": self.ablation,
                "iou_thr": self.iou_thr, "use_kernel": self.use_kernel}

    def cached_images(self) -> List[int]:
        return sorted(self._tables)


class ShardedSubsetEvaluationCore:
    """W shared-nothing ``SubsetEvaluationCore`` shards keyed by
    ``img_idx % W``.

    Each shard owns its own table/ensemble/AP dicts, so W worker threads
    (one per shard) can serve concurrent flushes without a lock and
    without ever contending on one dict.  The lookup path is merge-free:
    an image's home shard is a modulo, never a search, and since the
    assignment is total and deterministic no entry is ever duplicated
    across shards — aggregate memory equals the unsharded core's.

    The sharded core intentionally exposes the same single-pair surface
    (``ensemble`` / ``ap50`` / ``cost`` / ``evaluate`` / ``precompute``)
    as ``SubsetEvaluationCore`` by delegation, so callers can hold either.
    Thread safety is *by partition*: it is safe for different threads to
    touch different shards concurrently; two threads touching the same
    shard must be externally serialized (the async service runs one
    single-thread executor per shard).
    """

    def __init__(self, traces: TraceSet, *, n_shards: int = 4,
                 voting: str = "affirmative", ablation: str = "wbf",
                 iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto"):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.shards = [
            SubsetEvaluationCore(traces, voting=voting, ablation=ablation,
                                 iou_thr=iou_thr, use_kernel=use_kernel)
            for _ in range(self.n_shards)]
        self.traces = traces
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1

    @classmethod
    def like(cls, core: SubsetEvaluationCore,
             n_shards: int) -> "ShardedSubsetEvaluationCore":
        """A sharded core with the same ensemble configuration as ``core``
        (fresh, empty caches — sharding is a layout, not a migration)."""
        return cls(core.traces, n_shards=n_shards, **core.config())

    # -- shard addressing (the merge-free lookup path) -------------------
    def shard_id(self, img_idx: int) -> int:
        return int(img_idx) % self.n_shards

    def shard_of(self, img_idx: int) -> SubsetEvaluationCore:
        return self.shards[int(img_idx) % self.n_shards]

    def partition(self, img_indices: Sequence[int]
                  ) -> Dict[int, List[int]]:
        """shard id -> that shard's images, preserving request order.
        ``shard_id`` is the single source of the assignment rule."""
        groups: Dict[int, List[int]] = {}
        for i in img_indices:
            groups.setdefault(self.shard_id(i), []).append(int(i))
        return groups

    # -- delegated evaluation surface ------------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def precompute(self, img_indices: Sequence[int]) -> None:
        for sid, imgs in self.partition(img_indices).items():
            self.shards[sid].precompute(imgs)

    def ensemble(self, img_idx: int, mask: int) -> Detections:
        return self.shard_of(img_idx).ensemble(img_idx, mask)

    def pseudo_gt(self, img_idx: int) -> Detections:
        return self.shard_of(img_idx).pseudo_gt(img_idx)

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt") -> float:
        return self.shard_of(img_idx).ap50(img_idx, mask, against=against)

    def evaluate_lattice(self, img_idx: int, *,
                         against: str = "gt") -> LatticeResult:
        """Shard-local full-lattice evaluation: the image's home shard
        computes (and caches) all 2^N-1 rows in one pass."""
        return self.shard_of(img_idx).evaluate_lattice(img_idx,
                                                       against=against)

    def cost(self, mask: int) -> float:
        # mask costs are image-independent; shard 0 is their (sole) home
        return self.shards[0].cost(mask)

    def evaluate(self, img_idx: int, action: np.ndarray, *,
                 beta: float = 0.0,
                 against: str = "gt") -> Tuple[float, float, float]:
        return self.shard_of(img_idx).evaluate(img_idx, action, beta=beta,
                                               against=against)

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Per-shard invalidation through the same partition rule as every
        other delegated call, so entries are dropped exactly where they
        live."""
        dropped = 0
        for sid, imgs in self.partition(img_indices).items():
            dropped += self.shards[sid].invalidate_images(imgs)
        return dropped

    # -- aggregate introspection ----------------------------------------
    def cache_sizes(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.cache_sizes().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def shard_images(self) -> List[List[int]]:
        """Per-shard cached image ids — the corruption-check surface: every
        entry of ``shard_images()[s]`` must satisfy ``img % W == s``."""
        return [s.cached_images() for s in self.shards]
