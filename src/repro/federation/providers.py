"""Simulated MLaaS provider profiles.

The offline container cannot call AWS/Azure/GCP, so providers are simulated
with skill profiles calibrated to the paper's measurements (Sec. II):
AWS leads overall but returns nothing on bottle/cup/dining-table; Azure is
weakest on average yet best on exactly those categories; Google leads on
"book".  Every provider speaks its own label dialect (exercising the word
grouping stage) and charges 0.001 USD per request.

``scalability_providers`` reproduces the Tab. III setting: AWS/Azure/Google/
Alibaba + six synthetic services, one of which (MLaaS 5) is 20-30 AP50
points better than the rest.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.federation.vocab import COCO_TEMPLATE

# categories the paper calls out explicitly
_AWS_BLIND = {"bottle", "cup", "dining table"}
_AWS_SWEET = {"person", "chair", "car", "handbag"}
_AZURE_SWEET = {"cup", "bottle", "dining table"}
_GOOGLE_SWEET = {"book"}


@dataclass(frozen=True)
class ProviderProfile:
    """Immutable provider snapshot.

    Frozen on purpose: profiles are consumed as value objects by trace
    generation and the memoized subset-evaluation caches, so in-place
    mutation (e.g. by a scenario schedule) would silently alias cached
    state.  Derive variants through :meth:`replace`, which bumps ``rev``
    so two snapshots of the same provider are distinguishable, and key
    caches on :meth:`fingerprint`.
    """
    name: str
    base_recall: float
    sweet: Dict[str, float] = field(default_factory=dict)   # cat -> recall
    blind: frozenset = frozenset()
    box_jitter: float = 0.03
    fp_rate: float = 0.5            # expected false positives per image
    score_mu: float = 0.75
    score_sigma: float = 0.12
    cost_milli_usd: float = 1.0     # 0.001 USD per request
    dialect: int = 0                # which synonym variant this provider emits
    latency_ms: float = 350.0
    rev: int = 0                    # bumped by replace(): snapshot version

    def recall_for(self, category: str) -> float:
        if category in self.blind:
            return 0.0
        return self.sweet.get(category, self.base_recall)

    def replace(self, **changes) -> "ProviderProfile":
        """A new snapshot with ``changes`` applied and ``rev`` bumped
        (unless the caller pins ``rev`` explicitly)."""
        changes.setdefault("rev", self.rev + 1)
        return dataclasses.replace(self, **changes)

    def fingerprint(self, *, detection_only: bool = False) -> Tuple:
        """Hashable identity of this snapshot's behavior.

        ``detection_only=True`` drops the economic fields (cost, latency)
        and ``rev``, leaving exactly the knobs that shape the provider's
        detection stream — the cache key for regenerated traces.
        """
        fp = (self.name, self.base_recall,
              tuple(sorted(self.sweet.items())),
              tuple(sorted(self.blind)), self.box_jitter, self.fp_rate,
              self.score_mu, self.score_sigma, self.dialect)
        if detection_only:
            return fp
        return fp + (self.cost_milli_usd, self.latency_ms)


def default_providers() -> List[ProviderProfile]:
    aws = ProviderProfile(
        name="aws", base_recall=0.62,
        sweet={c: 0.78 for c in _AWS_SWEET}, blind=frozenset(_AWS_BLIND),
        box_jitter=0.025, fp_rate=1.6, dialect=0, latency_ms=320.0)
    azure = ProviderProfile(
        name="azure", base_recall=0.42,
        sweet={c: 0.80 for c in _AZURE_SWEET},
        box_jitter=0.045, fp_rate=2.2, score_mu=0.68, dialect=1,
        latency_ms=380.0)
    google = ProviderProfile(
        name="google", base_recall=0.50,
        sweet={c: 0.78 for c in _GOOGLE_SWEET},
        box_jitter=0.035, fp_rate=1.9, score_mu=0.71, dialect=2,
        latency_ms=410.0)
    return [aws, azure, google]


def scalability_providers() -> List[ProviderProfile]:
    """AWS/Azure/Google + Alibaba + six synthetic MLaaSes (Tab. III)."""
    base = default_providers()
    ali = ProviderProfile(name="alibaba", base_recall=0.68, box_jitter=0.03,
                          fp_rate=0.5, dialect=0, latency_ms=300.0)
    synth = []
    # (base_recall, jitter, fp) tuned so AP50 spans ~20..55 with MLaaS 5 on top
    for i, (rec, jit, fp) in enumerate([
            (0.80, 0.020, 0.30),    # MLaaS 4 — strong
            (0.92, 0.012, 0.15),    # MLaaS 5 — 20-30 points above the rest
            (0.34, 0.060, 0.90),    # MLaaS 6 — weak
            (0.88, 0.015, 0.20),    # MLaaS 7 — strong
            (0.40, 0.055, 0.80),    # MLaaS 8 — weak
            (0.56, 0.035, 0.50)]):  # MLaaS 9 — mid
        synth.append(ProviderProfile(
            name=f"mlaas{i + 4}", base_recall=rec, box_jitter=jit,
            fp_rate=fp, dialect=(i % 3), latency_ms=250.0 + 40 * i))
    return base + [ali] + synth


def lattice_stress_providers(n: int = 12) -> List[ProviderProfile]:
    """``n``-provider roster for full-lattice stress runs (N > 10).

    Extends :func:`scalability_providers` with deterministic synthetic
    services whose skill spreads mirror the Tab.-III synthetics, so an
    N=12 exact oracle exercises 4095 subsets per image without inventing
    a new calibration story.
    """
    roster = scalability_providers()
    if n <= len(roster):
        return roster[:n]
    # same (recall, jitter, fp) palette as the Tab.-III synthetics,
    # cycled deterministically — no RNG, rosters are reproducible
    palette = [(0.72, 0.025, 0.40), (0.48, 0.045, 0.70),
               (0.64, 0.030, 0.45), (0.36, 0.058, 0.85)]
    for i in range(len(roster), n):
        rec, jit, fp = palette[(i - len(roster)) % len(palette)]
        roster.append(ProviderProfile(
            name=f"mlaas{i}", base_recall=rec, box_jitter=jit,
            fp_rate=fp, dialect=(i % 3), latency_ms=240.0 + 35 * i))
    return roster


def provider_names(profiles: List[ProviderProfile]) -> List[str]:
    return [p.name for p in profiles]


ALL_CATEGORIES = list(COCO_TEMPLATE)
