"""Trace generation: the offline twin of the paper's recorded COCO-Val-2017
predictions from real cloud services.

Each trace image gets:
  * ground-truth objects (category frequencies zipf-skewed like COCO,
    "person" most frequent),
  * a rendered thumbnail (category-colored rectangles + noise) that the
    feature extractor consumes — the state genuinely carries category
    signal, so provider selection is learnable from pixels, as in the paper,
  * per-provider detections: recall/sweet-spot/blind-spot sampling from the
    provider profile, corner jitter, score noise, Poisson false positives,
    and labels emitted in the provider's own dialect (resolved later by the
    word-grouping stage).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.providers import ProviderProfile
from repro.federation.vocab import COCO_TEMPLATE, SYNONYMS, WordGrouper

IMG = 48


@dataclass
class RawDetections:
    """Provider output before word grouping: label *strings*."""
    boxes: np.ndarray
    scores: np.ndarray
    words: List[str]


@dataclass
class TraceSet:
    images: np.ndarray                       # (T, IMG, IMG, 3) float32 [0,1]
    gts: List[Detections]                    # canonical labels
    raw: List[List[RawDetections]]           # [image][provider]
    dets: List[List[Detections]]             # word-grouped, canonical labels
    providers: List[ProviderProfile]
    categories: List[str]
    # per-image per-object difficulty draws (the shared latent that decides
    # which providers see which objects) — kept so scenario dynamics can
    # regenerate a single provider's stream without re-rolling the world
    difficulties: Optional[List[np.ndarray]] = None

    def __len__(self) -> int:
        return len(self.gts)

    @property
    def n_providers(self) -> int:
        return len(self.providers)

    def costs(self) -> np.ndarray:
        return np.asarray([p.cost_milli_usd for p in self.providers],
                          np.float32)


def _palette(n: int) -> np.ndarray:
    rng = np.random.default_rng(1234)
    return rng.uniform(0.15, 1.0, size=(n, 3)).astype(np.float32)


def _dialect_word(cat: str, dialect: int) -> str:
    """Provider's name for a category: its dialect-th synonym (or canonical)."""
    syns = SYNONYMS.get(cat, [])
    options = [cat] + list(syns)
    return options[dialect % len(options)]


def category_features(images: np.ndarray, ncat: int) -> np.ndarray:
    """Matched-filter responses against the category palette.

    Plays the role of the paper's *pretrained* MobileNet: a pretrained
    backbone yields category-sensitive features; for rendered traces the
    equivalent is the per-category color response (plus the conv features
    the env also computes).  (T, H, W, 3) -> (T, ncat) float32.
    """
    pal = _palette(ncat)                                  # (ncat, 3)
    T = images.shape[0]
    px = images.reshape(T, -1, 3)                         # (T, P, 3)
    d2 = np.sum((px[:, :, None, :] - pal[None, None]) ** 2, axis=-1)
    resp = np.exp(-d2 / 0.05).mean(axis=1)                # (T, ncat)
    resp = resp / (resp.std(axis=0, keepdims=True) + 1e-6)
    return (resp - resp.mean(axis=0, keepdims=True)).astype(np.float32)


def _render(boxes: np.ndarray, labels: np.ndarray, palette: np.ndarray,
            rng) -> np.ndarray:
    img = rng.uniform(0.0, 0.08, size=(IMG, IMG, 3)).astype(np.float32)
    for b, lab in zip(boxes, labels):
        x1, y1, x2, y2 = (np.clip(b, 0, 1) * (IMG - 1)).astype(int)
        img[y1:y2 + 1, x1:x2 + 1] += palette[lab][None, None]
    return np.clip(img, 0.0, 1.0)


def provider_detections(p: ProviderProfile, boxes: np.ndarray,
                        labs: np.ndarray, difficulty: np.ndarray,
                        cats: Sequence[str], rng,
                        grouper: WordGrouper):
    """One provider's (raw, grouped) detections for one image.

    Consumes ``rng`` in exactly the order of the original trace-generation
    loop, so ``generate_traces`` keeps its historical stream bit-for-bit;
    scenario dynamics call it with a per-(provider, image) seeded rng to
    regenerate a single provider's detections deterministically after a
    profile change, against the image's stored ``difficulty`` latents.
    """
    ncat = len(cats)
    db, ds, dw = [], [], []
    for b, lab, diff in zip(boxes, labs, difficulty):
        cat = cats[lab]
        if diff < p.recall_for(cat):
            jit = rng.normal(0.0, p.box_jitter, 4)
            bb = np.clip(b + jit, 0.0, 1.0)
            if bb[2] <= bb[0] or bb[3] <= bb[1]:
                continue
            db.append(bb)
            ds.append(np.clip(rng.normal(p.score_mu, p.score_sigma),
                              0.05, 0.99))
            dw.append(_dialect_word(cat, p.dialect))
    for _ in range(rng.poisson(p.fp_rate)):
        c0 = rng.uniform(0.05, 0.8, 2)
        wh = rng.uniform(0.05, 0.3, 2)
        bb = np.array([c0[0], c0[1], min(c0[0] + wh[0], 1.0),
                       min(c0[1] + wh[1], 1.0)], np.float32)
        db.append(bb)
        ds.append(np.clip(rng.normal(0.66, 0.15), 0.05, 0.95))
        # false positives sometimes use irrelevant words (discarded
        # by grouping), sometimes a wrong category
        if rng.random() < 0.25:
            dw.append(rng.choice(["shadow", "texture", "pattern",
                                  "background", "blur"]))
        else:
            dw.append(_dialect_word(cats[int(rng.integers(ncat))],
                                    p.dialect))
    rawd = RawDetections(
        np.asarray(db, np.float32).reshape(-1, 4),
        np.asarray(ds, np.float32),
        dw)
    # word grouping -> canonical Detections (discard -1)
    gids = np.asarray(grouper.group_all(rawd.words), np.int32)
    keep = gids >= 0
    det = Detections(rawd.boxes[keep], rawd.scores[keep], gids[keep])
    return rawd, det


def generate_traces(providers: Sequence[ProviderProfile], n_images: int, *,
                    seed: int = 0, n_categories: int = 0,
                    mean_objects: float = 2.2) -> TraceSet:
    cats = COCO_TEMPLATE[:n_categories] if n_categories else COCO_TEMPLATE
    ncat = len(cats)
    palette = _palette(ncat)
    grouper = WordGrouper()
    rng = np.random.default_rng(seed)
    # COCO-like frequency skew with the paper's Fig.-1 top-10 categories
    # (person, chair, car, cup, bottle, dining table, book, handbag, ...)
    # most frequent — these are exactly the providers' sweet/blind spots.
    freq = 1.0 / np.arange(1, ncat + 1) ** 1.2
    top10 = ["person", "chair", "car", "cup", "bottle", "dining table",
             "book", "handbag", "bowl", "truck"]
    weights = [0.22, 0.07, 0.07, 0.065, 0.065, 0.06, 0.055, 0.05, 0.04,
               0.035]
    freq *= 0.28 / freq.sum()          # tail shares the remaining mass
    for c, w in zip(top10, weights):
        if c in cats:
            freq[cats.index(c)] = w
    freq /= freq.sum()

    images, gts, raw_all, det_all = [], [], [], []
    difficulties: List[np.ndarray] = []
    for t in range(n_images):
        n_obj = 1 + min(int(rng.poisson(mean_objects - 1)), 7)
        labs = rng.choice(ncat, size=n_obj, p=freq).astype(np.int32)
        cx = rng.uniform(0.15, 0.85, n_obj)
        cy = rng.uniform(0.15, 0.85, n_obj)
        w = rng.uniform(0.10, 0.45, n_obj)
        h = rng.uniform(0.10, 0.45, n_obj)
        boxes = np.stack([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2],
                         axis=1).clip(0, 1).astype(np.float32)
        scores = np.ones(n_obj, np.float32)
        gt = Detections(boxes, scores, labs)
        img = _render(boxes, labs, palette, rng)

        # Shared per-object difficulty: providers detect an object iff their
        # per-category skill exceeds its difficulty.  This makes providers
        # complementary BY CATEGORY (the paper's Fig. 1 structure) rather
        # than by independent coin-flips — adding a provider only adds true
        # positives where its sweet-spot categories appear, while its false
        # positives always come along.
        difficulty = rng.random(n_obj)

        per_provider_raw: List[RawDetections] = []
        per_provider_det: List[Detections] = []
        for p in providers:
            rawd, det = provider_detections(p, boxes, labs, difficulty,
                                            cats, rng, grouper)
            per_provider_raw.append(rawd)
            per_provider_det.append(det)
        images.append(img)
        gts.append(gt)
        raw_all.append(per_provider_raw)
        det_all.append(per_provider_det)
        difficulties.append(difficulty)

    return TraceSet(np.stack(images), gts, raw_all, det_all,
                    list(providers), list(cats), difficulties=difficulties)
