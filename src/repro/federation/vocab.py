"""Word grouping (paper Sec. IV-C): unify provider label vocabularies.

The user supplies a template T (the 80 COCO categories).  A synonym dataset
(embedded WordNet-style synsets + the manual additions the paper describes)
seeds a union-find; every provider word is resolved to a canonical group
index, and words irrelevant to the template are discarded (index -1).
"""
from __future__ import annotations

from typing import Dict, Iterable, List

COCO_TEMPLATE: List[str] = [
    "person", "bicycle", "car", "motorcycle", "airplane", "bus", "train",
    "truck", "boat", "traffic light", "fire hydrant", "stop sign",
    "parking meter", "bench", "bird", "cat", "dog", "horse", "sheep", "cow",
    "elephant", "bear", "zebra", "giraffe", "backpack", "umbrella",
    "handbag", "tie", "suitcase", "frisbee", "skis", "snowboard",
    "sports ball", "kite", "baseball bat", "baseball glove", "skateboard",
    "surfboard", "tennis racket", "bottle", "wine glass", "cup", "fork",
    "knife", "spoon", "bowl", "banana", "apple", "sandwich", "orange",
    "broccoli", "carrot", "hot dog", "pizza", "donut", "cake", "chair",
    "couch", "potted plant", "bed", "dining table", "toilet", "tv",
    "laptop", "mouse", "remote", "keyboard", "cell phone", "microwave",
    "oven", "toaster", "sink", "refrigerator", "book", "clock", "vase",
    "scissors", "teddy bear", "hair drier", "toothbrush",
]

# WordNet-style synsets restricted to the template, plus the manual
# additions the paper describes (Sec. IV-C: "we manually add the missing
# words within set A to the 80 groups").
SYNONYMS: Dict[str, List[str]] = {
    "person": ["human", "people", "pedestrian", "man", "woman"],
    "bicycle": ["bike", "cycle", "pushbike"],
    "car": ["automobile", "auto", "motorcar", "sedan"],
    "motorcycle": ["motorbike", "moped"],
    "airplane": ["aeroplane", "plane", "aircraft", "jet"],
    "bus": ["autobus", "coach", "omnibus"],
    "train": ["railway train", "locomotive"],
    "truck": ["lorry", "pickup truck", "van"],
    "boat": ["ship", "vessel", "watercraft"],
    "traffic light": ["traffic signal", "stoplight"],
    "fire hydrant": ["hydrant", "fireplug"],
    "stop sign": ["stop signal"],
    "bench": ["park bench"],
    "bird": ["fowl", "avian"],
    "cat": ["kitty", "house cat", "feline"],
    "dog": ["canine", "puppy", "hound"],
    "horse": ["pony", "equine"],
    "sheep": ["lamb", "ewe"],
    "cow": ["cattle", "ox", "bovine"],
    "elephant": ["pachyderm"],
    "bear": ["bruin"],
    "backpack": ["rucksack", "knapsack", "back pack"],
    "umbrella": ["parasol", "brolly"],
    "handbag": ["purse", "pocketbook", "bag"],
    "tie": ["necktie", "cravat"],
    "suitcase": ["luggage", "valise", "baggage"],
    "sports ball": ["ball", "football", "soccer ball"],
    "baseball bat": ["bat"],
    "baseball glove": ["mitt", "glove"],
    "tennis racket": ["racket", "racquet"],
    "bottle": ["flask", "water bottle"],
    "wine glass": ["wineglass", "goblet"],
    "cup": ["mug", "teacup", "coffee cup"],
    "bowl": ["basin", "dish"],
    "couch": ["sofa", "settee", "lounge"],
    "potted plant": ["houseplant", "pot plant", "plant"],
    "bed": ["mattress"],
    "dining table": ["table", "dinner table", "desk"],
    "toilet": ["lavatory", "commode", "wc"],
    "tv": ["television", "tvmonitor", "tv monitor", "telly"],
    "laptop": ["notebook computer", "laptop computer"],
    "mouse": ["computer mouse"],
    "remote": ["remote control", "clicker"],
    "keyboard": ["computer keyboard"],
    "cell phone": ["mobile phone", "cellphone", "smartphone", "phone"],
    "microwave": ["microwave oven"],
    "oven": ["stove", "cooker"],
    "sink": ["washbasin", "basin sink"],
    "refrigerator": ["fridge", "icebox"],
    "book": ["novel", "paperback"],
    "clock": ["timepiece", "wall clock"],
    "vase": ["urn"],
    "scissors": ["shears", "clippers"],
    "teddy bear": ["teddy", "plush bear", "stuffed bear"],
    "hair drier": ["hair dryer", "blow dryer"],
    "toothbrush": ["tooth brush"],
}


class _UnionFind:
    def __init__(self):
        self.parent: Dict[str, str] = {}

    def find(self, x: str) -> str:
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: str, b: str) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def _norm(w: str) -> str:
    return " ".join(w.strip().lower().replace("-", " ").replace("_", " ")
                    .split())


class WordGrouper:
    """Maps arbitrary provider category names to canonical template ids."""

    def __init__(self, template: Iterable[str] = COCO_TEMPLATE,
                 synonyms: Dict[str, List[str]] = SYNONYMS,
                 manual_additions: Dict[str, str] | None = None):
        self.template = [_norm(t) for t in template]
        uf = _UnionFind()
        for t in self.template:
            uf.find(t)
        for canon, syns in synonyms.items():
            for s in syns:
                uf.union(_norm(canon), _norm(s))
        if manual_additions:
            for word, canon in manual_additions.items():
                uf.union(_norm(canon), _norm(word))
        self._uf = uf
        self._canon_index = {t: i for i, t in enumerate(self.template)}
        # resolve every known word to a template index
        self._cache: Dict[str, int] = {}
        for w in list(uf.parent):
            self._cache[w] = self._resolve(w)

    def _resolve(self, w: str) -> int:
        root = self._uf.find(w)
        # root may not be the template word itself; scan its class
        if root in self._canon_index:
            return self._canon_index[root]
        for t, i in self._canon_index.items():
            if self._uf.find(t) == root:
                return i
        return -1

    def to_group(self, word: str) -> int:
        """Canonical group id for a provider word, or -1 (discard)."""
        w = _norm(word)
        if w not in self._cache:
            if w in self._uf.parent:
                gid = self._resolve(w)
            else:
                # collapsed-form fallback: "motor bike" <-> "motorbike"
                collapsed = w.replace(" ", "")
                gid = -1
                for known in self._uf.parent:
                    if known.replace(" ", "") == collapsed:
                        gid = self._resolve(known)
                        break
            self._cache[w] = gid
        return self._cache[w]

    def group_all(self, words: Iterable[str]) -> List[int]:
        return [self.to_group(w) for w in words]

    @property
    def num_groups(self) -> int:
        return len(self.template)
