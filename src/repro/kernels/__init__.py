# Pallas TPU kernels for the system's compute hot-spots.
# Each kernel package: <name>/kernel.py (pl.pallas_call + BlockSpec),
# <name>/ops.py (jit'd public wrapper), <name>/ref.py (pure-jnp oracle).
#
#   iou_matrix      — pairwise box IoU tiles (ensemble/word-grouping hot-spot,
#                     the paper's voting stage is O(r^2) IoU tests per image)
#   flash_attention — online-softmax blocked attention (32k prefill hot-spot;
#                     causal + sliding-window)
#   ssd_scan        — Mamba-2 SSD chunk scan with VMEM-carried chunk state
