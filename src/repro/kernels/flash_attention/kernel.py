"""Blocked online-softmax (flash) attention, Pallas TPU.

Grid layout: (batch, heads, num_q_blocks, num_kv_blocks) with the KV block
dimension INNERMOST and sequential ("arbitrary" TPU grid semantics), so the
running-softmax state for one query block — row max m, row sum l, and the
f32 output accumulator — lives in VMEM scratch that persists across the KV
sweep.  Block shapes: q/o tiles (BQ, hd), k/v tiles (BK, hd); with
BQ=BK=128 and hd=128 the working set is 4 tiles x 64 KiB + the (128,128)
f32 score tile ~= 0.4 MiB — far under VMEM, leaving room for Mosaic's
double buffering of the k/v streams.  The MXU sees two (BQ,hd)x(hd,BK)
contractions per step.

Causal and sliding-window masks are applied from global indices; fully
masked KV blocks are skipped with pl.when (they still DMA, the roofline win
on TPU comes from the skipped MXU work — a production variant would also
prune the grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, num_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    # skip fully-masked KV blocks (strictly above the causal diagonal)
    live = (iq * block_q + block_q - 1 >= ik * block_k) if causal \
        else (ik >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == num_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd).  Softmax scale = hd^-0.5."""
    B, H, S, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    n_q = pl.cdiv(S, bq)
    n_k = pl.cdiv(S, bk)
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, num_kv=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
