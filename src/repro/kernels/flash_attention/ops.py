"""Public flash-attention wrapper.

Accepts the model-side (B, S, H, hd) layout with GQA (K <= H kv heads),
broadcasts KV groups, and dispatches to the Pallas kernel (interpret mode
on CPU).  hd should be a multiple of 128 lanes on real TPU; interpret mode
accepts anything.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = jnp.moveaxis(q, 2, 1)     # (B, H, S, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    interpret = jax.default_backend() == "cpu"
    out = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
