"""Pure-jnp oracle: exact softmax attention with causal/window masks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd), fp32 softmax."""
    B, H, S, hd = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)
