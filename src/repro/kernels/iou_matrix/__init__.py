from repro.kernels.iou_matrix.ops import iou_matrix_op  # noqa: F401
