"""Pairwise box-IoU Pallas kernel.

Tiles the (M, N) IoU matrix into (BM, BN) VMEM blocks; each grid step loads
BM "row" boxes and BN "column" boxes (x1,y1,x2,y2 in separate lanes) and
computes the tile with pure VPU ops — there is no contraction, so the MXU is
idle and the kernel is bandwidth/VPU bound by design.  Box tiles are tiny
(BM x 4), so VMEM pressure is the (BM, BN) f32 output tile: 128x512x4 =
256 KiB, comfortably inside the ~16 MiB/core budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _iou_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                        # (BM, 4)
    b = b_ref[...]                        # (BN, 4)
    ax1, ay1, ax2, ay2 = a[:, 0], a[:, 1], a[:, 2], a[:, 3]
    bx1, by1, bx2, by2 = b[:, 0], b[:, 1], b[:, 2], b[:, 3]
    x1 = jnp.maximum(ax1[:, None], bx1[None, :])
    y1 = jnp.maximum(ay1[:, None], by1[None, :])
    x2 = jnp.minimum(ax2[:, None], bx2[None, :])
    y2 = jnp.minimum(ay2[:, None], by2[None, :])
    inter = jnp.maximum(x2 - x1, 0.0) * jnp.maximum(y2 - y1, 0.0)
    area_a = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
    area_b = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
    union = area_a[:, None] + area_b[None, :] - inter
    out_ref[...] = jnp.where(union > 0.0,
                             inter / jnp.maximum(union, 1e-12), 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def iou_matrix_pallas(boxes_a: jnp.ndarray, boxes_b: jnp.ndarray, *,
                      block_m: int = 128, block_n: int = 512,
                      interpret: bool = False) -> jnp.ndarray:
    """boxes_a: (M, 4), boxes_b: (N, 4) -> (M, N) f32 IoU."""
    M, N = boxes_a.shape[0], boxes_b.shape[0]
    bm, bn = min(block_m, M), min(block_n, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn))
    return pl.pallas_call(
        _iou_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(boxes_a.astype(jnp.float32), boxes_b.astype(jnp.float32))
