"""Public wrapper: picks Pallas-on-TPU or interpret-on-CPU automatically."""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.iou_matrix.kernel import iou_matrix_pallas

# one-time flag: the numpy-twin fallback warns on its first use only
_FALLBACK_WARNED = False


def iou_matrix_op(boxes_a, boxes_b, *, block_m: int = 128,
                  block_n: int = 512) -> jnp.ndarray:
    """(M,4) x (N,4) -> (M,N) IoU via the Pallas kernel (interpret on CPU).

    Block sizes are clamped to the input sizes (a 128-wide block over a
    3-box input is a lowering error on real backends), and any exception
    out of kernel lowering/execution falls back to the numpy twin — the
    kernel's bitwise oracle — with a one-time warning.
    """
    global _FALLBACK_WARNED
    a = jnp.asarray(boxes_a, jnp.float32).reshape(-1, 4)
    b = jnp.asarray(boxes_b, jnp.float32).reshape(-1, 4)
    M, N = int(a.shape[0]), int(b.shape[0])
    if M == 0 or N == 0:
        return jnp.zeros((M, N), jnp.float32)
    interpret = jax.default_backend() == "cpu"
    bm, bn = min(block_m, M), min(block_n, N)
    try:
        return iou_matrix_pallas(a, b, block_m=bm, block_n=bn,
                                 interpret=interpret)
    except Exception as e:  # lowering/unsupported-backend failures
        if not _FALLBACK_WARNED:
            _FALLBACK_WARNED = True
            warnings.warn(
                "Pallas IoU kernel failed to lower/run "
                f"({type(e).__name__}: {e}); falling back to the numpy "
                "twin for this process", RuntimeWarning, stacklevel=2)
        from repro.ensemble.boxes import iou_matrix
        return jnp.asarray(iou_matrix(np.asarray(a), np.asarray(b)),
                           jnp.float32)
