"""Public wrapper: picks Pallas-on-TPU or interpret-on-CPU automatically."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.iou_matrix.kernel import iou_matrix_pallas


def iou_matrix_op(boxes_a, boxes_b, *, block_m: int = 128,
                  block_n: int = 512) -> jnp.ndarray:
    """(M,4) x (N,4) -> (M,N) IoU via the Pallas kernel (interpret on CPU)."""
    a = jnp.asarray(boxes_a, jnp.float32).reshape(-1, 4)
    b = jnp.asarray(boxes_b, jnp.float32).reshape(-1, 4)
    if a.shape[0] == 0 or b.shape[0] == 0:
        return jnp.zeros((a.shape[0], b.shape[0]), jnp.float32)
    interpret = jax.default_backend() == "cpu"
    return iou_matrix_pallas(a, b, block_m=block_m, block_n=block_n,
                             interpret=interpret)
