"""Pure-jnp oracle for the IoU kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def iou_matrix_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    x1 = jnp.maximum(a[:, None, 0], b[None, :, 0])
    y1 = jnp.maximum(a[:, None, 1], b[None, :, 1])
    x2 = jnp.minimum(a[:, None, 2], b[None, :, 2])
    y2 = jnp.minimum(a[:, None, 3], b[None, :, 3])
    inter = jnp.maximum(x2 - x1, 0.0) * jnp.maximum(y2 - y1, 0.0)
    area = lambda bb: (jnp.maximum(bb[:, 2] - bb[:, 0], 0.0)  # noqa: E731
                       * jnp.maximum(bb[:, 3] - bb[:, 1], 0.0))
    union = area(a)[:, None] + area(b)[None, :] - inter
    return jnp.where(union > 0.0, inter / jnp.maximum(union, 1e-12), 0.0)
