"""Mamba-2 SSD chunk scan, Pallas TPU.

Grid: (batch, heads, num_chunks) with the CHUNK dimension innermost and
sequential, so the running (hd, N) state for one (batch, head) pair lives
in f32 VMEM scratch carried across the chunk sweep — the TPU-native
equivalent of the paper's inter-chunk recurrence (the GPU version leans on
warp-level scans; here the carry is simply scratch persistence across
sequential grid steps, and the intra-chunk work is two (Q,N)x(N,Q)-shaped
MXU contractions plus a (Q,Q)x(Q,hd) weighted gather).

Block shapes per step: x (Q, hd), dtA/dt (Q,), B/C (Q, N), out (Q, hd),
state scratch (hd, N).  With Q=128, hd=64, N=128: ~0.3 MiB — VMEM-safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dta_ref, dt_ref, b_ref, c_ref, o_ref, st_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (Q, hd)
    dta = dta_ref[0, 0, 0].astype(jnp.float32)   # (Q,)  = dt * A (negative)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    a_cs = jnp.cumsum(dta)                       # (Q,) inclusive
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(a_cs[i]-a_cs[j]) * dt[j], i>=j
    li = a_cs[:, None] - a_cs[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iq >= jq, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    M = cb * L * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))  # (Q, hd)

    # inter-chunk: y_i += (C_i * exp(a_cs[i])) @ state^T
    state = st_ref[...]                           # (hd, N)
    c_scaled = Cm * jnp.exp(a_cs)[:, None]
    y_inter = jax.lax.dot_general(c_scaled, state,
                                  (((1,), (1,)), ((), ())))       # (Q, hd)
    o_ref[0, 0, 0] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update: state' = exp(sum a) * state + sum_j w_j * x_j (x) B_j
    decay_end = jnp.exp(a_cs[-1] - a_cs)          # (Q,)
    w = dt * decay_end
    xw = x * w[:, None]                           # (Q, hd)
    upd = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())))   # (hd, N)
    st_ref[...] = jnp.exp(a_cs[-1]) * state + upd


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan_pallas(x, dta, dt, Bm, Cm, *, interpret: bool = False):
    """Chunked SSD.

    x:   (B, H, NC, Q, hd)   inputs per head
    dta: (B, H, NC, Q)       dt * A (A negative)
    dt:  (B, H, NC, Q)
    Bm:  (B, NC, Q, N)       shared across heads (G=1)
    Cm:  (B, NC, Q, N)
    Returns y: (B, H, NC, Q, hd).
    """
    B, H, NC, Q, hd = x.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, hd),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, NC, Q, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dta, dt, Bm, Cm)
