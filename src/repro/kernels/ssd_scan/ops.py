"""Public SSD wrapper matching the model-side (B, S, nh, hd) layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


def ssd_scan(xh, dt, A, Bmat, Cmat, *, chunk: int = 128):
    """Same contract as models.ssm.ssd_chunked (y only).

    xh: (B, S, nh, hd); dt: (B, S, nh) f32; A: (nh,) f32 negative;
    Bmat/Cmat: (B, S, N).
    """
    B, S, nh, hd = xh.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    NC = S // Q
    x = jnp.moveaxis(xh, 2, 1).reshape(B, nh, NC, Q, hd)
    dth = jnp.moveaxis(dt.astype(jnp.float32), 2, 1).reshape(B, nh, NC, Q)
    dta = dth * A[None, :, None, None]
    Bm = Bmat.astype(jnp.float32).reshape(B, NC, Q, N)
    Cm = Cmat.astype(jnp.float32).reshape(B, NC, Q, N)
    interpret = jax.default_backend() == "cpu"
    y = ssd_scan_pallas(x.astype(jnp.float32), dta, dth, Bm, Cm,
                        interpret=interpret)
    return jnp.moveaxis(y.reshape(B, nh, S, hd), 1, 2)
