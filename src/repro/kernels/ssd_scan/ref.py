"""Oracles for the SSD kernel.

``ssd_ref`` mirrors models.ssm.ssd_chunked (the production jnp path);
``ssd_naive`` is the O(S^2)-free sequential recurrence — the ground truth
both the kernel and the chunked path must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(xh, dt, A, Bmat, Cmat, chunk: int):
    y, final = ssd_chunked(xh, dt, A, Bmat, Cmat, chunk)
    return y


def ssd_naive(xh, dt, A, Bmat, Cmat):
    """Token-by-token recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    B, S, nh, hd = xh.shape
    N = Bmat.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                 # (B,nh,hd),(B,nh),(B,N),(B,N)
        decay = jnp.exp(dt_t * A)                 # (B,nh)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, b_t, x_t)
        h = decay[:, :, None, None] * h + upd
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y_t

    h0 = jnp.zeros((B, nh, hd, N), f32)
    xs = (jnp.moveaxis(xh.astype(f32), 1, 0),
          jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bmat.astype(f32), 1, 0),
          jnp.moveaxis(Cmat.astype(f32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)                 # (B,S,nh,hd)
