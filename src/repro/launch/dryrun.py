import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) with
ShapeDtypeStruct inputs on 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Output: memory_analysis / cost_analysis / collective-byte summary per combo,
appended as JSON records (consumed by benchmarks/roofline_report.py).
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs.base import ARCH_IDS, SHAPES, get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_dryrun  # noqa: E402
from repro.roofline.analysis import HW, collective_bytes, model_flops, \
    roofline_terms  # noqa: E402


def should_skip(arch_id: str, shape_id: str):
    """long_500k only for sub-quadratic paths — see DESIGN.md.

    All 10 assigned archs qualify (native state/latent or sliding-window),
    so nothing is skipped; the hook stays for future full-attention archs.
    """
    return None


def run_one(arch_id: str, shape_id: str, *, multi_pod: bool,
            param_mode: str = "", extra_tag: str = "",
            layers: int = 0) -> dict:
    cfg = get_arch(arch_id)
    if layers:
        # reduced-depth twin for the scan-trip-count flops correction
        # (benchmarks/roofline_correct.py): XLA cost analysis counts a
        # while-loop body once, so per-layer costs are recovered by a
        # two-point extrapolation over the layer count.
        import dataclasses
        kw = {"num_layers": layers}
        if cfg.encoder_layers:
            kw["encoder_layers"] = layers
        cfg = dataclasses.replace(cfg, **kw)
    shape = get_shape(shape_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch_id, "shape": shape_id,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "tag": extra_tag}
    t0 = time.time()
    try:
        fn, arg_specs = build_dryrun(cfg, shape, mesh,
                                     param_mode=param_mode)
        with mesh:
            lowered = jax.jit(fn).lower(*arg_specs)
            compiled = lowered.compile()
        rec["lower_compile_s"] = round(time.time() - t0, 1)
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):       # older jax: list of dicts
            ca = ca[0] if ca else {}
        rec["flops_per_dev"] = float(ca.get("flops", 0.0))
        rec["bytes_per_dev"] = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        coll = collective_bytes(compiled.as_text())
        rec["collective_bytes_per_dev"] = coll
        n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                      else 1)
        rec["model_flops_total"] = model_flops(cfg, n_tok,
                                               train=shape.kind == "train")
        chips = 1
        for s in mesh.devices.shape:
            chips *= s
        rec["chips"] = chips
        rec["model_flops_per_dev"] = rec["model_flops_total"] / chips
        rec["useful_flops_ratio"] = (rec["model_flops_per_dev"] /
                                     rec["flops_per_dev"]
                                     if rec["flops_per_dev"] else 0.0)
        rec.update(roofline_terms(rec["flops_per_dev"], rec["bytes_per_dev"],
                                  coll.get("total", 0.0)))
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--param-mode", default="", choices=["", "tp", "2d"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    records = []
    for arch_id, shape_id in combos:
        rec = run_one(arch_id, shape_id, multi_pod=args.multi_pod,
                      param_mode=args.param_mode, extra_tag=args.tag,
                      layers=args.layers)
        records.append(rec)
        status = rec["status"]
        extra = (f" flops/dev={rec.get('flops_per_dev', 0):.3e}"
                 f" coll={rec.get('collective_bytes_per_dev', {}).get('total', 0):.3e}B"
                 f" dom={rec.get('dominant', '-')}"
                 if status == "ok" else f" {rec.get('error', '')[:200]}")
        print(f"[dryrun] {arch_id} x {shape_id} x {rec['mesh']}: "
              f"{status}{extra}", flush=True)
        if status == "fail":
            print(rec.get("traceback", ""), flush=True)
        jax.clear_caches()
        if args.out:
            with open(args.out, "a") as f:
                slim = {k: v for k, v in rec.items() if k != "traceback"}
                f.write(json.dumps(slim) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    print(f"[dryrun] {n_ok}/{len(records)} combos OK")
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
