"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import; everything else sees the real (single) device.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)          # 256 chips
MULTI_POD = (2, 16, 16)        # 2 pods x 256 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
