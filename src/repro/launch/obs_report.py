"""Render an observability run directory into human-readable summaries.

  PYTHONPATH=src python -m repro.launch.obs_report RUNDIR

Reads whichever artifacts exist under ``RUNDIR`` (all optional):

  * ``metrics.json``      — counter/gauge tables + histogram p50/p99
                            (``metrics.prom``, its Prometheus text twin,
                            is used as fallback; ``--prom FILE`` renders
                            a saved ``/metrics`` scrape directly)
  * ``serving_log.jsonl`` — per-regime request/cost/latency/AP summary
                            with flush-reason and per-provider fee
                            breakdowns (the off-policy-evaluation input;
                            see docs/observability.md)
  * ``trace.jsonl``       — per-span-name count and duration percentiles
  * ``events.jsonl``      — the scenario/training event stream

The summarizers are plain functions over plain dicts so tests (and
downstream off-policy tooling) can call them directly.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.obs import hist_quantile, read_serving_log


def _pct(vals: List[float], q: float) -> float:
    if not vals:
        return 0.0
    vs = sorted(vals)
    idx = min(int(q * len(vs)), len(vs) - 1)
    return vs[idx]


def load_run(run_dir: str) -> Dict:
    """Load every artifact present under ``run_dir``."""
    out: Dict = {"dir": run_dir, "metrics": None, "serving": [],
                 "spans": [], "events": []}
    mpath = os.path.join(run_dir, "metrics.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            out["metrics"] = json.load(f)
    else:
        # a scrape-only run (or an external Prometheus dump) still
        # renders: the text twin carries everything but exact min/max
        ppath = os.path.join(run_dir, "metrics.prom")
        if os.path.exists(ppath):
            from repro.obs.prom import parse_prometheus
            with open(ppath) as f:
                out["metrics"] = parse_prometheus(f.read())
    spath = os.path.join(run_dir, "serving_log.jsonl")
    if os.path.exists(spath):
        out["serving"] = read_serving_log(spath)
    for name, key in (("trace.jsonl", "spans"),
                      ("events.jsonl", "events")):
        path = os.path.join(run_dir, name)
        if os.path.exists(path):
            with open(path) as f:
                out[key] = [json.loads(ln) for ln in f if ln.strip()]
    return out


def serving_summary(records: List[dict]) -> Dict[str, dict]:
    """Per-regime (segment) aggregation of serving-log records.

    Keys are ``"seg<k>"`` (or ``"all"`` for records served off-pool);
    each value reports request count, total/mean cost, latency p50/p99,
    mean AP50 over scored requests, flush-reason counts, and summed
    per-provider fees.
    """
    by_seg: Dict[str, dict] = {}
    for rec in records:
        key = "all" if rec.get("seg") is None else f"seg{rec['seg']}"
        s = by_seg.setdefault(key, {
            "requests": 0, "cost_total": 0.0, "_lat": [], "_ap": [],
            "flush_reasons": {}, "fees_by_provider": {}, "empty": 0})
        s["requests"] += 1
        s["cost_total"] += rec["cost_milli_usd"]
        s["_lat"].append(rec["latency_ms"])
        if rec.get("ap50") is not None:
            s["_ap"].append(rec["ap50"])
        if not rec.get("providers"):
            s["empty"] += 1
        reason = rec.get("flush_reason")
        if reason:
            s["flush_reasons"][reason] = \
                s["flush_reasons"].get(reason, 0) + 1
        for name, fee in rec.get("fees", {}).items():
            s["fees_by_provider"][name] = \
                s["fees_by_provider"].get(name, 0.0) + fee
    for s in by_seg.values():
        n = max(s["requests"], 1)
        s["cost_per_request"] = round(s["cost_total"] / n, 4)
        s["cost_total"] = round(s["cost_total"], 3)
        s["latency_p50_ms"] = round(_pct(s["_lat"], 0.50), 2)
        s["latency_p99_ms"] = round(_pct(s["_lat"], 0.99), 2)
        s["mean_ap50"] = round(sum(s["_ap"]) / len(s["_ap"]), 4) \
            if s["_ap"] else None
        s["fees_by_provider"] = {k: round(v, 3) for k, v in
                                 sorted(s["fees_by_provider"].items())}
        del s["_lat"], s["_ap"]
    return dict(sorted(by_seg.items()))


def span_summary(spans: List[dict]) -> Dict[str, dict]:
    """Per-span-name count + duration percentiles."""
    by_name: Dict[str, List[float]] = {}
    for sp in spans:
        by_name.setdefault(sp["name"], []).append(sp["dur_ms"])
    return {name: {"count": len(ds),
                   "p50_ms": round(_pct(ds, 0.50), 3),
                   "p99_ms": round(_pct(ds, 0.99), 3),
                   "max_ms": round(max(ds), 3)}
            for name, ds in sorted(by_name.items())}


def metrics_lines(snap: dict) -> List[str]:
    lines = []
    for name, v in sorted(snap.get("counters", {}).items()):
        lines.append(f"  counter  {name:<40s} {v:g}")
    for name, v in sorted(snap.get("gauges", {}).items()):
        lines.append(f"  gauge    {name:<40s} {v:g}")
    for name, h in sorted(snap.get("histograms", {}).items()):
        if not h["count"]:
            continue
        p50 = hist_quantile(h, 0.50)
        p99 = hist_quantile(h, 0.99)
        # Prometheus-parsed snapshots carry no exact max (the format
        # doesn't transport it) — report what survives
        hmax = "n/a" if h["max"] is None else f"{h['max']:.3f}"
        lines.append(
            f"  hist     {name:<40s} n={h['count']} "
            f"mean={h['sum'] / h['count']:.3f} "
            f"p50={p50:.3f} p99={p99:.3f} max={hmax}")
    return lines


def render(run: Dict) -> str:
    """The full text report for one run directory."""
    parts = [f"== obs report: {run['dir']} =="]
    if run["metrics"]:
        parts.append("-- metrics --")
        parts += metrics_lines(run["metrics"])
    if run["serving"]:
        parts.append(f"-- serving log ({len(run['serving'])} requests) --")
        for seg, s in serving_summary(run["serving"]).items():
            ap = "n/a" if s["mean_ap50"] is None else f"{s['mean_ap50']:.3f}"
            reasons = ",".join(f"{k}={v}" for k, v in
                               sorted(s["flush_reasons"].items())) or "n/a"
            parts.append(
                f"  {seg}: {s['requests']} reqs "
                f"cost/req={s['cost_per_request']:.3f}mUSD "
                f"lat p50={s['latency_p50_ms']:.0f}ms "
                f"p99={s['latency_p99_ms']:.0f}ms ap50={ap} "
                f"flushes[{reasons}]")
            parts.append(f"    fees: {s['fees_by_provider']}")
    if run["spans"]:
        parts.append(f"-- trace spans ({len(run['spans'])}) --")
        for name, s in span_summary(run["spans"]).items():
            parts.append(f"  {name:<14s} n={s['count']} "
                         f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms "
                         f"max={s['max_ms']:.2f}ms")
    if run["events"]:
        parts.append(f"-- events ({len(run['events'])}) --")
        for ev in run["events"][-20:]:
            extra = {k: v for k, v in ev.items()
                     if k not in ("event", "ts")}
            parts.append(f"  {ev['event']}: {extra}")
    if len(parts) == 1:
        parts.append("(no artifacts found)")
    return "\n".join(parts)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("run_dir", nargs="?", default=None,
                    help="directory written by --obs-dir")
    ap.add_argument("--prom", default=None, metavar="FILE",
                    help="render a Prometheus text exposition instead "
                         "of a run directory (e.g. a saved /metrics "
                         "scrape from the HTTP front door)")
    args = ap.parse_args(argv)
    if args.prom is not None:
        from repro.obs.prom import parse_prometheus
        with open(args.prom) as f:
            snap = parse_prometheus(f.read())
        print("\n".join([f"== obs report: {args.prom} (prometheus) =="]
                        + metrics_lines(snap)))
        return 0
    if args.run_dir is None:
        ap.error("run_dir is required unless --prom is given")
    if not os.path.isdir(args.run_dir):
        ap.error(f"not a directory: {args.run_dir}")
    print(render(load_run(args.run_dir)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
