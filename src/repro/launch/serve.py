"""Provider-side serving driver: batched prefill+decode on a reduced arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import get_arch
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    engine = ServeEngine(cfg, max_len=args.max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 size=rng.integers(4, args.prompt_len + 1),
                                 dtype=np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature, rid=i)
            for i in range(args.requests)]
    t0 = time.time()
    outs = engine.serve(reqs, seed=args.seed)
    dt = time.time() - t0
    tok = sum(len(o.tokens) for o in outs)
    print(f"[serve] {cfg.name}: {len(reqs)} requests, {tok} tokens "
          f"in {dt:.2f}s ({tok / dt:.1f} tok/s)")
    for o in outs[:3]:
        print(f"  rid={o.rid} tokens={o.tokens[:8].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
