"""Serving drivers behind one launch entry point.

Provider-side LM serving: batched prefill+decode on a reduced arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --prompt-len 32 --new-tokens 16

Federation-side request serving: ``--federation`` routes a stream of
image requests through the Armol selector + provider fan-out + ensemble.
The default path is the synchronous ``FederationService``; ``--async``
switches to the micro-batching ``AsyncFederationService`` (``--workers``
cache shards / ensemble threads, flush at ``--max-batch`` requests or
``--max-wait-ms``, whichever comes first).

  PYTHONPATH=src python -m repro.launch.serve --federation --async \
      --requests 600 --workers 4 --max-batch 16 --max-wait-ms 2

``--transport {thread,process,socket}`` picks the evaluation plane
(``--shard-backend`` is the deprecated alias); ``--transport socket``
with ``--hosts host:port,...`` joins externally started
``repro.launch.shard_host`` servers — the multi-HOST path, see
``docs/serving.md``.

``--policy {rl,cascade,mct,hybrid}`` swaps the subset-selection policy
(the RL agent vs the ``repro.selection`` strategies; see
``docs/policies.md``); all four serve through the identical accounting
path, sync or async.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def run_federation(args) -> int:
    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces
    from repro.serving.async_service import AsyncFederationService
    from repro.serving.federation_service import FederationService

    obs = None
    if args.obs_dir:
        from repro.obs import Obs
        obs = Obs(args.obs_dir, trace_sample=args.trace_sample,
                  seed=args.seed)
    pool = None
    if args.scenario:
        from repro.scenarios import (DynamicProviderPool,
                                     NonStationaryArmolEnv, build_scenario)
        providers = default_providers()
        schedule = build_scenario(args.scenario, providers,
                                  horizon=max(args.requests, 2),
                                  seed=args.seed)
        print(schedule.describe())
        pool = DynamicProviderPool(providers, schedule,
                                   n_images=args.images, seed=args.seed)
        env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                    observe_pool=False, seed=args.seed + 1)
    else:
        traces = generate_traces(default_providers(), args.images,
                                 seed=args.seed)
        env = ArmolEnv(traces, mode="gt", beta=0.0, seed=args.seed + 1)
    if args.policy == "rl":
        agent = SAC(SACConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers, seed=args.seed))
    elif args.policy == "cascade":
        from repro.selection import CascadeSelector
        agent = CascadeSelector(env, beta=args.beta)
    elif args.policy == "mct":
        from repro.selection import MCTSelector
        agent = MCTSelector(env, budget=args.budget, seed=args.seed)
    else:   # hybrid: cascade gate fronting a (freshly initialized) SAC
        from repro.selection import HybridSelector
        rl = SAC(SACConfig(state_dim=env.state_dim,
                           n_providers=env.n_providers, seed=args.seed))
        agent = HybridSelector(env, rl, beta=args.beta)
    rng = np.random.default_rng(args.seed)
    reqs = [int(i) for i in rng.integers(0, args.images, args.requests)]
    transport = args.transport
    if transport is None:
        if args.shard_backend is not None:
            print("[serve] --shard-backend is deprecated; "
                  "use --transport")
            transport = args.shard_backend
        else:
            transport = "thread"
    topts = None
    if args.hosts:
        if transport != "socket":
            raise SystemExit("--hosts requires --transport socket")
        topts = {"hosts": [hp.strip() for hp in args.hosts.split(",")
                           if hp.strip()]}
    mode = (f"async/{transport}" if args.use_async else "sync")
    print(f"[serve] federation ({mode}, policy={args.policy}): "
          f"{env.n_providers} providers, "
          f"{args.images} images, {args.requests} requests"
          + (f", scenario={args.scenario}" if args.scenario else ""))

    if args.use_async:
        with AsyncFederationService(
                env, agent, max_batch=args.max_batch,
                max_wait_ms=args.max_wait_ms, adaptive=args.adaptive,
                workers=args.workers, pool=pool, transport=transport,
                transport_options=topts, obs=obs) as svc:
            svc.handle_many(reqs[:args.max_batch])      # warm jit + shards
            svc.reset_stats()
            if pool is not None:
                svc.set_clock(0)    # warm-up must not consume the schedule
            if obs is not None:
                # open AFTER warm-up so the log covers only measured
                # traffic; gt mode scores each record's ensemble AP50
                obs.open_serving_log(
                    [p.name for p in env.traces.providers],
                    env.traces.gts if env.mode == "gt" else None)
            t0 = time.time()
            futures = [svc.submit(i) for i in reqs]
            results = [f.result() for f in futures]
            dt = time.time() - t0
            extra = (f" mean_flush={svc.mean_flush_size():.1f}"
                     f" flushes={svc.stats['flushes']}"
                     f" shards={svc.workers}")
            if pool is not None:
                extra += (f" segments="
                          f"{pool.schedule.segment_index(svc.clock) + 1}")
            if obs is not None:
                obs.write_metrics(svc.extra_metric_snapshots())
    else:
        svc = FederationService(env, agent, obs=obs)
        svc.handle(reqs[0])                             # warm jit
        if obs is not None:
            obs.open_serving_log(
                [p.name for p in env.traces.providers],
                env.traces.gts if env.mode == "gt" else None)
        t0 = time.time()
        results = [svc.handle(i) for i in reqs]
        dt = time.time() - t0
        extra = ""
        if obs is not None:
            obs.write_metrics()

    cost = sum(r.cost_milli_usd for r in results)
    lat = np.asarray([r.latency_ms for r in results])
    print(f"[serve] {len(results)} requests in {dt:.2f}s "
          f"({len(results) / max(dt, 1e-9):.0f} req/s){extra}")
    print(f"[serve] accounted cost={cost:.1f} mUSD, modeled latency "
          f"p50={np.percentile(lat, 50):.0f}ms "
          f"p99={np.percentile(lat, 99):.0f}ms")
    if obs is not None:
        obs.close()
        print(f"[serve] observability artifacts in {args.obs_dir} "
              f"(render: python -m repro.launch.obs_report "
              f"{args.obs_dir})")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="LM architecture (required unless --federation)")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default: 8 LM, 400 federation)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--federation", action="store_true",
                    help="serve federation requests instead of the LM")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="micro-batching AsyncFederationService")
    ap.add_argument("--workers", type=int, default=4,
                    help="async: cache shards / ensemble worker threads")
    ap.add_argument("--transport", default=None,
                    choices=("thread", "process", "socket"),
                    help="async: the evaluation plane — in-process "
                         "threads (zero IPC, GIL-bound assembly), one "
                         "worker process per shard (parallel assembly), "
                         "or shard HOSTS over TCP (multi-host; spawns "
                         "--workers local hosts unless --hosts names "
                         "external ones).  Results are bit-identical "
                         "across all three")
    ap.add_argument("--hosts", default="",
                    help="async --transport socket: comma-separated "
                         "addr:port of externally started shard hosts "
                         "(python -m repro.launch.shard_host); empty = "
                         "spawn --workers hosts locally")
    ap.add_argument("--shard-backend", default=None,
                    choices=("thread", "process"),
                    help="DEPRECATED alias of --transport")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="async: flush when this many requests queue")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="async: flush when the oldest request is this old")
    ap.add_argument("--adaptive", action="store_true",
                    help="async: deadline-aware flush sizing from queue "
                         "depth (deeper queue -> flush sooner)")
    ap.add_argument("--images", type=int, default=120,
                    help="federation: trace-set size")
    ap.add_argument("--policy", default="rl",
                    choices=("rl", "cascade", "mct", "hybrid"),
                    help="federation: subset-selection policy — the RL "
                         "agent, the calibrated cheap-first cascade, the "
                         "online budgeted MCT selector, or the cascade "
                         "gate fronting the RL agent (docs/policies.md)")
    ap.add_argument("--beta", type=float, default=-0.05,
                    help="cascade/hybrid: cost weight of the calibration "
                         "objective (ap50 + beta * fee)")
    ap.add_argument("--budget", type=float, default=2.0,
                    help="mct: per-request fee budget (mUSD)")
    ap.add_argument("--scenario", default="",
                    help="federation: serve through a non-stationary "
                         "provider scenario (one schedule step per "
                         "request; implies --async)")
    ap.add_argument("--obs-dir", default="",
                    help="federation: write observability artifacts "
                         "(metrics.json, serving_log.jsonl, trace.jsonl) "
                         "to this directory; results are bit-identical "
                         "with or without it")
    ap.add_argument("--trace-sample", type=float, default=0.0,
                    help="fraction of requests traced through the async "
                         "plane (0 = tracing off/free; needs --obs-dir)")
    args = ap.parse_args()

    if args.requests is None:
        args.requests = 400 if args.federation else 8
    if args.scenario and not args.use_async:
        # mid-stream pool swaps live in the async service's flush path
        args.use_async = True
    if args.federation:
        return run_federation(args)
    if not args.arch:
        ap.error("--arch is required unless --federation is given")

    from repro.configs.base import get_arch
    from repro.serving.engine import Request, ServeEngine

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    engine = ServeEngine(cfg, max_len=args.max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 size=rng.integers(4, args.prompt_len + 1),
                                 dtype=np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature, rid=i)
            for i in range(args.requests)]
    t0 = time.time()
    outs = engine.serve(reqs, seed=args.seed)
    dt = time.time() - t0
    tok = sum(len(o.tokens) for o in outs)
    print(f"[serve] {cfg.name}: {len(reqs)} requests, {tok} tokens "
          f"in {dt:.2f}s ({tok / dt:.1f} tok/s)")
    for o in outs[:3]:
        print(f"  rid={o.rid} tokens={o.tokens[:8].tolist()}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
