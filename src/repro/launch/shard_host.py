"""Standalone shard host: one TCP server owning a private
``SubsetEvaluationCore``, speaking the serving plane's op contract.

Start H of these (one per box / per core pool), then point the serving
front at them:

  PYTHONPATH=src python -m repro.launch.shard_host --port 9701 &
  PYTHONPATH=src python -m repro.launch.shard_host --port 9702 &
  PYTHONPATH=src python -m repro.launch.serve --federation --async \
      --transport socket --hosts 127.0.0.1:9701,127.0.0.1:9702

Every host must be started with the SAME roster arguments
(``--images``/``--seed``/ensemble config) as the front: the client's
connect-time ``hello`` handshake refuses hosts whose trace fingerprints
or config differ, because such hosts would answer valid-but-different
rows and silently break cross-shard bit-parity.  See ``docs/serving.md``.
"""
from __future__ import annotations

import argparse
import socket


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1",
                    help="interface to bind (default 127.0.0.1)")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (0 = ephemeral, printed on start)")
    ap.add_argument("--images", type=int, default=64,
                    help="roster size; must match the serving front")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed; must match the serving front")
    ap.add_argument("--voting", default="affirmative")
    ap.add_argument("--ablation", default="wbf")
    ap.add_argument("--iou-thr", type=float, default=0.5)
    ap.add_argument("--use-kernel", default="auto",
                    choices=["auto", "true", "false"])
    args = ap.parse_args(argv)

    from repro.ensemble.pipeline import resolve_use_kernel
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces
    from repro.serving.socket_shards import serve_host

    uk = {"auto": "auto", "true": True, "false": False}[args.use_kernel]
    cfg = {"voting": args.voting, "ablation": args.ablation,
           "iou_thr": args.iou_thr,
           "use_kernel": resolve_use_kernel(uk)}
    traces = generate_traces(default_providers(), args.images,
                             seed=args.seed)

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((args.host, args.port))
    srv.listen(16)
    print(f"[shard_host] serving {traces.n_providers} providers / "
          f"{args.images} images on {args.host}:{srv.getsockname()[1]} "
          f"(cfg={cfg})", flush=True)
    try:
        serve_host(srv, traces, cfg)
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
