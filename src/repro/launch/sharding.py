"""Sharding rules: param/optimizer/cache/batch PartitionSpecs.

Rule dispatch is by parameter *name* (the last dict key), with divisibility
guards so e.g. GQA archs with num_kv_heads=8 < model-axis=16 fall back to
replicated KV projections instead of splitting heads across shards.
Leading stacked-layer dims (from lax.scan stacking — 1 for most archs, 2
for VLM/Zamba super-blocks) are never sharded.

Modes:
  tp  — tensor-parallel only (serving; weights replicated over "data")
  2d  — FSDP x TP (training; the non-"model" big dim shards over "data")
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# parameter names that column-parallel shard (output dim over "model")
# NOTE: wq_a (MLA query down-projection, d x q_lora) is deliberately NOT
# column-sharded: sharding q_lora makes the wq_b contraction partial-summed
# and GSPMD sinks that psum into the (B, H, S, S) attention scores — four
# full-score all-reduces, 99% of DeepSeek prefill collective traffic
# (see EXPERIMENTS.md §Perf iteration 2).  The projection is tiny; keep it
# replicated.
_COL = {"wq", "w_gate", "w_up", "in_z", "in_x", "wq_b"}
# kv projections: column-parallel only if num_kv_heads divides the axis
_COL_KV = {"wk", "wv"}
# MLA latent-side per-head expansions: column over heads
_COL_MLA = {"wk_b", "wv_b"}
# row-parallel (input dim over "model")
_ROW = {"wo", "w_down", "out_proj"}
# expert-parallel 3-D weights (expert dim over "model")
_EXPERT = {"w_gate", "w_up", "w_down"}
_BIAS_COL = {"bq"}
_BIAS_KV = {"bk", "bv"}


def _name_of(path) -> str:
    for entry in reversed(path):
        k = getattr(entry, "key", None)
        if k is None:
            k = getattr(entry, "name", None)
        if isinstance(k, str):
            return k
    return ""


def _path_names(path):
    out = []
    for entry in path:
        k = getattr(entry, "key", getattr(entry, "name", None))
        if isinstance(k, str):
            out.append(k)
    return out


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_pspec(path, leaf, cfg: ArchConfig, *, model_size: int,
                data_size: int, mode: str = "2d") -> P:
    """PartitionSpec for one parameter leaf."""
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    lead = len(shape)  # num leading (stacked/scan) dims before the base shape
    fsdp = mode == "2d"

    def base(*spec):
        """Pad with None for the stacked leading dims."""
        pad = (None,) * (len(shape) - len(spec))
        return P(*pad, *spec)

    in_moe = "moe" in names and "shared" not in names
    if name == "embed":
        a = "model" if _div(shape[0], model_size) else None
        b = "data" if fsdp and _div(shape[1], data_size) else None
        return P(a, b)
    if name == "unembed":
        a = "data" if fsdp and _div(shape[0], data_size) else None
        b = "model" if _div(shape[1], model_size) else None
        return P(a, b)
    if in_moe and name in _EXPERT and len(shape) >= 3:
        # (..., E, a, b): experts over "model"
        e_ok = _div(shape[-3], model_size)
        d_ok = fsdp and _div(shape[-2], data_size)
        return base("model" if e_ok else None, "data" if d_ok else None, None)
    if name == "router":
        return base("data" if fsdp and _div(shape[-2], data_size) else None,
                    None)
    if name in _COL:
        a = "data" if fsdp and _div(shape[-2], data_size) else None
        b = "model" if _div(shape[-1], model_size) else None
        return base(a, b)
    if name in _COL_KV:
        ok = _div(cfg.num_kv_heads, model_size)
        a = "data" if fsdp and _div(shape[-2], data_size) else None
        return base(a, "model" if ok else None)
    if name in _COL_MLA:
        ok = _div(cfg.num_heads, model_size)
        a = "data" if fsdp and _div(shape[-2], data_size) else None
        return base(a, "model" if ok else None)
    if name in _ROW:
        a = "model" if _div(shape[-2], model_size) else None
        b = "data" if fsdp and _div(shape[-1], data_size) else None
        return base(a, b)
    if name in _BIAS_COL:
        return base("model" if _div(shape[-1], model_size) else None)
    if name in _BIAS_KV:
        ok = _div(cfg.num_kv_heads, model_size)
        return base("model" if ok else None)
    if name == "conv_x":            # (..., d_inner, d_conv)
        return base("model" if _div(shape[-2], model_size) else None, None)
    if name == "conv_x_b":          # (..., d_inner)
        return base("model" if _div(shape[-1], model_size) else None)
    # everything else (norms, gates, conv_bc, in_bc, in_dt, A_log, D, ...)
    return P(*(None,) * len(shape))


def params_shardings(mesh, params_shapes, cfg: ArchConfig, mode: str = "2d"):
    msz = mesh.shape["model"]
    dsz = mesh.shape["data"]

    def one(path, leaf):
        spec = param_pspec(path, leaf, cfg, model_size=msz, data_size=dsz,
                           mode=mode)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shapes)


# ---------------------------------------------------------------------------
# Activations / batch / cache
# ---------------------------------------------------------------------------

def batch_pspec(mesh, global_batch: int) -> P:
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if _div(global_batch, total):
        return P(axes)
    if _div(global_batch, mesh.shape["data"]) and len(axes) > 1:
        return P("data")
    return P(None)


def batch_shardings(mesh, batch_shapes, global_batch: int):
    bp = batch_pspec(mesh, global_batch)

    def one(leaf):
        pad = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*bp, *pad))
    return jax.tree.map(one, batch_shapes)


def cache_pspec(path, leaf, cfg: ArchConfig, *, model_size: int,
                data_size: int, global_batch: int) -> P:
    """KV/state cache sharding.

    Baseline policy: batch over "data" when divisible; the head dim over
    "model" when divisible, OTHERWISE the sequence dim over "model"
    (sequence-sharded cache — GSPMD inserts the softmax-reduction
    collectives).  SSM states shard heads over "model".
    """
    name = _name_of(path)
    shape = leaf.shape
    if name == "pos" or len(shape) == 0:
        return P()
    b_ok = _div(global_batch, data_size)

    def with_batch(bidx, rest):
        spec = [None] * len(shape)
        if b_ok:
            spec[bidx] = "data"
        for i, ax in rest.items():
            spec[i] = ax
        return P(*spec)

    if name in ("k", "v"):
        # (..., B, W, K, hd)
        bidx = len(shape) - 4
        if _div(cfg.num_kv_heads, model_size):
            return with_batch(bidx, {len(shape) - 2: "model"})
        return with_batch(bidx, {len(shape) - 3: "model"})
    if name in ("cross_k", "cross_v"):
        bidx = len(shape) - 4
        if _div(cfg.num_kv_heads, model_size):
            return with_batch(bidx, {len(shape) - 2: "model"})
        return with_batch(bidx, {})
    if name in ("latent", "latent0", "k_rope", "k_rope0"):
        # (L, B, W, r): sequence-sharded latent cache
        bidx = len(shape) - 3
        return with_batch(bidx, {len(shape) - 2: "model"})
    if name == "ssm":
        # (..., B, nh, hd, N)
        bidx = len(shape) - 4
        d_inner, nh, _ = _ssm_dims(cfg)
        if _div(nh, model_size):
            return with_batch(bidx, {len(shape) - 3: "model"})
        return with_batch(bidx, {})
    if name in ("conv_x",):
        bidx = len(shape) - 3
        d_inner, _, _ = _ssm_dims(cfg)
        if _div(d_inner, model_size):
            return with_batch(bidx, {len(shape) - 2: "model"})
        return with_batch(bidx, {})
    if name in ("conv_bc",):
        bidx = len(shape) - 3
        return with_batch(bidx, {})
    return P(*(None,) * len(shape))


def _ssm_dims(cfg):
    from repro.models import ssm as ssm_lib
    return ssm_lib.dims(cfg) if cfg.ssm is not None else (0, 0, 0)


def cache_shardings(mesh, cache_shapes, cfg: ArchConfig, global_batch: int):
    msz, dsz = mesh.shape["model"], mesh.shape["data"]

    def one(path, leaf):
        spec = cache_pspec(path, leaf, cfg, model_size=msz, data_size=dsz,
                           global_batch=global_batch)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh, shapes):
    def one(leaf):
        return NamedSharding(mesh, P(*(None,) * len(leaf.shape)))
    return jax.tree.map(one, shapes)
