"""ShapeDtypeStruct input specs for every (architecture x input shape).

Nothing here allocates device memory: params/opt/cache structures come from
``jax.eval_shape`` and inputs are hand-built ShapeDtypeStructs with
NamedShardings attached — the shannon/kernels dry-run pattern.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import sharding as shd
from repro.models.model import Model, build_model
from repro.training.train_step import TrainState, init_train_state, \
    make_train_step

# serving weights: TP-only if bf16 params fit under this per-chip budget
TP_BYTES_BUDGET = 8 * 1024 ** 3


def serve_param_mode(cfg: ArchConfig, model_size: int) -> str:
    per_chip = cfg.param_count() * 2 / model_size
    return "tp" if per_chip <= TP_BYTES_BUDGET else "2d"


def batch_struct(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((B, S), jnp.int32),
             "labels": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_vision),
                                    jnp.float32)
    if cfg.family == "audio":
        batch["audio_frames"] = sds((B, cfg.num_audio_frames, cfg.d_model),
                                    jnp.float32)
    return batch


def _attach(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def build_dryrun(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                 remat: bool = True,
                 param_mode: str = "") -> Tuple[Callable, Tuple]:
    """Returns (fn, arg_specs) ready for jax.jit(fn).lower(*arg_specs)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        mode = param_mode or "2d"
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(model, k), key)
        pshard = shd.params_shardings(mesh, state_shapes.params, cfg, mode)
        oshard = TrainState(
            params=pshard,
            opt=type(state_shapes.opt)(
                step=shd.replicated(mesh, state_shapes.opt.step),
                mu=shd.params_shardings(mesh, state_shapes.opt.mu, cfg, mode),
                nu=shd.params_shardings(mesh, state_shapes.opt.nu, cfg,
                                        mode)))
        state_specs = _attach(state_shapes, oshard)
        bshapes = batch_struct(cfg, B, S)
        bspecs = _attach(bshapes, shd.batch_shardings(mesh, bshapes, B))
        import os
        loss_chunks = int(os.environ.get("REPRO_LOSS_CHUNKS", "0"))
        step_fn = make_train_step(model, remat=remat,
                                  loss_chunks=loss_chunks)
        return step_fn, (state_specs, bspecs)

    mode = param_mode or serve_param_mode(cfg, mesh.shape["model"])
    params_shapes = jax.eval_shape(model.init, key)
    pspecs = _attach(params_shapes,
                     shd.params_shardings(mesh, params_shapes, cfg, mode))

    if shape.kind == "prefill":
        bshapes = batch_struct(cfg, B, S)
        bshapes.pop("labels")
        bspecs = _attach(bshapes, shd.batch_shardings(mesh, bshapes, B))

        def prefill_fn(params, batch):
            return model.prefill(params, batch, S)
        return prefill_fn, (pspecs, bspecs)

    # decode: one new token against a seq_len cache
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(None, B, S, None))
    cspecs = _attach(cache_shapes,
                     shd.cache_shardings(mesh, cache_shapes, cfg, B))
    tok = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=shd.batch_shardings(
            mesh, jax.ShapeDtypeStruct((B, 1), jnp.int32), B))

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_fn, (pspecs, cspecs, tok)
