"""Training drivers behind one launch entry point.

Provider-side LM training: runs a real (reduced or full) architecture with
the synthetic data pipeline on whatever devices exist.  On the CPU
container use ``--reduced`` (the full configs are exercised via
launch.dryrun instead).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 50 --batch 8 --seq 128

Federation-side selector training: ``--federation`` trains the Armol
provider-selection agent through the multi-lane batched drivers
(``--lanes`` parallel env lanes, fused lax.scan update blocks; ``--lanes
1`` is bit-identical to the sequential reference).

  PYTHONPATH=src python -m repro.launch.train --federation --algo sac \
      --epochs 5 --steps 500 --images 400 --lanes 8

``--scenario`` switches to ONLINE adaptation on a non-stationary provider
pool (``repro.scenarios``): the schedule re-prices, degrades, downs, and
launches providers mid-stream while training continues, reporting per-
segment recovery vs the per-segment oracle.

  PYTHONPATH=src python -m repro.launch.train --federation \
      --scenario provider_outage --horizon 1600 --images 120
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.store import save_pytree
from repro.configs.base import get_arch
from repro.data.pipeline import synthetic_lm_batches
from repro.models.model import build_model
from repro.training.train_step import init_train_state, make_train_step


def run_scenario(args) -> int:
    """Online adaptation through a non-stationary provider scenario."""
    from repro.core.sac import SAC, SACConfig
    from repro.core.td3 import TD3, TD3Config
    from repro.federation.providers import default_providers
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario, run_online)

    if args.algo == "ppo":
        raise SystemExit("--scenario runs the off-policy online driver; "
                         "use --algo sac or td3")
    providers = default_providers()
    schedule = build_scenario(args.scenario, providers,
                              horizon=args.horizon, seed=args.seed)
    print(schedule.describe())
    pool = DynamicProviderPool(providers, schedule, n_images=args.images,
                               seed=args.seed)
    env = NonStationaryArmolEnv(pool, mode=args.mode, beta=args.beta,
                                observe_pool=not args.blind,
                                seed=args.seed + 1)
    kw = dict(state_dim=env.state_dim, n_providers=env.n_providers,
              lr=3e-4, gamma=0.0, hidden=(32, 32), seed=args.seed)
    agent = TD3(TD3Config(**kw)) if args.algo == "td3" \
        else SAC(SACConfig(alpha=0.02, **kw))
    obs = _make_obs(args)
    res = run_online(agent, env, lanes=args.lanes, seed=args.seed,
                     obs=obs)
    s = res["summary"]
    print(f"[train] scenario done: min post-switch recovery="
          f"{s['min_recovery_post_switch']} mean="
          f"{s['mean_recovery_post_switch']} "
          f"cache_hit={s['mean_cache_hit_rate']} ({s['steps']} steps, "
          f"{s['wall_s']}s)")
    _finish_obs(obs, args)
    return 0


def _make_obs(args):
    """Build the run's ``repro.obs.Obs`` handle from ``--obs-dir`` (or
    ``None`` — every driver treats that as observability off)."""
    if not getattr(args, "obs_dir", ""):
        return None
    from repro.obs import Obs
    return Obs(args.obs_dir, seed=args.seed)


def _finish_obs(obs, args) -> None:
    if obs is None:
        return
    obs.write_metrics()
    obs.close()
    print(f"[train] observability artifacts in {args.obs_dir} "
          f"(render: python -m repro.launch.obs_report {args.obs_dir})")


def run_federation(args) -> int:
    from repro.core.loops import run_off_policy, run_ppo
    from repro.core.ppo import PPO, PPOConfig
    from repro.core.sac import SAC, SACConfig
    from repro.core.td3 import TD3, TD3Config
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces

    if args.scenario:
        return run_scenario(args)
    traces = generate_traces(default_providers(), args.images,
                             seed=args.seed)
    env = ArmolEnv(traces, mode=args.mode, beta=args.beta,
                   seed=args.seed + 1)
    print(f"[train] federation selector: {env.n_providers} providers, "
          f"{args.images} images, algo={args.algo}, lanes={args.lanes}")
    t0 = time.time()
    if args.algo == "ppo":
        agent = PPO(PPOConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers,
                              seed=args.seed))
        hist = run_ppo(agent, env, lanes=args.lanes, epochs=args.epochs,
                       steps_per_epoch=args.steps)
        total = args.epochs * (-(-args.steps // args.lanes)) * args.lanes
    else:
        cls, cfg_cls = (TD3, TD3Config) if args.algo == "td3" \
            else (SAC, SACConfig)
        agent = cls(cfg_cls(state_dim=env.state_dim,
                            n_providers=env.n_providers, seed=args.seed))
        obs = _make_obs(args)
        hist = run_off_policy(agent, env, lanes=args.lanes,
                              epochs=args.epochs,
                              steps_per_epoch=args.steps, seed=args.seed,
                              obs=obs)
        total = hist[-1]["steps"]
        _finish_obs(obs, args)
    dt = time.time() - t0
    last = hist[-1]
    print(f"[train] done: AP50={last['ap50']:.2f} cost={last['cost']:.3f} "
          f"({total / max(dt, 1e-9):.0f} env steps/s over {total} steps)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="LM architecture (required unless --federation)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=None,
                    help="LM: training steps (default 50); federation: "
                         "env steps per epoch (default 500)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--federation", action="store_true",
                    help="train the Armol provider-selection agent on the "
                         "batched multi-lane drivers")
    ap.add_argument("--algo", choices=["sac", "td3", "ppo"], default="sac")
    ap.add_argument("--mode", choices=["gt", "nogt"], default="gt")
    ap.add_argument("--beta", type=float, default=-0.03)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--images", type=int, default=400)
    ap.add_argument("--scenario", default="",
                    help="federation: train ONLINE through a non-"
                         "stationary provider scenario (price_war, "
                         "provider_outage, accuracy_drift, flash_crowd, "
                         "provider_churn, random[:seed])")
    ap.add_argument("--horizon", type=int, default=1600,
                    help="scenario: schedule length in env steps")
    ap.add_argument("--blind", action="store_true",
                    help="scenario: hide provider status/fees from the "
                         "state (adaptation from reward alone)")
    ap.add_argument("--obs-dir", default="",
                    help="write observability artifacts (metrics.json, "
                         "events.jsonl) to this directory; training "
                         "results are bit-identical with or without it")
    args = ap.parse_args()

    if args.federation:
        # the shared --steps flag means env steps per epoch here; the LM
        # default of 50 would end training before the first update block
        if args.steps is None:
            args.steps = 500
        return run_federation(args)
    if args.steps is None:
        args.steps = 50
    if not args.arch:
        ap.error("--arch is required unless --federation is given")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, dtype=jnp.float32 if args.reduced else None)
    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params / 1e6:.1f}M params, {len(jax.devices())} device(s)")

    step_fn = jax.jit(make_train_step(model, peak_lr=args.lr,
                                      total_steps=args.steps))
    data = synthetic_lm_batches(cfg, args.batch, args.seq, seed=args.seed)
    obs = _make_obs(args)
    h_step = obs.metrics.histogram("train.lm_step_ms") \
        if obs is not None else None
    t0 = time.time()
    for step in range(args.steps):
        st0 = time.monotonic() if h_step is not None else 0.0
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        if h_step is not None:
            h_step.observe((time.monotonic() - st0) * 1e3)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    if args.ckpt:
        save_pytree(args.ckpt, state.params)
        print(f"[train] saved params to {args.ckpt}")
    _finish_obs(obs, args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
