"""Provider-side LM training driver.

Runs a real (reduced or full) architecture with the synthetic data pipeline
on whatever devices exist.  On the CPU container use ``--reduced`` (the
full configs are exercised via launch.dryrun instead).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.store import save_pytree
from repro.configs.base import get_arch
from repro.data.pipeline import synthetic_lm_batches
from repro.models.model import build_model
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, dtype=jnp.float32 if args.reduced else None)
    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params / 1e6:.1f}M params, {len(jax.devices())} device(s)")

    step_fn = jax.jit(make_train_step(model, peak_lr=args.lr,
                                      total_steps=args.steps))
    data = synthetic_lm_batches(cfg, args.batch, args.seq, seed=args.seed)
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    if args.ckpt:
        save_pytree(args.ckpt, state.params)
        print(f"[train] saved params to {args.ckpt}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
