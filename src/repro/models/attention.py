"""Attention: GQA (full / sliding-window / ring-cache decode), cross-attn, MLA.

Conventions
-----------
activations  x: (B, S, d_model)
q            : (B, S, H, hd)
kv cache     : k/v (B, S_cache, K, hd); keys stored *already RoPE'd*.
MLA cache    : latent (B, S, kv_lora) + k_rope (B, S, rope_dim).
Decode steps take a scalar ``pos`` (same position across the batch —
static batching; the continuous-batching scheduler lives in serving/).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_model: int
    rope_theta: float
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"

    @staticmethod
    def from_cfg(cfg: ArchConfig) -> "AttnSpec":
        return AttnSpec(cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
                        cfg.d_model, cfg.rope_theta, cfg.qkv_bias, cfg.qk_norm,
                        cfg.norm)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_attention(key, spec: AttnSpec, dtype):
    ks = jax.random.split(key, 6)
    H, K, hd, d = spec.num_heads, spec.num_kv_heads, spec.head_dim, spec.d_model
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if spec.qk_norm:
        p["q_norm"] = init_norm(ks[4], hd, "rmsnorm", dtype)
        p["k_norm"] = init_norm(ks[5], hd, "rmsnorm", dtype)
    return p


def _project_qkv(p, x, spec: AttnSpec, positions):
    B, S, _ = x.shape
    H, K, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if spec.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, num_kv_heads):
    """q: (B,S,H,hd), k/v: (B,T,K,hd), mask: (B|1, S, T) bool or None."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, window: int = 0):
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    return m[None]  # (1, S, S)


def attention_forward(p, x, positions, spec: AttnSpec, *, causal=True,
                      window: int = 0, return_cache=False):
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(p, x, spec, positions)
    mask = causal_mask(x.shape[1], window) if causal else None
    out = _sdpa(q, k, v, mask, spec.num_kv_heads)
    y = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    if return_cache:
        return y, (k, v)
    return y


def attention_decode(p, x, pos, cache_k, cache_v, spec: AttnSpec, *,
                     window: int = 0):
    """One-token decode. x: (B,1,d); cache_k/v: (B,W,K,hd); pos scalar int32.

    With ``window`` the cache is a ring buffer of size W; otherwise W is the
    max sequence length and ``pos`` indexes into it directly.
    """
    B = x.shape[0]
    W = cache_k.shape[1]
    q, k, v = _project_qkv(p, x, spec, jnp.full((B, 1), pos))
    slot = jnp.mod(pos, W) if window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    j = jnp.arange(W)
    if window:
        valid = (j <= pos) | (pos >= W)
    else:
        valid = j <= pos
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, W))
    out = _sdpa(q, cache_k, cache_v, mask, spec.num_kv_heads)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# Cross attention (VLM image layers, enc-dec)
# ---------------------------------------------------------------------------

def init_cross_attention(key, spec: AttnSpec, d_src: int, dtype, gated=False):
    ks = jax.random.split(key, 5)
    H, K, hd, d = spec.num_heads, spec.num_kv_heads, spec.head_dim, spec.d_model
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d_src, K * hd), dtype),
        "wv": dense_init(ks[2], (d_src, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if gated:
        p["gate"] = jnp.zeros((), dtype)
    return p


def cross_kv(p, src, spec: AttnSpec):
    """Precompute cross K/V from source embeddings (B, T, d_src)."""
    B, T, _ = src.shape
    K, hd = spec.num_kv_heads, spec.head_dim
    k = src @ p["wk"]
    v = src @ p["wv"]
    if spec.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k.reshape(B, T, K, hd), v.reshape(B, T, K, hd)


def cross_attention_forward(p, x, kv: Tuple, spec: AttnSpec):
    B, S, _ = x.shape
    H, hd = spec.num_heads, spec.head_dim
    q = x @ p["wq"]
    if spec.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    k, v = kv
    out = _sdpa(q, k, v, None, spec.num_kv_heads)
    y = out.reshape(B, S, -1) @ p["wo"]
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return y


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV; decode uses weight absorption
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    assert m is not None
    ks = jax.random.split(key, 8)
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": init_norm(ks[1], m.q_lora_rank, "rmsnorm", dtype),
        "wq_b": dense_init(ks[2], (m.q_lora_rank, H * qk), dtype),
        "wkv_a": dense_init(ks[3], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": init_norm(ks[4], m.kv_lora_rank, "rmsnorm", dtype),
        # stored per-head for decode-side absorption
        "wk_b": dense_init(ks[5], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "wv_b": dense_init(ks[6], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": dense_init(ks[7], (H * m.v_head_dim, d), dtype),
    }


def _mla_q(p, x, m: MLAConfig, H, positions):
    B, S, _ = x.shape
    cq = apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm")
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, 10000.0)
    return q_nope, q_rope


def _mla_latent(p, x, m: MLAConfig, positions):
    ckv = x @ p["wkv_a"]
    latent = apply_norm(p["kv_norm"], ckv[..., :m.kv_lora_rank], "rmsnorm")
    k_rope = ckv[..., None, m.kv_lora_rank:]            # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, 10000.0)[..., 0, :]
    return latent, k_rope


def _score_constraint(s):
    """§Perf: pin (B, H, S, T) attention scores to (data, model) sharding.

    Without this GSPMD replicates the full fp32 score tensor across the
    data axis for the MLA two-term score sum (observed on DeepSeek 32k
    prefill: two (B, H, 32k, 32k) all-reduces = 99% of collective traffic).
    Enabled via REPRO_MLA_CONSTRAINT=1; no-op without a mesh context.
    """
    import os
    if os.environ.get("REPRO_MLA_CONSTRAINT") != "1":
        return s
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            s, P("data", "model", None, None))
    except (ValueError, RuntimeError):
        return s


def mla_forward(p, x, positions, cfg: ArchConfig, *, causal=True,
                return_cache=False):
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, m, H, positions)
    latent, k_rope = _mla_latent(p, x, m, positions)
    k_nope = (latent @ p["wk_b"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (latent @ p["wv_b"]).reshape(B, S, H, m.v_head_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    scores = (_score_constraint(jnp.einsum("bshn,bthn->bhst", q_nope, k_nope))
              + _score_constraint(
                  jnp.einsum("bshr,btr->bhst", q_rope, k_rope))
              ).astype(jnp.float32)
    scores = _score_constraint(scores) * scale
    if causal:
        mask = causal_mask(S)[0]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthv->bshv", w, v).reshape(B, S, -1)
    y = out @ p["wo"]
    if return_cache:
        return y, (latent, k_rope)
    return y


def mla_decode(p, x, pos, cache_latent, cache_krope, cfg: ArchConfig):
    """Absorbed decode: scores in latent space; cache = (B,W,kv_lora)+(B,W,rope)."""
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    W = cache_latent.shape[1]
    positions = jnp.full((B, 1), pos)
    q_nope, q_rope = _mla_q(p, x, m, H, positions)       # (B,1,H,·)
    latent, k_rope = _mla_latent(p, x, m, positions)     # (B,1,kv_lora),(B,1,rope)
    cache_latent = jax.lax.dynamic_update_slice_in_dim(cache_latent, latent, pos, 1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(cache_krope, k_rope, pos, 1)
    # absorb wk_b into the query:  q_lat[h] = q_nope[h] @ wk_b[:, h, :].T
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, wk_b)   # (B,1,H,kv_lora)
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, cache_latent)
              + jnp.einsum("bshr,btr->bhst", q_rope, cache_krope))
    scores = scores.astype(jnp.float32) * scale
    valid = jnp.arange(W) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btl->bshl", w, cache_latent)   # (B,1,H,kv_lora)
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat, wv_b).reshape(B, 1, -1)
    y = out @ p["wo"]
    return y, (cache_latent, cache_krope)
