"""Shared building blocks: norms, RoPE, MLPs, initialisers.

Pure-functional: params are plain dict pytrees; every apply function is
shape-polymorphic jnp so GSPMD can partition it under pjit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

Dtype = jnp.dtype


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, d, norm_kind: str, dtype):
    del key
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, norm_kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32)
        if "bias" in p:
            out = out + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def gated_rmsnorm(p, x, gate, eps: float = 1e-5):
    """Mamba-2 style: RMSNorm(x * silu(gate))."""
    x = x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return apply_norm(p, x, "rmsnorm", eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (half,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def apply_mlp(p, x, act: str):
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = fn(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def act_fn(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu
