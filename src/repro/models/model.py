"""Composable model zoo: one ``Model`` facade over six architecture families.

Families:
  dense   — GQA decoder (command-r-plus, qwen1.5-110b/0.5b, stablelm-12b)
  moe     — GQA or MLA decoder with MoE FFN (olmoe, deepseek-v2)
  ssm     — Mamba-2 stack (mamba2-370m)
  hybrid  — Mamba-2 blocks + one shared attn block every k (zamba2)
  vlm     — dense decoder + cross-attn image layers (llama-3.2-vision)
  audio   — encoder-decoder (seamless-m4t); frontend embeddings are stubs

All stacks scan over stacked per-layer params (lax.scan) so the HLO stays
compact enough to compile 80 dry-run combinations on one CPU core.  Every
family exposes: init / forward (train) / prefill / decode_step / init_cache.
Decode caches: full KV, sliding-window ring KV, MLA latent, or SSM state —
chosen per config ``long_context`` plan and requested max_len.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as att
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import AttnSpec
from repro.models.layers import (apply_mlp, apply_norm, embed_init, init_mlp,
                                 init_norm)

PyTree = Any

def _scan(f, init, xs):
    """lax.scan with env-controlled unroll (REPRO_SCAN_UNROLL).

    The roofline correction (benchmarks/roofline_correct.py) sets a large
    unroll so XLA inlines the layer bodies and cost_analysis() counts every
    layer — a plain while-loop body is counted once regardless of trip
    count, which silently undercounts stacked-layer FLOPs/bytes.
    """
    import os
    unroll = int(os.environ.get("REPRO_SCAN_UNROLL", "1"))
    return jax.lax.scan(f, init, xs, unroll=unroll)




# ---------------------------------------------------------------------------
# Dense / MoE transformer block
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, dtype, *, layer_is_moe: bool,
                dense_ff: Optional[int] = None):
    ks = jax.random.split(key, 4)
    spec = AttnSpec.from_cfg(cfg)
    p = {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, dtype)}
    if cfg.mla is not None:
        p["attn"] = att.init_mla(ks[1], cfg, dtype)
    else:
        p["attn"] = att.init_attention(ks[1], spec, dtype)
    if not cfg.parallel_block:
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm, dtype)
    if layer_is_moe:
        p["moe"] = moe_lib.init_moe(ks[3], cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, dense_ff or cfg.d_ff, dtype)
    return p


def _block_forward(p, x, positions, cfg: ArchConfig, *, causal=True,
                   window: int = 0, return_cache=False):
    spec = AttnSpec.from_cfg(cfg)
    h = apply_norm(p["norm1"], x, cfg.norm)
    cache = None
    if cfg.mla is not None:
        out = att.mla_forward(p["attn"], h, positions, cfg, causal=causal,
                              return_cache=return_cache)
    else:
        out = att.attention_forward(p["attn"], h, positions, spec,
                                    causal=causal, window=window,
                                    return_cache=return_cache)
    if return_cache:
        a, cache = out
    else:
        a = out
    aux = jnp.float32(0.0)
    if cfg.parallel_block:
        if "moe" in p:
            m, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe, cfg.act)
        else:
            m = apply_mlp(p["mlp"], h, cfg.act)
        x = x + a + m
    else:
        x = x + a
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            m, aux = moe_lib.apply_moe(p["moe"], h2, cfg.moe, cfg.act)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.act)
        x = x + m
    return (x, aux, cache) if return_cache else (x, aux)


def _block_decode(p, x, pos, kcache, vcache, cfg: ArchConfig, *, window: int):
    spec = AttnSpec.from_cfg(cfg)
    h = apply_norm(p["norm1"], x, cfg.norm)
    if cfg.mla is not None:
        a, (kcache, vcache) = att.mla_decode(p["attn"], h, pos, kcache,
                                             vcache, cfg)
    else:
        a, (kcache, vcache) = att.attention_decode(p["attn"], h, pos, kcache,
                                                   vcache, spec, window=window)
    if cfg.parallel_block:
        m = apply_mlp(p["mlp"], h, cfg.act) if "mlp" in p else \
            moe_lib.apply_moe(p["moe"], h, cfg.moe, cfg.act)[0]
        x = x + a + m
    else:
        x = x + a
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            m, _ = moe_lib.apply_moe(p["moe"], h2, cfg.moe, cfg.act)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.act)
        x = x + m
    return x, kcache, vcache


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------


@dataclass
class Model:
    cfg: ArchConfig
    dtype: Any = jnp.bfloat16

    # ----- construction ----------------------------------------------------
    def init(self, key) -> PyTree:
        cfg, dtype = self.cfg, self.dtype
        kE, kU, kB, kX, kN, kS = jax.random.split(key, 6)
        params: Dict[str, Any] = {
            "embed": embed_init(kE, (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": init_norm(kN, cfg.d_model, cfg.norm, dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(kU, (cfg.d_model, cfg.vocab_size),
                                           dtype)
        fam = cfg.family
        if fam in ("dense", "moe"):
            mo = cfg.moe
            n_dense = mo.first_dense_layers if mo else 0
            n_main = cfg.num_layers - n_dense
            if n_dense:
                params["dense_blocks"] = _stack_init(
                    kX, n_dense, lambda k: _init_block(
                        k, cfg, dtype, layer_is_moe=False,
                        dense_ff=mo.d_ff_dense))
            params["blocks"] = _stack_init(
                kB, n_main, lambda k: _init_block(
                    k, cfg, dtype, layer_is_moe=mo is not None))
        elif fam == "ssm":
            def one(k):
                kk1, kk2 = jax.random.split(k)
                return {"norm": init_norm(kk1, cfg.d_model, cfg.norm, dtype),
                        "mamba": ssm_lib.init_mamba_block(kk2, cfg, dtype)}
            params["blocks"] = _stack_init(kB, cfg.num_layers, one)
        elif fam == "hybrid":
            per = cfg.shared_attn_every
            n_super = cfg.num_layers // per

            def one_super(k):
                def one(kk):
                    k1, k2 = jax.random.split(kk)
                    return {"norm": init_norm(k1, cfg.d_model, cfg.norm, dtype),
                            "mamba": ssm_lib.init_mamba_block(k2, cfg, dtype)}
                return _stack_init(k, per, one)
            params["blocks"] = _stack_init(kB, n_super, one_super)
            params["shared_attn"] = _init_block(kS, cfg, dtype,
                                                layer_is_moe=False)
        elif fam == "vlm":
            per = cfg.cross_attn_every
            n_super = cfg.num_layers // per
            spec = AttnSpec.from_cfg(cfg)

            def one_super(k):
                k1, k2, k3, k4 = jax.random.split(k, 4)
                selfs = _stack_init(k1, per - 1, lambda kk: _init_block(
                    kk, cfg, dtype, layer_is_moe=False))
                cross = {
                    "norm1": init_norm(k2, cfg.d_model, cfg.norm, dtype),
                    "attn": att.init_cross_attention(k3, spec, cfg.d_vision,
                                                     dtype, gated=True),
                    "norm2": init_norm(k4, cfg.d_model, cfg.norm, dtype),
                    "mlp": init_mlp(jax.random.fold_in(k4, 1), cfg.d_model,
                                    cfg.d_ff, dtype),
                    "gate_mlp": jnp.zeros((), dtype),
                }
                return {"selfs": selfs, "cross": cross}
            params["blocks"] = _stack_init(kB, n_super, one_super)
        elif fam == "audio":
            spec = AttnSpec.from_cfg(cfg)

            def one_enc(k):
                return _init_block(k, cfg, dtype, layer_is_moe=False)

            def one_dec(k):
                k1, k2, k3 = jax.random.split(k, 3)
                p = _init_block(k1, cfg, dtype, layer_is_moe=False)
                p["norm_x"] = init_norm(k2, cfg.d_model, cfg.norm, dtype)
                p["cross"] = att.init_cross_attention(k3, spec, cfg.d_model,
                                                      dtype)
                return p
            params["enc_blocks"] = _stack_init(kX, cfg.encoder_layers, one_enc)
            params["blocks"] = _stack_init(kB, cfg.num_layers, one_dec)
            params["enc_norm"] = init_norm(jax.random.fold_in(kN, 7),
                                           cfg.d_model, cfg.norm, dtype)
        else:
            raise ValueError(fam)
        return params

    # ----- helpers ----------------------------------------------------------
    def _logits(self, params, x):
        x = apply_norm(params["final_norm"], x, self.cfg.norm)
        w = params.get("unembed")
        if w is None:
            w = params["embed"].T
        return (x @ w).astype(jnp.float32)

    def _window_for(self, max_len: int) -> int:
        cfg = self.cfg
        if cfg.long_context == "sliding_window" and max_len > cfg.sliding_window:
            return cfg.sliding_window
        return 0

    # ----- training forward --------------------------------------------------
    def forward(self, params, batch, *, remat: bool = False, window: int = 0,
                return_hidden: bool = False):
        """Returns (logits (B,S,V) fp32, aux_loss scalar).

        ``window`` > 0 applies a sliding-window causal mask to the dense
        self-attention layers (training-time twin of the ring decode cache).
        ``return_hidden`` skips the unembedding and returns the final-norm
        hidden states instead (for chunked-loss training, which avoids
        materialising the full (B, S, V) logits tensor).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        aux_total = jnp.float32(0.0)
        fam = cfg.family

        if fam in ("dense", "moe"):
            def body(carry, lp):
                x, aux = carry
                x, a = _block_forward(lp, x, positions, cfg, window=window)
                return (x, aux + a), None
            body_fn = jax.checkpoint(body) if remat else body
            if "dense_blocks" in params:
                (x, aux_total), _ = _scan(
                    body_fn, (x, aux_total), params["dense_blocks"])
            (x, aux_total), _ = _scan(body_fn, (x, aux_total),
                                             params["blocks"])
        elif fam == "ssm":
            def body(x, lp):
                h = apply_norm(lp["norm"], x, cfg.norm)
                x = x + ssm_lib.mamba_forward(lp["mamba"], h, cfg)
                return x, None
            body_fn = jax.checkpoint(body) if remat else body
            x, _ = _scan(body_fn, x, params["blocks"])
        elif fam == "hybrid":
            shared = params["shared_attn"]

            def super_body(x, lp):
                def inner(x, mp):
                    h = apply_norm(mp["norm"], x, cfg.norm)
                    x = x + ssm_lib.mamba_forward(mp["mamba"], h, cfg)
                    return x, None
                x, _ = _scan(inner, x, lp)
                x, _ = _block_forward(shared, x, positions, cfg)
                return x, None
            body_fn = jax.checkpoint(super_body) if remat else super_body
            x, _ = _scan(body_fn, x, params["blocks"])
        elif fam == "vlm":
            spec = AttnSpec.from_cfg(cfg)
            img = batch["image_embeds"].astype(x.dtype)

            def super_body(x, lp):
                def inner(x, sp):
                    x, _ = _block_forward(sp, x, positions, cfg)
                    return x, None
                x, _ = _scan(inner, x, lp["selfs"])
                cp = lp["cross"]
                h = apply_norm(cp["norm1"], x, cfg.norm)
                kv = att.cross_kv(cp["attn"], img, spec)
                x = x + att.cross_attention_forward(cp["attn"], h, kv, spec)
                h2 = apply_norm(cp["norm2"], x, cfg.norm)
                g = jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
                x = x + g * apply_mlp(cp["mlp"], h2, cfg.act)
                return x, None
            body_fn = jax.checkpoint(super_body) if remat else super_body
            x, _ = _scan(body_fn, x, params["blocks"])
        elif fam == "audio":
            enc = self._encode(params, batch, remat=remat)
            spec = AttnSpec.from_cfg(cfg)

            def body(x, lp):
                x, _ = _block_forward_cross(lp, x, positions, enc, cfg, spec)
                return x, None
            body_fn = jax.checkpoint(body) if remat else body
            x, _ = _scan(body_fn, x, params["blocks"])
        if return_hidden:
            x = apply_norm(params["final_norm"], x, self.cfg.norm)
            return x, aux_total
        return self._logits(params, x), aux_total

    def unembed(self, params, hidden):
        """hidden (B, C, d) -> fp32 logits (B, C, V); pairs with
        forward(return_hidden=True)."""
        w = params.get("unembed")
        if w is None:
            w = params["embed"].T
        return (hidden @ w).astype(jnp.float32)

    def _encode(self, params, batch, *, remat=False):
        cfg = self.cfg
        frames = batch["audio_frames"].astype(self.dtype)
        B, F, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
        x = frames

        def body(x, lp):
            x, _ = _block_forward(lp, x, pos, cfg, causal=False)
            return x, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = _scan(body_fn, x, params["enc_blocks"])
        return apply_norm(params["enc_norm"], x, cfg.norm)

    # ----- caches -------------------------------------------------------------
    def init_cache(self, params_or_none, batch_size: int, max_len: int,
                   batch: Optional[dict] = None):
        """Zero cache pytree for ``decode_step``.  ``params_or_none`` and
        ``batch`` are only needed for cross-attention archs (to precompute
        cross-KV); pass None for a pure spec."""
        cfg, dtype = self.cfg, self.dtype
        B = batch_size
        window = self._window_for(max_len)
        W = window or max_len
        hd = cfg.resolved_head_dim
        K = cfg.num_kv_heads
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        fam = cfg.family
        if fam in ("dense", "moe", "vlm", "audio"):
            if cfg.mla is not None:
                m = cfg.mla
                n_moe = cfg.num_layers - cfg.moe.first_dense_layers
                cache["latent"] = jnp.zeros((n_moe, B, W, m.kv_lora_rank), dtype)
                cache["k_rope"] = jnp.zeros((n_moe, B, W, m.qk_rope_head_dim),
                                            dtype)
                nd = cfg.moe.first_dense_layers
                if nd:
                    cache["latent0"] = jnp.zeros((nd, B, W, m.kv_lora_rank),
                                                 dtype)
                    cache["k_rope0"] = jnp.zeros(
                        (nd, B, W, m.qk_rope_head_dim), dtype)
            else:
                if fam == "vlm":
                    n_super = cfg.num_layers // cfg.cross_attn_every
                    n_self = n_super * (cfg.cross_attn_every - 1)
                    cache["k"] = jnp.zeros(
                        (n_super, cfg.cross_attn_every - 1, B, W, K, hd), dtype)
                    cache["v"] = jnp.zeros_like(cache["k"])
                    cache["cross_k"] = jnp.zeros(
                        (n_super, B, cfg.num_image_tokens, K, hd), dtype)
                    cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
                else:
                    L = cfg.num_layers
                    cache["k"] = jnp.zeros((L, B, W, K, hd), dtype)
                    cache["v"] = jnp.zeros_like(cache["k"])
                if fam == "audio":
                    F = cfg.num_audio_frames
                    cache["cross_k"] = jnp.zeros((cfg.num_layers, B, F, K, hd),
                                                 dtype)
                    cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        elif fam == "ssm":
            d_inner, nh, d_bc = ssm_lib.dims(cfg)
            L = cfg.num_layers
            cache["ssm"] = jnp.zeros((L, B, nh, d_inner // nh,
                                      cfg.ssm.d_state), jnp.float32)
            cache["conv_x"] = jnp.zeros((L, B, d_inner, cfg.ssm.d_conv - 1),
                                        dtype)
            cache["conv_bc"] = jnp.zeros((L, B, d_bc, cfg.ssm.d_conv - 1),
                                         dtype)
        elif fam == "hybrid":
            d_inner, nh, d_bc = ssm_lib.dims(cfg)
            per = cfg.shared_attn_every
            n_super = cfg.num_layers // per
            cache["ssm"] = jnp.zeros((n_super, per, B, nh, d_inner // nh,
                                      cfg.ssm.d_state), jnp.float32)
            cache["conv_x"] = jnp.zeros((n_super, per, B, d_inner,
                                         cfg.ssm.d_conv - 1), dtype)
            cache["conv_bc"] = jnp.zeros((n_super, per, B, d_bc,
                                          cfg.ssm.d_conv - 1), dtype)
            Wa = min(W, cfg.sliding_window)
            cache["k"] = jnp.zeros((n_super, B, Wa, K, hd), dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
        # cross-KV fill for real runs
        if params_or_none is not None and batch is not None:
            spec = AttnSpec.from_cfg(cfg)
            if fam == "vlm":
                img = batch["image_embeds"].astype(dtype)
                ck, cv = jax.vmap(
                    lambda lp: att.cross_kv(lp["cross"]["attn"], img, spec)
                )(params_or_none["blocks"])
                cache["cross_k"], cache["cross_v"] = ck, cv
            elif fam == "audio":
                enc = self._encode(params_or_none, batch)
                ck, cv = jax.vmap(
                    lambda lp: att.cross_kv(lp["cross"], enc, spec)
                )(params_or_none["blocks"])
                cache["cross_k"], cache["cross_v"] = ck, cv
        return cache

    # ----- prefill ------------------------------------------------------------
    def prefill(self, params, batch, max_len: int):
        """Run the prompt, return (last-token logits (B,V), cache at pos=S)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        window = self._window_for(max_len)
        W = window or max_len
        x = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        # cross-KV (vlm/audio) is produced by the scans below; skip the
        # init_cache fill to avoid computing it twice.
        cache = self.init_cache(None, B, max_len, None)
        cache["pos"] = jnp.int32(S)
        fam = cfg.family

        def place(kv):  # (B,S,K,hd) -> ring-placed (B,W,K,hd)
            return _ring_place(kv, S, W)

        if fam in ("dense", "moe"):
            if cfg.mla is not None:
                def body(x, lp):
                    x, _, c = _block_forward(lp, x, positions, cfg,
                                             return_cache=True)
                    lat, kr = c
                    return x, (_ring_place(lat, S, W),
                               _ring_place(kr, S, W))
                if "dense_blocks" in params:
                    x, (l0, r0) = _scan(body, x, params["dense_blocks"])
                    cache["latent0"], cache["k_rope0"] = l0, r0
                x, (lat, kr) = _scan(body, x, params["blocks"])
                cache["latent"], cache["k_rope"] = lat, kr
            else:
                def body(x, lp):
                    x, _, (k, v) = _block_forward(lp, x, positions, cfg,
                                                  window=window,
                                                  return_cache=True)
                    return x, (place(k), place(v))
                x, (ks, vs) = _scan(body, x, params["blocks"])
                cache["k"], cache["v"] = ks, vs
        elif fam == "ssm":
            def body(x, lp):
                h = apply_norm(lp["norm"], x, cfg.norm)
                y, st = ssm_lib.mamba_forward(lp["mamba"], h, cfg,
                                              return_state=True)
                return x + y, st
            x, (ssm_states, (cxs, cbcs)) = _scan(body, x,
                                                        params["blocks"])
            cache["ssm"], cache["conv_x"], cache["conv_bc"] = \
                ssm_states, cxs, cbcs
        elif fam == "hybrid":
            shared = params["shared_attn"]
            Wa = cache["k"].shape[2]
            wina = Wa if Wa < max_len else 0

            def super_body(x, lp):
                def inner(x, mp):
                    h = apply_norm(mp["norm"], x, cfg.norm)
                    y, st = ssm_lib.mamba_forward(mp["mamba"], h, cfg,
                                                  return_state=True)
                    return x + y, st
                x, sts = _scan(inner, x, lp)
                x, _, (k, v) = _block_forward(shared, x, positions, cfg,
                                              window=wina, return_cache=True)
                return x, (sts, (_ring_place(k, S, Wa), _ring_place(v, S, Wa)))
            x, (sts, kv) = _scan(super_body, x, params["blocks"])
            cache["ssm"], (cache["conv_x"], cache["conv_bc"]) = sts
            cache["k"], cache["v"] = kv
        elif fam == "vlm":
            spec = AttnSpec.from_cfg(cfg)

            def super_body(x, lp):
                def inner(x, sp):
                    x, _, (k, v) = _block_forward(sp, x, positions, cfg,
                                                  window=window,
                                                  return_cache=True)
                    return x, (place(k), place(v))
                x, kv = _scan(inner, x, lp["selfs"])
                cp = lp["cross"]
                ckv = att.cross_kv(cp["attn"], batch["image_embeds"].astype(
                    x.dtype), spec)
                h = apply_norm(cp["norm1"], x, cfg.norm)
                x = x + att.cross_attention_forward(cp["attn"], h, ckv, spec)
                h2 = apply_norm(cp["norm2"], x, cfg.norm)
                g = jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
                x = x + g * apply_mlp(cp["mlp"], h2, cfg.act)
                return x, (kv, ckv)
            x, ((ks, vs), (cks, cvs)) = _scan(super_body, x,
                                                     params["blocks"])
            cache["k"], cache["v"] = ks, vs
            cache["cross_k"], cache["cross_v"] = cks, cvs
        elif fam == "audio":
            enc = self._encode(params, batch)
            spec = AttnSpec.from_cfg(cfg)

            def body(x, lp):
                ckv = att.cross_kv(lp["cross"], enc, spec)
                x, (k, v) = _block_forward_cross(lp, x, positions, enc, cfg,
                                                 spec, window=window,
                                                 return_cache=True)
                return x, ((place(k), place(v)), ckv)
            x, ((ks, vs), (cks, cvs)) = _scan(body, x, params["blocks"])
            cache["k"], cache["v"] = ks, vs
            cache["cross_k"], cache["cross_v"] = cks, cvs
        logits = self._logits(params, x[:, -1:, :])[:, 0, :]
        return logits, cache

    # ----- decode ---------------------------------------------------------------
    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B,V) fp32, updated cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = params["embed"][tokens]
        fam = cfg.family
        window_flag = 0
        if fam in ("dense", "moe", "vlm", "audio") and cfg.mla is None:
            W = cache["k"].shape[-3]
        elif cfg.mla is not None:
            W = cache["latent"].shape[2]
        else:
            W = 0
        if cfg.long_context == "sliding_window" and W and \
                W == cfg.sliding_window:
            window_flag = W

        if fam in ("dense", "moe"):
            if cfg.mla is not None:
                def body(x, inp):
                    lp, lat, kr = inp
                    h = apply_norm(lp["norm1"], x, cfg.norm)
                    a, (lat, kr) = att.mla_decode(lp["attn"], h, pos, lat, kr,
                                                  cfg)
                    x = x + a
                    h2 = apply_norm(lp["norm2"], x, cfg.norm)
                    if "moe" in lp:
                        m, _ = moe_lib.apply_moe(lp["moe"], h2, cfg.moe,
                                                 cfg.act)
                    else:
                        m = apply_mlp(lp["mlp"], h2, cfg.act)
                    return x + m, (lat, kr)
                if "dense_blocks" in params:
                    x, (l0, r0) = _scan(
                        body, x, (params["dense_blocks"], cache["latent0"],
                                  cache["k_rope0"]))
                    cache["latent0"], cache["k_rope0"] = l0, r0
                x, (lat, kr) = _scan(
                    body, x, (params["blocks"], cache["latent"],
                              cache["k_rope"]))
                cache["latent"], cache["k_rope"] = lat, kr
            else:
                def body(x, inp):
                    lp, k, v = inp
                    x, k, v = _block_decode(lp, x, pos, k, v, cfg,
                                            window=window_flag)
                    return x, (k, v)
                x, (ks, vs) = _scan(body, x, (params["blocks"],
                                                     cache["k"], cache["v"]))
                cache["k"], cache["v"] = ks, vs
        elif fam == "ssm":
            def body(x, inp):
                lp, st, cx, cbc = inp
                h = apply_norm(lp["norm"], x, cfg.norm)
                y, (st, (cx, cbc)) = ssm_lib.mamba_decode(
                    lp["mamba"], h, (st, (cx, cbc)), cfg)
                return x + y, (st, cx, cbc)
            x, (sts, cxs, cbcs) = _scan(
                body, x, (params["blocks"], cache["ssm"], cache["conv_x"],
                          cache["conv_bc"]))
            cache["ssm"], cache["conv_x"], cache["conv_bc"] = sts, cxs, cbcs
        elif fam == "hybrid":
            shared = params["shared_attn"]
            Wa = cache["k"].shape[2]
            wina = Wa if Wa < 10**9 and Wa == cfg.sliding_window else 0

            def super_body(x, inp):
                lp, st, cx, cbc, k, v = inp

                def inner(x, minp):
                    mp, s1, c1, c2 = minp
                    h = apply_norm(mp["norm"], x, cfg.norm)
                    y, (s1, (c1, c2)) = ssm_lib.mamba_decode(
                        mp["mamba"], h, (s1, (c1, c2)), cfg)
                    return x + y, (s1, c1, c2)
                x, (st, cx, cbc) = _scan(inner, x, (lp, st, cx, cbc))
                x, k, v = _block_decode(shared, x, pos, k, v, cfg, window=wina)
                return x, (st, cx, cbc, k, v)
            x, (sts, cxs, cbcs, ks, vs) = _scan(
                super_body, x, (params["blocks"], cache["ssm"],
                                cache["conv_x"], cache["conv_bc"],
                                cache["k"], cache["v"]))
            cache["ssm"], cache["conv_x"], cache["conv_bc"] = sts, cxs, cbcs
            cache["k"], cache["v"] = ks, vs
        elif fam == "vlm":
            spec = AttnSpec.from_cfg(cfg)

            def super_body(x, inp):
                lp, k, v, ck, cv = inp

                def inner(x, sinp):
                    sp, k1, v1 = sinp
                    x, k1, v1 = _block_decode(sp, x, pos, k1, v1, cfg,
                                              window=window_flag)
                    return x, (k1, v1)
                x, (k, v) = _scan(inner, x, (lp["selfs"], k, v))
                cp = lp["cross"]
                h = apply_norm(cp["norm1"], x, cfg.norm)
                x = x + att.cross_attention_forward(cp["attn"], h, (ck, cv),
                                                    spec)
                h2 = apply_norm(cp["norm2"], x, cfg.norm)
                g = jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype)
                x = x + g * apply_mlp(cp["mlp"], h2, cfg.act)
                return x, (k, v)
            x, (ks, vs) = _scan(
                super_body, x, (params["blocks"], cache["k"], cache["v"],
                                cache["cross_k"], cache["cross_v"]))
            cache["k"], cache["v"] = ks, vs
        elif fam == "audio":
            spec = AttnSpec.from_cfg(cfg)

            def body(x, inp):
                lp, k, v, ck, cv = inp
                h = apply_norm(lp["norm1"], x, cfg.norm)
                a, (k, v) = att.attention_decode(lp["attn"], h, pos, k, v,
                                                 spec, window=window_flag)
                x = x + a
                hx = apply_norm(lp["norm_x"], x, cfg.norm)
                x = x + att.cross_attention_forward(lp["cross"], hx, (ck, cv),
                                                    spec)
                h2 = apply_norm(lp["norm2"], x, cfg.norm)
                x = x + apply_mlp(lp["mlp"], h2, cfg.act)
                return x, (k, v)
            x, (ks, vs) = _scan(
                body, x, (params["blocks"], cache["k"], cache["v"],
                          cache["cross_k"], cache["cross_v"]))
            cache["k"], cache["v"] = ks, vs
        cache["pos"] = pos + 1
        logits = self._logits(params, x)[:, 0, :]
        return logits, cache


def _block_forward_cross(lp, x, positions, enc, cfg, spec, *, window=0,
                         return_cache=False):
    """Enc-dec decoder block: self-attn + cross-attn + FFN."""
    h = apply_norm(lp["norm1"], x, cfg.norm)
    out = att.attention_forward(lp["attn"], h, positions, spec, causal=True,
                                window=window, return_cache=return_cache)
    if return_cache:
        a, kv = out
    else:
        a, kv = out, None
    x = x + a
    hx = apply_norm(lp["norm_x"], x, cfg.norm)
    ckv = att.cross_kv(lp["cross"], enc, spec)
    x = x + att.cross_attention_forward(lp["cross"], hx, ckv, spec)
    h2 = apply_norm(lp["norm2"], x, cfg.norm)
    x = x + apply_mlp(lp["mlp"], h2, cfg.act)
    return (x, kv) if return_cache else (x, jnp.float32(0.0))


def _ring_place(kv, S: int, W: int):
    """Place a (B, S, ...) prefill cache into a (B, W, ...) ring buffer.

    Slot j holds the latest position p < S with p % W == j.
    """
    if S == W:
        return kv
    if S < W:
        pad = [(0, 0)] * kv.ndim
        pad[1] = (0, W - S)
        return jnp.pad(kv, pad)
    j = jnp.arange(W)
    src = (S - 1) - jnp.mod((S - 1) - j, W)
    return jnp.take(kv, src, axis=1)


def _stack_init(key, n: int, init_one):
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def build_model(cfg: ArchConfig, dtype=None) -> Model:
    d = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype] \
        if dtype is None else dtype
    return Model(cfg, d)
