"""Mixture-of-Experts FFN with grouped, capacity-based one-hot dispatch.

Dispatch follows the GShard/MaxText pattern: tokens are split into groups of
``GROUP_SIZE``; within each group they are routed to per-expert capacity
buffers with one-hot dispatch einsums, the expert FFN runs on the
(G, E, C, d) buffers, and combine weights scatter the outputs back.  With
experts sharded over the "model" mesh axis this lowers to the expected
all-to-all / all-gather traffic, and compiled FLOPs track *active* (not
total) expert compute — which keeps the roofline's MODEL_FLOPS/HLO_FLOPs
ratio honest.  Grouping bounds the dispatch tensor to
T × E × C/group ≈ T · top_k · 1.25 · E/E elements instead of T · E · C_full.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.layers import act_fn, dense_init

CAPACITY_FACTOR = 1.25  # default; per-config override via MoEConfig.capacity_factor
GROUP_SIZE = 256


def _ep_constraint(x, spec):
    """Expert-parallel layout constraint (§Perf): force the dispatched
    activations onto (groups->data, experts->model) so GSPMD emits
    all-to-alls instead of replicating token activations across the model
    axis.  Enabled via REPRO_MOE_CONSTRAINT=1 (requires a mesh context)."""
    if os.environ.get("REPRO_MOE_CONSTRAINT") != "1":
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def init_moe(key, d_model: int, mo: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    E, dff = mo.num_experts, mo.d_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), dtype, scale=0.1),
        "w_gate": dense_init(ks[1], (E, d_model, dff), dtype),
        "w_up": dense_init(ks[2], (E, d_model, dff), dtype),
        "w_down": dense_init(ks[3], (E, dff, d_model), dtype),
    }
    if mo.num_shared_experts:
        d_sh = mo.d_shared * mo.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], (d_model, d_sh), dtype),
            "w_up": dense_init(kk[1], (d_model, d_sh), dtype),
            "w_down": dense_init(kk[2], (d_sh, d_model), dtype),
        }
    return p


def _group_size(T: int) -> int:
    gs = min(T, GROUP_SIZE)
    while T % gs:
        gs -= 1
    return gs


def capacity(tokens_per_group: int, mo: MoEConfig) -> int:
    cf = mo.capacity_factor
    c = int(tokens_per_group * mo.top_k * cf / mo.num_experts) + 1
    return max(4, min(c, tokens_per_group))


def apply_moe(p, x, mo: MoEConfig, act: str):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    gs = _group_size(T)
    G = T // gs
    E, K = mo.num_experts, mo.top_k
    C = capacity(gs, mo)
    fn = act_fn(act)

    xg = x.reshape(G, gs, d)
    logits = (xg @ p["router"]).astype(jnp.float32)          # (G, gs, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                 # (G, gs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # per-(token,k) slot inside its expert's capacity buffer, within the group
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # (G, gs, K, E)
    flat = onehot.reshape(G, gs * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat               # (G, gs*K, E)
    pos = jnp.sum(flat * pos_in_e, axis=-1).reshape(G, gs, K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # build (G, gs, E, C) dispatch/combine without materialising the K axis
    dispatch = jnp.zeros((G, gs, E, C), dtype=x.dtype)
    combine = jnp.zeros((G, gs, E, C), dtype=x.dtype)
    for k in range(K):
        oe = jax.nn.one_hot(idx[..., k], E, dtype=x.dtype)   # (G, gs, E)
        oc = jax.nn.one_hot(jnp.where(keep[..., k], pos[..., k], C),
                            C + 1, dtype=x.dtype)[..., :-1]  # (G, gs, C)
        d_k = oe[..., None] * oc[..., None, :]
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_vals[..., k, None, None].astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)          # (G, E, C, d)
    xe = _ep_constraint(xe, ("data", "model", None, None))
    h = fn(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # (G, E, C, d)
    ye = _ep_constraint(ye, ("data", "model", None, None))
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)            # (G, gs, d)
    y = y.reshape(B, S, d)

    if "shared" in p:
        sh = p["shared"]
        hs = fn(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    # load-balance aux loss (Switch style)
    pm = probs.reshape(T, E)
    me = jnp.mean(pm, axis=0)                                # (E,)
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0].reshape(T), E,
                                   dtype=jnp.float32), axis=0)
    aux = mo.router_aux_weight * E * jnp.sum(me * frac)
    return y, aux
