"""Mamba-2 block (SSD — state-space duality), chunked prefill + one-step decode.

Shapes (G = 1 state group):
  projections : in_z/in_x (d, d_inner), in_bc (d, 2N), in_dt (d, nh)
  x heads     : (B, S, nh, hd)      B/C: (B, S, N)
  ssm state   : (B, nh, hd, N)
  conv states : (B, d_inner, d_conv-1) and (B, 2N, d_conv-1)

The input projection is intentionally SPLIT per segment (z, x, BC, dt)
rather than fused: under tensor parallelism the z/x projections column-shard
over the "model" axis (head-parallel SSD), while the small BC/dt projections
stay replicated — a fused in_proj would put shard boundaries across segment
edges and force GSPMD to reshard every slice.  The depthwise conv is split
the same way (conv over x, conv over BC), which is mathematically identical
to Mamba-2's conv over the concat.

The chunked algorithm follows arXiv:2405.21060 §6: intra-chunk (quadratic
within chunk, batched matmuls → MXU-friendly) + inter-chunk recurrence over
chunk states (lax.scan).  kernels/ssd_scan provides the Pallas version of
the same computation; this module is the jnp reference used for training
and the architectures' default path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, gated_rmsnorm, init_norm


def dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.d_inner(cfg.d_model)
    nh = ssm.n_heads(cfg.d_model)
    return d_inner, nh, 2 * ssm.d_state


def init_mamba_block(key, cfg: ArchConfig, dtype):
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, nh, d_bc = dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_z": dense_init(ks[0], (d, d_inner), dtype),
        "in_x": dense_init(ks[1], (d, d_inner), dtype),
        "in_bc": dense_init(ks[2], (d, d_bc), dtype),
        "in_dt": dense_init(ks[3], (d, nh), dtype),
        "conv_x": dense_init(ks[4], (d_inner, ssm.d_conv), dtype, scale=1.0),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc": dense_init(ks[5], (d_bc, ssm.d_conv), dtype, scale=1.0),
        "conv_bc_b": jnp.zeros((d_bc,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_norm(ks[6], d_inner, "rmsnorm", dtype),
        "out_proj": dense_init(ks[7], (d_inner, d), dtype),
    }


def _causal_conv(x, w, b, d_conv: int):
    """Depthwise causal conv over seq. x: (B, S, C), w: (C, d_conv)."""
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x) + b.astype(x.dtype)
    S = x.shape[1]
    for i in range(d_conv):
        acc = acc + pad[:, i:i + S, :] * w[:, i]
    return jax.nn.silu(acc)


def ssd_chunked(xh, dt, A, Bmat, Cmat, chunk: int, initial_state=None):
    """Chunked SSD scan.

    xh: (B,S,nh,hd)  dt: (B,S,nh) fp32  A: (nh,) fp32 (negative)
    Bmat/Cmat: (B,S,N).  Returns (y (B,S,nh,hd), final_state (B,nh,hd,N)).
    """
    B, S, nh, hd = xh.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    NC = S // Q
    f32 = jnp.float32

    xq = xh.reshape(B, NC, Q, nh, hd)
    dtq = dt.reshape(B, NC, Q, nh)
    Bq = Bmat.reshape(B, NC, Q, N).astype(f32)
    Cq = Cmat.reshape(B, NC, Q, N).astype(f32)

    a = dtq * A                                      # (B,NC,Q,nh)
    a_cs = jnp.cumsum(a, axis=2)                     # inclusive cumsum
    # intra-chunk: L[i,j] = exp(a_cs[i] - a_cs[j]) for i >= j
    li = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]   # (B,NC,Q,Q,nh)
    iq = jnp.arange(Q)
    tri = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)       # (B,NC,Q,Q)
    M = cb[..., None] * L * dtq[:, :, None, :, :]    # weight on x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(xh.dtype), xq)

    # chunk states: sum_j B_j ⊗ x_j * dt_j * exp(a_cs[-1] - a_cs[j])
    decay_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)   # (B,NC,Q,nh)
    w = (dtq * decay_end).astype(f32)                # (B,NC,Q,nh)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bq, w,
                        xq.astype(f32))              # (B,NC,nh,hd,N)

    # inter-chunk recurrence
    a_sum = a_cs[:, :, -1, :]                        # (B,NC,nh)
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, N), f32)

    def step(carry, inp):
        st_c, decay_c = inp                          # (B,nh,hd,N), (B,nh)
        prev = carry
        new = jnp.exp(decay_c)[:, :, None, None] * prev + st_c
        return new, prev

    final, prevs = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_sum, 1, 0)))
    prev_states = jnp.moveaxis(prevs, 0, 1)          # (B,NC,nh,hd,N)

    # inter-chunk contribution: C_i · (exp(a_cs[i]) * prev_state)
    c_decay = jnp.exp(a_cs)                          # (B,NC,Q,nh)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cq,
                         c_decay.astype(f32), prev_states)
    y = y_intra.astype(f32) + y_inter
    return y.reshape(B, S, nh, hd), final


def mamba_forward(p, x, cfg: ArchConfig, *, return_state=False,
                  initial_state=None):
    """Full-sequence Mamba-2 block. x: (B,S,d) -> (B,S,d)."""
    ssm = cfg.ssm
    d_inner, nh, d_bc = dims(cfg)
    hd = ssm.head_dim
    B, S, _ = x.shape
    N = ssm.d_state

    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    bc = x @ p["in_bc"]
    dt = x @ p["in_dt"]

    def tail(v):
        if S >= ssm.d_conv - 1:
            return v[:, -(ssm.d_conv - 1):, :]
        return jnp.pad(v, ((0, 0), (ssm.d_conv - 1 - S, 0), (0, 0)))
    conv_x_tail, conv_bc_tail = tail(xr), tail(bc)

    xr = _causal_conv(xr, p["conv_x"], p["conv_x_b"], ssm.d_conv)
    bc = _causal_conv(bc, p["conv_bc"], p["conv_bc_b"], ssm.d_conv)
    xs = xr.reshape(B, S, nh, hd)
    Bmat, Cmat = bc[..., :N], bc[..., N:]
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(xs, dtf, A, Bmat, Cmat, ssm.chunk,
                           initial_state=initial_state)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = gated_rmsnorm(p["norm"], y, z)
    out = y @ p["out_proj"]
    if return_state:
        conv_state = (jnp.moveaxis(conv_x_tail, 1, 2),
                      jnp.moveaxis(conv_bc_tail, 1, 2))
        return out, (final, conv_state)
    return out


def mamba_decode(p, x, state: Tuple, cfg: ArchConfig):
    """One-token decode. x: (B,1,d); state = (ssm_state, (conv_x, conv_bc))."""
    ssm = cfg.ssm
    d_inner, nh, d_bc = dims(cfg)
    hd = ssm.head_dim
    N = ssm.d_state
    B = x.shape[0]
    ssm_state, (cx, cbc) = state            # (B,nh,hd,N), (B,d_inner,3), ...
    xt = x[:, 0, :]
    z = xt @ p["in_z"]
    xr = xt @ p["in_x"]
    bc = xt @ p["in_bc"]
    dt = xt @ p["in_dt"]

    def conv_step(prev, new, w, b):
        win = jnp.concatenate([prev, new[:, :, None]], axis=-1)
        out = jax.nn.silu(jnp.sum(win * w[None], axis=-1) + b)
        return out, win[:, :, 1:]
    xr, cx = conv_step(cx, xr, p["conv_x"], p["conv_x_b"])
    bc, cbc = conv_step(cbc, bc, p["conv_bc"], p["conv_bc_b"])

    xs = xr.reshape(B, nh, hd)
    Bv = bc[:, :N].astype(jnp.float32)
    Cv = bc[:, N:].astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtf * A)                          # (B,nh)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dtf, Bv, xs.astype(jnp.float32))
    ssm_state = decay[:, :, None, None] * ssm_state + upd
    y = jnp.einsum("bn,bhpn->bhp", Cv, ssm_state)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = gated_rmsnorm(p["norm"], y, z[:, None, :])
    return y @ p["out_proj"], (ssm_state, (cx, cbc))
