"""Unified observability: metrics, request tracing, structured logs.

One :class:`Obs` object wires the three pillars together and owns the
run directory every artifact lands in:

  * ``metrics.json``      — merged :class:`~repro.obs.metrics.MetricsRegistry`
                            snapshot (counters/gauges/histograms)
  * ``serving_log.jsonl`` — one record per served request
                            (:class:`~repro.obs.serving_log.ServingLog`)
  * ``trace.jsonl``       — sampled request spans
                            (:class:`~repro.obs.tracing.Tracer`)
  * ``events.jsonl``      — structured training/scenario events
                            (regime switches, segment closes, recovery)

``launch/serve.py --obs-dir DIR --trace-sample P`` and
``launch/train.py --obs-dir DIR`` construct one; ``launch/obs_report.py
DIR`` renders the directory back into per-regime summaries.  The design
contract, enforced by ``tests/test_obs_parity.py``: serving and training
RESULTS are bit-identical with observability on or off — obs reads
timing and copies values, it never touches an rng, a cache key, or an
accounting quantity — and the instrumented hot path stays within noise
of the bare one (``benchmarks/run.py obs_overhead``, gated).

A disabled ``Obs`` (or simply passing ``obs=None`` everywhere) costs a
branch check per call site: the registry hands out no-op metrics and the
tracer never samples.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import (DEFAULT_MS_BUCKETS, MetricsRegistry,
                               counters_snapshot, empty_snapshot,
                               hist_quantile, merge_snapshots)
from repro.obs.prom import parse_prometheus, render_prometheus
from repro.obs.serving_log import ServingLog, read_serving_log
from repro.obs.tracing import NULL_SPAN, Tracer

__all__ = ["Obs", "MetricsRegistry", "Tracer", "ServingLog",
           "merge_snapshots", "counters_snapshot", "empty_snapshot",
           "hist_quantile", "read_serving_log", "DEFAULT_MS_BUCKETS",
           "NULL_SPAN", "render_prometheus", "parse_prometheus"]


class Obs:
    """Umbrella handle for one run's observability.

    Parameters
    ----------
    out_dir:      run directory for the JSON/JSONL artifacts (created;
                  ``None`` keeps everything in memory).
    trace_sample: fraction of requests traced (0 = tracing off/free).
    enabled:      master switch — ``False`` makes every surface no-op.
    seed:         trace sampler seed (isolated from user rngs).
    """

    def __init__(self, out_dir: Optional[str] = None,
                 trace_sample: float = 0.0, enabled: bool = True,
                 seed: int = 0):
        self.enabled = bool(enabled)
        self.out_dir = out_dir
        if out_dir is not None and self.enabled:
            os.makedirs(out_dir, exist_ok=True)
        self.metrics = MetricsRegistry(enabled=self.enabled)
        self._lock = threading.Lock()
        self._trace_f = None
        self._events_f = None
        self.tracer = Tracer(
            sample=trace_sample if self.enabled else 0.0,
            writer=self._write_trace if (out_dir and self.enabled
                                         and trace_sample > 0) else None,
            seed=seed)
        self.serving_log: Optional[ServingLog] = None
        self.events: List[dict] = []

    # -- serving log -------------------------------------------------------
    def open_serving_log(self, provider_names: Optional[Sequence[str]]
                         = None, gts: Optional[Sequence] = None,
                         retain: int = 0) -> Optional[ServingLog]:
        """Attach the per-request serving log (call once, before
        traffic).  No-op when disabled."""
        if not self.enabled:
            return None
        path = None if self.out_dir is None else \
            os.path.join(self.out_dir, "serving_log.jsonl")
        self.serving_log = ServingLog(path, provider_names=provider_names,
                                      gts=gts, retain=retain)
        return self.serving_log

    # -- structured events -------------------------------------------------
    def event(self, name: str, **fields) -> None:
        """Record one structured event (regime switch, segment close,
        recovery ...) — JSON-safe fields only."""
        if not self.enabled:
            return
        rec = {"event": name, "ts": time.time(), **fields}
        with self._lock:
            self.events.append(rec)
            if self.out_dir is not None:
                if self._events_f is None:
                    self._events_f = open(
                        os.path.join(self.out_dir, "events.jsonl"), "a")
                self._events_f.write(json.dumps(rec) + "\n")

    # -- sinks -------------------------------------------------------------
    def _write_trace(self, span: dict) -> None:
        with self._lock:
            if self._trace_f is None:
                self._trace_f = open(
                    os.path.join(self.out_dir, "trace.jsonl"), "a")
            self._trace_f.write(json.dumps(span) + "\n")

    def write_metrics(self, extra_snapshots: Sequence[Dict] = ()) -> Dict:
        """Merge the registry with any extra snapshots (e.g. worker-side
        registries shipped over the shard pipe) and write
        ``metrics.json`` plus its Prometheus text twin ``metrics.prom``
        (the same exposition ``/metrics`` serves).  Returns the merged
        snapshot."""
        snap = merge_snapshots(self.metrics.snapshot(), *extra_snapshots)
        if self.enabled and self.out_dir is not None:
            with open(os.path.join(self.out_dir, "metrics.json"),
                      "w") as f:
                json.dump(snap, f, indent=1)
            from repro.obs.prom import render_prometheus
            with open(os.path.join(self.out_dir, "metrics.prom"),
                      "w") as f:
                f.write(render_prometheus(snap))
        return snap

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self.serving_log is not None:
            self.serving_log.close()
        with self._lock:
            for f in (self._trace_f, self._events_f):
                if f is not None:
                    f.close()
            self._trace_f = self._events_f = None

    def __enter__(self) -> "Obs":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
