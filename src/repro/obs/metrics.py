"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only — worker processes import this without
pulling jax/numpy), with two properties the serving planes rely on:

  * **plain-dict snapshots** — :meth:`MetricsRegistry.snapshot` returns
    nothing but dicts/lists/floats, so a snapshot crosses the
    ``mp_shards`` pipe RPC as-is and lands in a JSON file unchanged.
  * **associative/commutative merge** — :func:`merge_snapshots` folds any
    number of snapshots in any order to the same result (counters and
    gauges sum; histogram bucket counts, sums and counts add; min/max
    take the extremes).  The parent merges W worker snapshots plus its
    own registry into ONE view regardless of which shard answered first
    (``tests/test_obs*.py`` property-test this).

Gauges merge by SUM because every cross-process use here is a
partitioned quantity (per-shard cache sizes, per-lane occupancy); a
gauge that must not sum across sources should carry the source in its
name (the per-shard RPC histograms do exactly that: ``...ms.s0``,
``...ms.s1``).

A registry built with ``enabled=False`` hands out shared no-op metric
instances: callers keep their handles, every ``inc``/``observe`` is a
single no-op method call, and ``snapshot()`` is empty — observability
off means observability free.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Dict, Optional, Sequence, Tuple

# default latency buckets (milliseconds): sub-ms dict lookups through
# multi-second cold lattice passes
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0)


class Counter:
    """Monotonic (between resets) additive metric."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class Gauge:
    """Last-written value with additive and running-max helpers."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self.value += v

    def set_max(self, v: float) -> None:
        with self._lock:
            if v > self.value:
                self.value = float(v)

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are inclusive upper edges, with
    an implicit +inf overflow bucket (``len(counts) == len(bounds) + 1``).
    Tracks sum/count/min/max alongside the bucket counts so merged
    snapshots keep exact means and extremes."""

    __slots__ = ("_lock", "bounds", "counts", "sum", "count", "vmin",
                 "vmax")

    def __init__(self, lock: threading.Lock,
                 bounds: Sequence[float] = DEFAULT_MS_BUCKETS):
        if list(bounds) != sorted(bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self._lock = lock
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect_right(self.bounds, v)] += 1
            self.sum += v
            self.count += 1
            if self.vmin is None or v < self.vmin:
                self.vmin = v
            if self.vmax is None or v > self.vmax:
                self.vmax = v

    def observe_batch(self, values: Sequence[float]) -> None:
        """One lock acquire for a whole batch of observations — the hot
        serving path records per-request quantities per FLUSH, not per
        request."""
        with self._lock:
            counts, bounds = self.counts, self.bounds
            for v in values:
                v = float(v)
                counts[bisect_right(bounds, v)] += 1
                self.sum += v
                self.count += 1
                if self.vmin is None or v < self.vmin:
                    self.vmin = v
                if self.vmax is None or v > self.vmax:
                    self.vmax = v

    def reset(self) -> None:
        with self._lock:
            self.counts = [0] * (len(self.bounds) + 1)
            self.sum = 0.0
            self.count = 0
            self.vmin = self.vmax = None


class _NullMetric:
    """Shared no-op stand-in handed out by a disabled registry."""

    __slots__ = ()
    value = 0.0
    bounds: Tuple[float, ...] = ()
    counts: list = []
    sum = 0.0
    count = 0
    vmin = vmax = None

    def inc(self, v: float = 1.0) -> None: pass           # noqa: E704
    def set(self, v: float) -> None: pass                 # noqa: E704
    def add(self, v: float) -> None: pass                 # noqa: E704
    def set_max(self, v: float) -> None: pass             # noqa: E704
    def observe(self, v: float) -> None: pass             # noqa: E704
    def observe_batch(self, values) -> None: pass         # noqa: E704
    def reset(self) -> None: pass                         # noqa: E704


NULL_METRIC = _NullMetric()


def empty_snapshot() -> Dict[str, dict]:
    return {"counters": {}, "gauges": {}, "histograms": {}}


class MetricsRegistry:
    """Name-keyed metric factory + snapshot surface.

    ``counter``/``gauge``/``histogram`` create on first use and return
    the same object afterwards (re-declaring a histogram with different
    bounds raises — merged snapshots require one bucket layout per
    name).  One lock guards both the name table and every metric's
    mutations: the hot path is one uncontended acquire per update, and a
    snapshot taken mid-traffic is internally consistent.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_METRIC        # type: ignore[return-value]
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(self._lock)
            return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_METRIC        # type: ignore[return-value]
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(self._lock)
            return g

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_MS_BUCKETS
                  ) -> Histogram:
        if not self.enabled:
            return NULL_METRIC        # type: ignore[return-value]
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(threading.Lock(),
                                                       bounds)
            elif h.bounds != tuple(float(b) for b in bounds):
                raise ValueError(
                    f"histogram {name!r} re-declared with different "
                    f"bounds: {h.bounds} vs {tuple(bounds)}")
            return h

    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict copy of every metric (JSON- and pickle-safe)."""
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = {}
        for k, h in list(self._histograms.items()):
            with h._lock:
                hists[k] = {"buckets": list(h.bounds),
                            "counts": list(h.counts), "sum": h.sum,
                            "count": h.count, "min": h.vmin,
                            "max": h.vmax}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def reset(self, prefix: Optional[str] = None) -> None:
        """Zero every metric (or only names under ``prefix``), keeping
        registrations and handed-out handles valid."""
        for table in (self._counters, self._gauges, self._histograms):
            for name, m in list(table.items()):
                if prefix is None or name.startswith(prefix):
                    m.reset()


def _merge_hist(a: dict, b: dict, name: str) -> dict:
    if list(a["buckets"]) != list(b["buckets"]):
        raise ValueError(f"cannot merge histogram {name!r}: bucket "
                         f"layouts differ ({a['buckets']} vs "
                         f"{b['buckets']})")
    mins = [v for v in (a["min"], b["min"]) if v is not None]
    maxs = [v for v in (a["max"], b["max"]) if v is not None]
    return {"buckets": list(a["buckets"]),
            "counts": [x + y for x, y in zip(a["counts"], b["counts"])],
            "sum": a["sum"] + b["sum"],
            "count": a["count"] + b["count"],
            "min": min(mins) if mins else None,
            "max": max(maxs) if maxs else None}


def merge_snapshots(*snaps: Dict[str, dict]) -> Dict[str, dict]:
    """Fold snapshots into one: counters/gauges sum, histograms add
    bucket-wise.  Associative and commutative — any grouping or ordering
    of the same snapshots merges to the same result, so the parent can
    fold worker replies as they arrive."""
    out = empty_snapshot()
    for snap in snaps:
        if snap is None:
            continue
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0.0) + v
        for k, v in snap.get("gauges", {}).items():
            out["gauges"][k] = out["gauges"].get(k, 0.0) + v
        for k, h in snap.get("histograms", {}).items():
            cur = out["histograms"].get(k)
            out["histograms"][k] = dict(h) if cur is None else \
                _merge_hist(cur, h, k)
    return out


def counters_snapshot(mapping: Dict[str, float],
                      prefix: str = "") -> Dict[str, dict]:
    """Lift a plain ``{name: value}`` dict (e.g. a core's cache-stats
    dict) into a mergeable snapshot of counters."""
    snap = empty_snapshot()
    snap["counters"] = {prefix + k: float(v) for k, v in mapping.items()}
    return snap


def hist_quantile(h: dict, q: float) -> Optional[float]:
    """Estimate the ``q``-quantile of a histogram snapshot by linear
    interpolation within its buckets (exact at the recorded min/max)."""
    total = h["count"]
    if not total:
        return None
    target = q * total
    lo, seen = 0.0, 0
    bounds = list(h["buckets"]) + [h["max"] if h["max"] is not None
                                   else float("inf")]
    for cnt, hi in zip(h["counts"], bounds):
        if seen + cnt >= target and cnt > 0:
            frac = (target - seen) / cnt
            lo_edge = max(lo, h["min"]) if h["min"] is not None else lo
            hi_edge = min(hi, h["max"]) if h["max"] is not None else hi
            if hi_edge < lo_edge:
                hi_edge = lo_edge
            return lo_edge + frac * (hi_edge - lo_edge)
        seen += cnt
        lo = hi
    return h["max"]
