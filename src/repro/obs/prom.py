"""Prometheus text exposition over metrics snapshots.

:func:`render_prometheus` turns one merged
:class:`~repro.obs.metrics.MetricsRegistry` snapshot into the classic
``text/plain; version=0.0.4`` exposition format — counters as
``counter``, gauges as ``gauge``, fixed-bucket histograms as the
standard cumulative ``_bucket{le="..."}`` / ``_sum`` / ``_count``
triple — so the serving plane's ``/metrics`` endpoint (and the
``metrics.prom`` artifact ``Obs.write_metrics`` drops next to
``metrics.json``) can be scraped by a stock Prometheus.

:func:`parse_prometheus` is the inverse over this module's own output
(the subset of the format we emit, not a general scraper): it rebuilds a
plain-dict snapshot, which is how ``obs_report --prom`` renders a scrape
and how tests close the round trip.  Exact ``min``/``max`` do not
survive the format (Prometheus histograms don't carry them), so parsed
histograms report them as ``None`` — quantile estimates then interpolate
on bucket edges alone.

Metric names are sanitized to the Prometheus grammar
(``[a-zA-Z_:][a-zA-Z0-9_:]*``): every other character becomes ``_``
(dots included — ``serving.flushes`` exports as ``serving_flushes``);
the original dotted name rides along in a ``# repro-name`` comment so
the parser restores it losslessly.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    out = _SANITIZE.sub("_", name)
    if not out or not _NAME_OK.match(out):
        out = "_" + out
    return out


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers without exponent/decimals,
    +Inf for the unbounded bucket."""
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snap: Dict[str, dict]) -> str:
    """One snapshot -> Prometheus text exposition (trailing newline
    included, as the format requires)."""
    lines: List[str] = []

    def _emit(orig: str, kind: str) -> str:
        pname = _prom_name(orig)
        if pname != orig:
            lines.append(f"# repro-name {pname} {orig}")
        lines.append(f"# TYPE {pname} {kind}")
        return pname

    for name in sorted(snap.get("counters", {})):
        pname = _emit(name, "counter")
        lines.append(f"{pname} {_fmt(snap['counters'][name])}")
    for name in sorted(snap.get("gauges", {})):
        pname = _emit(name, "gauge")
        lines.append(f"{pname} {_fmt(snap['gauges'][name])}")
    for name in sorted(snap.get("histograms", {})):
        h = snap["histograms"][name]
        pname = _emit(name, "histogram")
        cum = 0
        for cnt, le in zip(h["counts"],
                           list(h["buckets"]) + [float("inf")]):
            cum += int(cnt)
            lines.append(f'{pname}_bucket{{le="{_fmt(le)}"}} {cum}')
        lines.append(f"{pname}_sum {_fmt(h['sum'])}")
        lines.append(f"{pname}_count {int(h['count'])}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, dict]:
    """Inverse of :func:`render_prometheus` — rebuild the snapshot dict
    from exposition text.  Tolerates reordered families and unknown
    comments; histogram ``min``/``max`` come back as ``None`` (the
    format does not carry them)."""
    types: Dict[str, str] = {}
    orig_names: Dict[str, str] = {}
    samples: List[tuple] = []
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            elif len(parts) >= 4 and parts[1] == "repro-name":
                orig_names[parts[2]] = parts[3]
            continue
        if "{" in line:
            name, rest = line.split("{", 1)
            labels_s, value_s = rest.rsplit("}", 1)
            labels = {}
            for kv in labels_s.split(","):
                if kv:
                    k, v = kv.split("=", 1)
                    labels[k.strip()] = v.strip().strip('"')
            samples.append((name.strip(), labels, value_s.strip()))
        else:
            name, value_s = line.rsplit(None, 1)
            samples.append((name.strip(), {}, value_s))
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    hist_parts: Dict[str, dict] = {}
    for name, labels, value_s in samples:
        value = float("inf") if value_s == "+Inf" else float(value_s)
        base = None
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and \
                    types.get(name[: -len(suffix)]) == "histogram":
                base = name[: -len(suffix)]
                part = hist_parts.setdefault(
                    base, {"bounds": [], "cums": [], "sum": 0.0,
                           "count": 0})
                if suffix == "_bucket":
                    le = labels.get("le", "+Inf")
                    bound = float("inf") if le == "+Inf" else float(le)
                    part["bounds"].append(bound)
                    part["cums"].append(int(value))
                elif suffix == "_sum":
                    part["sum"] = value
                else:
                    part["count"] = int(value)
                break
        if base is not None:
            continue
        kind = types.get(name)
        key = orig_names.get(name, name)
        if kind == "gauge":
            out["gauges"][key] = value
        else:               # counter (or untyped: counters by default)
            out["counters"][key] = value
    for base, part in hist_parts.items():
        order = sorted(range(len(part["bounds"])),
                       key=lambda i: part["bounds"][i])
        bounds = [part["bounds"][i] for i in order]
        cums = [part["cums"][i] for i in order]
        counts, prev = [], 0
        for c in cums:
            counts.append(c - prev)
            prev = c
        finite = [b for b in bounds if not math.isinf(b)]
        key = orig_names.get(base, base)
        out["histograms"][key] = {
            "buckets": finite, "counts": counts, "sum": part["sum"],
            "count": part["count"], "min": None, "max": None}
    return out


def quantile_from_text(text: str, name: str,
                       q: float) -> Optional[float]:
    """Convenience: parse exposition text and estimate one histogram's
    ``q``-quantile (``None`` when the metric is absent or empty)."""
    from repro.obs.metrics import hist_quantile
    snap = parse_prometheus(text)
    h = snap["histograms"].get(name)
    return None if h is None else hist_quantile(h, q)
