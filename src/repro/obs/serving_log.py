"""Structured JSONL serving log: one record per served request.

This is the durable record the ROADMAP's off-policy-evaluation item
needs — which subsets were paid for, at what fee, under which regime,
and what the ensemble earned — written by BOTH ``FederationService``
accounting paths (the thread/sync `_account_batch` and the
process-backend `_results_from_ensembles` assembly), so every serving
configuration produces the same record stream.

Record schema (one JSON object per line)::

    {"img": int,            # trace image id
     "seg": int | null,     # scenario segment (regime) — null off-pool
     "clock": int | null,   # scenario clock at the request's flush
     "mask": int,           # selected subset bitmask
     "providers": [str],    # names of the selected providers
     "fees": {name: float}, # per-provider fee paid (mUSD), selected only
     "cost_milli_usd": float,   # summed fee (matches the result)
     "latency_ms": float,   # modeled request latency (paper Sec. II-B)
     "ap50": float | null,  # ensemble AP vs ground truth when available
     "flush_reason": str | null,    # why the flush fired (async plane)
     "backend": str | null, # "thread" | "process" | "sync"
     "ts": float}           # wall-clock seconds (record time)

Doubly-robust / IPS estimators consume exactly these fields: the logged
action is ``mask``, the logged cost is the fee sum, the logged outcome
is ``ap50``, and ``seg`` keys the regime the propensities must condition
on.  ``docs/observability.md`` documents the contract.

The log is an **asynchronous writer**: :meth:`log_flush` only appends a
tuple of references to a queue (the inputs are immutable — result
objects, int masks, fee vectors that are never mutated in place) and a
dedicated daemon thread does all JSON formatting, AP scoring fallback
and file I/O.  The serving threads' critical path pays a list build and
one lock/notify per flush; the ``obs_overhead`` benchmark gates that
this stays within noise of logging off.  Consequences:

* ``tail()`` / ``n_records`` are eventually consistent — call
  :meth:`flush` (a write barrier) before reading them in tests.
* :meth:`close` drains the queue, so a closed log file is complete.
* The log never touches any rng, cache, or accounting state: serving
  results are bit-identical with logging on or off.

AP is computed once per (segment, image, mask) and memoized; the
accounting paths additionally pass ``aps`` read off the evaluation
core's memo/lattice (a dict or table hit), so the fallback matching
only runs for the process backend's parent-side records.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence


class ServingLog:
    """Queue-fed JSONL writer + in-memory tail.

    Parameters
    ----------
    path:           output file (append; opened lazily).  ``None`` keeps
                    records only in memory (``retain`` must then be > 0
                    to be useful).
    provider_names: roster names, indexed by provider bit position.
    gts:            per-image ground-truth ``Detections`` (or ``None``
                    when serving without ground truth — ``ap50`` logs as
                    null).
    retain:         keep the last N records in memory for tests/reports
                    (0 keeps none).
    """

    def __init__(self, path: Optional[str] = None,
                 provider_names: Optional[Sequence[str]] = None,
                 gts: Optional[Sequence] = None, retain: int = 0):
        self.path = path
        self.provider_names = list(provider_names or [])
        self.gts = gts
        self.retain = int(retain)
        # _lock guards the sink (file handle, tail, n_records); _cv (its
        # own lock) guards the handoff queue and the enqueued/written
        # counters the flush barrier waits on
        self._lock = threading.Lock()
        self._cv = threading.Condition(threading.Lock())
        self._q: deque = deque()
        self._enqueued = 0
        self._written = 0
        self._writer: Optional[threading.Thread] = None
        self._closed = False
        self._f = None
        self._ap_memo: Dict = {}
        # (costs_fingerprint, mask, cost, latency) -> serialized
        # '"mask": ..., "providers": ..., "fees": ..., "cost_milli_usd":
        # ..., "latency_ms": ...' JSON fragment.  Fees follow from the
        # fee vector + mask, and the modeled cost/latency are pure
        # functions of the same (paper Sec. II-B) — keying on the actual
        # result values keeps the memo correct by construction while the
        # subset-dependent middle of a record is built once per subset,
        # not per request
        self._frag_memo: Dict = {}
        self._tail: List[dict] = []
        self.n_records = 0

    # -- internals --------------------------------------------------------
    def _selected(self, mask: int) -> List[int]:
        return [i for i in range(max(len(self.provider_names),
                                     mask.bit_length()))
                if (mask >> i) & 1]

    def _fragment(self, key, costs_vec) -> str:
        """Build + memoize the subset-dependent middle of a record for
        one (fee vector, subset, cost, latency) tuple."""
        _, mask, cost, latency = key
        names = self.provider_names
        sel = self._selected(mask)
        frag = (
            f'"mask": {mask}, "providers": '
            + json.dumps([names[i] if i < len(names) else f"p{i}"
                          for i in sel])
            + ', "fees": '
            + json.dumps({(names[i] if i < len(names) else f"p{i}"):
                          float(costs_vec[i]) for i in sel})
            + f', "cost_milli_usd": {cost!r}, "latency_ms": {latency!r}')
        self._frag_memo[key] = frag
        return frag

    def _ap(self, seg, img: int, mask: int, detections) -> Optional[float]:
        if self.gts is None:
            return None
        key = (seg, img, mask)
        ap = self._ap_memo.get(key)
        if ap is None:
            from repro.ensemble.metrics import image_ap50
            ap = float(image_ap50(detections, self.gts[img]))
            self._ap_memo[key] = ap
        return ap

    # -- the one write path ----------------------------------------------
    def log_flush(self, imgs: Sequence[int], masks: Sequence[int],
                  costs_vec, results, *, seg: Optional[int] = None,
                  clock: Optional[int] = None,
                  reason: Optional[str] = None,
                  backend: Optional[str] = None,
                  aps: Optional[Sequence[Optional[float]]] = None) -> None:
        """Enqueue one record per request of a flush.

        ``costs_vec`` is the per-provider fee vector the flush was
        accounted under (a scenario segment's vector, or the static
        roster's); ``results`` are the flush's ``FederationResult``s in
        the same order as ``imgs``/``masks``.  ``aps`` supplies
        already-scored AP50 values (the accounting paths read them off
        the evaluation core's memo/lattice, which is much cheaper than
        rescoring here); omitted, AP is computed against ``gts`` on the
        writer thread and memoized.

        Hot-path cost is the handoff only: append ONE tuple of
        references, notify.  Formatting and I/O happen on the writer
        thread — callers hand over flush-local sequences they do not
        mutate afterwards (the accounting paths build fresh arrays per
        flush).
        """
        item = (imgs, masks, costs_vec, results, seg, clock, reason,
                backend, aps, time.time())
        with self._cv:
            if self._closed:
                raise RuntimeError("log_flush on a closed ServingLog")
            self._q.append(item)
            self._enqueued += 1
            if self._writer is None:
                self._writer = threading.Thread(
                    target=self._write_loop, name="serving-log-writer",
                    daemon=True)
                self._writer.start()
            # deliberately NO notify: waking the writer per flush makes
            # it runnable mid-traffic and the resulting GIL hand-offs
            # cost the serving threads far more than the formatting
            # itself.  The writer self-paces on a short timed wait and
            # drains whatever accumulated; only close()/flush() need a
            # prompt wake and notify explicitly.

    def _format_flush(self, item) -> List[str]:
        (imgs, masks, costs_vec, results, seg, clock, reason, backend,
         aps, ts) = item
        # flush-constant JSON pieces (json.dumps keeps names/reasons
        # quoting-safe; every per-request field below is a number)
        seg_s = "null" if seg is None else str(int(seg))
        clock_s = "null" if clock is None else str(int(clock))
        reason_s = json.dumps(reason)
        backend_s = json.dumps(backend)
        tb = getattr(costs_vec, "tobytes", None)
        costs_key = tb() if tb is not None else tuple(costs_vec)
        tail_s = (f'"flush_reason": {reason_s}, "backend": {backend_s}, '
                  f'"ts": {ts!r}}}\n')
        frag_memo = self._frag_memo
        lines = []
        for t, (img, mask, res) in enumerate(zip(imgs, masks, results)):
            img, mask = int(img), int(mask)
            key = (costs_key, mask, float(res.cost_milli_usd),
                   float(res.latency_ms))
            frag = frag_memo.get(key)
            if frag is None:
                frag = self._fragment(key, costs_vec)
            ap = self._ap(seg, img, mask, res.detections) if aps is None \
                else (None if aps[t] is None else float(aps[t]))
            lines.append(
                f'{{"img": {img}, "seg": {seg_s}, "clock": {clock_s}, '
                f'{frag}, "ap50": {"null" if ap is None else repr(ap)}, '
                + tail_s)
        return lines

    def _write_loop(self) -> None:
        while True:
            with self._cv:
                while not self._q and not self._closed:
                    self._cv.wait(timeout=0.05)
                if not self._q and self._closed:
                    return          # closed and drained
                items = list(self._q)
                self._q.clear()
            lines: List[str] = []
            for item in items:
                lines.extend(self._format_flush(item))
            with self._lock:
                self.n_records += len(lines)
                if self.retain:
                    self._tail.extend(json.loads(ln) for ln in lines)
                    del self._tail[:-self.retain]
                if self.path is not None:
                    if self._f is None:
                        self._f = open(self.path, "a")
                    self._f.write("".join(lines))
            with self._cv:
                self._written += len(items)
                self._cv.notify_all()

    # -- reading / lifecycle ----------------------------------------------
    def tail(self) -> List[dict]:
        with self._lock:
            return list(self._tail)

    def flush(self) -> None:
        """Write barrier: block until every enqueued flush is formatted
        and handed to the OS, then flush the file buffer."""
        with self._cv:
            self._cv.notify_all()   # wake the writer out of its timed nap
            while self._written < self._enqueued:
                self._cv.wait(timeout=0.05)
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            writer = self._writer
        if writer is not None:
            writer.join(timeout=30.0)
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def read_serving_log(path: str) -> List[dict]:
    """Parse a serving-log JSONL file back into records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
