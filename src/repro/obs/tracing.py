"""Span-based request tracing for the async serving plane.

A sampled request carries a ``trace_id`` from ``submit`` to its future's
resolution; the stations along the way — the flush that batched it (with
its flush reason), the per-shard RPC, ensemble assembly, the in-worker
evaluation on the far side of the ``mp_shards`` pipe — each record one
span tied to that trace.  Spans are plain dicts:

    {"name": str, "trace": str, "span": str, "parent": str | None,
     "ts": float (epoch seconds), "dur_ms": float, "attrs": {...}}

The wire form of a trace context is ``(trace_id, parent_span_id)`` — a
picklable 2-tuple the process-shard protocol appends to its eval
messages; the worker answers with a finished span dict that the parent
records verbatim (worker spans carry their pid in ``attrs``).

Sampling is the cost knob: ``sample=0.0`` (the default) makes
``sample_request`` a constant ``None`` and ``span(...)`` return a shared
no-op context manager — tracing off is a handful of predictable branch
checks on the hot path, nothing else.  The sampler uses its own
``random.Random(seed)``: it never touches numpy global state or any
env/agent rng, which is what keeps traced and untraced runs
bit-identical.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

WireContext = Tuple[str, str]       # (trace_id, parent_span_id)


class _NullSpan:
    """Shared no-op span: context manager with inert ids."""

    __slots__ = ()
    trace_id: Optional[str] = None
    span_id: Optional[str] = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("_tracer", "name", "trace_id", "span_id", "parent_id",
                 "attrs", "_t0", "_ts")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 parent_id: Optional[str], attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = tracer._next_span_id()
        self.parent_id = parent_id
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self._ts = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self._tracer.record({
            "name": self.name, "trace": self.trace_id,
            "span": self.span_id, "parent": self.parent_id,
            "ts": self._ts,
            "dur_ms": (time.perf_counter() - self._t0) * 1e3,
            "attrs": self.attrs})
        return None


class Tracer:
    """Sampling span recorder.

    Parameters
    ----------
    sample:    fraction of requests that get a trace (0 disables).
    writer:    optional callback invoked with each finished span dict
               (the ``Obs`` umbrella wires a JSONL appender here).
    max_spans: in-memory ring capacity for :meth:`drain`/reporting.
    seed:      sampler seed — deterministic, isolated from user rngs.
    """

    def __init__(self, sample: float = 0.0,
                 writer: Optional[Callable[[dict], None]] = None,
                 max_spans: int = 20_000, seed: int = 0):
        self.sample = float(sample)
        self.enabled = self.sample > 0.0
        self._writer = writer
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=int(max_spans))
        self._n_traces = 0
        self._n_spans = 0

    # -- trace/span identity ---------------------------------------------
    def sample_request(self) -> Optional[str]:
        """A fresh trace id for a sampled request, else ``None``."""
        if not self.enabled:
            return None
        with self._lock:
            if self.sample < 1.0 and self._rng.random() >= self.sample:
                return None
            self._n_traces += 1
            return f"t{self._n_traces:08x}"

    def _next_span_id(self) -> str:
        with self._lock:
            self._n_spans += 1
            return f"s{self._n_spans:08x}"

    # -- span creation / recording ---------------------------------------
    def span(self, name: str, trace_id: Optional[str],
             parent: Optional[str] = None, **attrs):
        """Context manager recording one span on exit; a ``None``
        ``trace_id`` (unsampled request, tracing off) returns the shared
        no-op span."""
        if trace_id is None or not self.enabled:
            return NULL_SPAN
        return Span(self, name, trace_id, parent, attrs)

    def wire_context(self, span) -> Optional[WireContext]:
        """The picklable context an RPC message carries: the worker's
        span will hang off ``span`` in the assembled trace."""
        if span is None or span.trace_id is None:
            return None
        return (span.trace_id, span.span_id)

    def record(self, rec: dict) -> None:
        """Store a finished span (local exit or worker-shipped)."""
        with self._lock:
            self._spans.append(rec)
        if self._writer is not None:
            self._writer(rec)

    # -- reporting --------------------------------------------------------
    def drain(self) -> List[dict]:
        with self._lock:
            out = list(self._spans)
            self._spans.clear()
        return out

    def spans(self) -> List[dict]:
        with self._lock:
            return list(self._spans)
