"""AdamW with fp32 moments over (possibly bf16) param pytrees.

No optax offline — this is a faithful, minimal implementation: decoupled
weight decay, bias-corrected moments, global-norm clipping.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gnorm


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m / c1) / (jnp.sqrt(v / c2) + eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (update + weight_decay * p32)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
