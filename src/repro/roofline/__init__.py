from repro.roofline.analysis import (HW, collective_bytes, roofline_terms,  # noqa: F401
                                     model_flops)
from repro.roofline.measure import (achieved_point, hlo_cost, measure,  # noqa: F401
                                    timed_best)
