"""Three-term roofline model from the compiled dry-run artifact.

  compute term    = HLO_FLOPs  / (chips * peak_FLOPs)
  memory term     = HLO_bytes  / (chips * HBM_bw)
  collective term = coll_bytes / (chips * link_bw)

``cost_analysis()`` on an SPMD-partitioned executable reports *per-device*
flops/bytes, so the terms below divide by chips only when given whole-system
numbers (we pass per-device numbers straight through with chips=1).

collective_bytes is parsed from the post-SPMD HLO text: we sum the result
shapes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops with an op-specific traffic multiplier (ring
all-reduce moves ~2x its buffer; the others ~1x).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class HW:
    """TPU v5e-class chip."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    link_bw: float = 50e9             # B/s per ICI link (assumption: one
    #                                   link's worth of bisection per chip)


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TRAFFIC_MULT = {
    "all-reduce": 2.0,        # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """Returns [(op_kind, traffic_bytes_per_device), ...]."""
    out = []
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        name, dtype, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        # avoid double counting async start/done pairs
        if ".done" in name or name in seen_done:
            continue
        seen_done.add(name)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((kind, int(n * _DTYPE_BYTES[dtype]
                               * _TRAFFIC_MULT[kind])))
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    per_kind: Dict[str, float] = {}
    for kind, b in parse_collectives(hlo_text):
        per_kind[kind] = per_kind.get(kind, 0) + b
    per_kind["total"] = sum(per_kind.values())
    return per_kind


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HW = HW()) -> Dict[str, float]:
    t_c = flops_per_dev / hw.peak_flops
    t_m = bytes_per_dev / hw.hbm_bw
    t_x = coll_bytes_per_dev / hw.link_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom}


def model_flops(cfg: ArchConfig, tokens: int, *, train: bool) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); 2*N*D for inference."""
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    return mult * n * tokens
