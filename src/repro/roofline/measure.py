"""Measured roofline points for jitted programs (achieved vs peak).

``analysis.py`` models a roofline from configs; this module measures one:
the compiled executable's own cost model supplies FLOPs and bytes
(``jitfn.lower(...).compile().cost_analysis()``), wall time comes from a
best-of-K timed run with ``block_until_ready`` fencing, and the two
combine into achieved FLOP/s / bandwidth and fractions of the ``HW``
peaks.  Arithmetic intensity (FLOPs per HBM byte) places the program on
the roofline's x-axis: intensity below ``peak_flops / hbm_bw`` means the
memory roof binds, above it the compute roof.

Absolute achieved numbers are machine-dependent (this container runs the
CPU backend against a TPU-class HW model, so fractions of peak are tiny
and meaningless as gates); the benchmark suite therefore gates only
same-run speedup ratios and HLO-derived quantities, which are invariant
across hosts.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax

from repro.roofline.analysis import HW


def _first(d, *names, default=0.0):
    for n in names:
        if n in d:
            return float(d[n])
    return default


def hlo_cost(jitfn, *args, **kwargs) -> Dict[str, float]:
    """FLOPs / bytes / arithmetic intensity of one compiled call.

    Uses the executable's cost analysis (per-device numbers).  Older jax
    versions return a list of per-computation dicts — take the entry for
    the main computation.  Missing keys read as 0.0 (the CPU backend
    reports flops but sometimes omits ``bytes accessed``).
    """
    ca = jitfn.lower(*args, **kwargs).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = _first(ca, "flops")
    byts = _first(ca, "bytes accessed", "bytes_accessed")
    return {"flops": flops, "bytes": byts,
            "intensity": flops / byts if byts else 0.0}


def timed_best(fn: Callable, *args, repeats: int = 5,
               **kwargs) -> Tuple[float, object]:
    """Best-of-``repeats`` wall seconds for one fenced call (compile /
    warmup excluded: one untimed call runs first)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def achieved_point(cost: Dict[str, float], seconds: float,
                   hw: HW = HW()) -> Dict[str, float]:
    """One measured roofline point: achieved rates + fractions of the
    ``HW`` peaks + which roof the HLO intensity says should bind."""
    flops, byts = cost["flops"], cost["bytes"]
    knee = hw.peak_flops / hw.hbm_bw          # intensity where roofs cross
    bound = "compute" if cost["intensity"] >= knee else "memory"
    return {
        "flops": flops, "bytes": byts, "intensity": cost["intensity"],
        "seconds": seconds,
        "achieved_flops_s": flops / seconds if seconds else 0.0,
        "achieved_bw_s": byts / seconds if seconds else 0.0,
        "frac_peak_flops": (flops / seconds) / hw.peak_flops
        if seconds else 0.0,
        "frac_peak_bw": (byts / seconds) / hw.hbm_bw if seconds else 0.0,
        "knee_intensity": knee, "bound": bound,
    }


def measure(jitfn, *args, repeats: int = 5, hw: HW = HW(),
            **kwargs) -> Dict[str, float]:
    """Compile-cost + timed run + roofline placement in one call."""
    cost = hlo_cost(jitfn, *args, **kwargs)
    seconds, _ = timed_best(jitfn, *args, repeats=repeats, **kwargs)
    return achieved_point(cost, seconds, hw=hw)
