from repro.scenarios.schedule import (ProviderEvent,  # noqa: F401
                                      ScenarioSchedule, BUILTIN_SCENARIOS,
                                      build_scenario, random_scenario)
from repro.scenarios.pool import DynamicProviderPool, PoolView  # noqa: F401
from repro.scenarios.env import NonStationaryArmolEnv  # noqa: F401
from repro.scenarios.online import (evaluate_segment,  # noqa: F401
                                    run_online)
