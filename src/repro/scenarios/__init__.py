from repro.scenarios.schedule import (ProviderEvent,  # noqa: F401
                                      ScenarioSchedule, BUILTIN_SCENARIOS,
                                      build_scenario, random_scenario)
from repro.scenarios.pool import (DynamicProviderPool,  # noqa: F401
                                  PoolSnapshot, PoolView,
                                  build_segment_traces)
from repro.scenarios.env import NonStationaryArmolEnv  # noqa: F401
from repro.scenarios.online import (evaluate_segment,  # noqa: F401
                                    run_online)
