"""Non-stationary ArmolEnv: the trace env under a scenario schedule.

The env owns a scenario clock: every transition consumes one schedule
step (``step_lanes`` consumes one per lane), and evaluation routes to the
pool's segment core/fees for the clock's current segment.  Everything
else — features, train/test split, the lane machinery, the batched
evaluation path — is inherited from :class:`ArmolEnv`, so the multi-lane
training drivers run unchanged on a moving world.

Reward stays Eq.-5 shaped (``ap50 + beta * cost``, ``-1`` on an empty
ensemble) but ``cost`` comes from the segment's fee vector: a down
provider bills nothing and contributes nothing; a re-priced provider
bills its current fee.

``observe_pool=True`` appends the pool status (per-provider activity +
normalized fees) to the state, mirroring a real deployment where provider
status pages and price sheets are observable; the selector can then
condition on the regime instead of inferring it from reward alone.
Status columns are rewritten in place at segment switches, so inherited
code that indexes ``self.features`` always sees the current regime.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.federation.env import ArmolEnv
from repro.scenarios.pool import DynamicProviderPool


class NonStationaryArmolEnv(ArmolEnv):
    def __init__(self, pool: DynamicProviderPool, *, mode: str = "gt",
                 beta: float = 0.0, observe_pool: bool = True,
                 train_frac: float = 0.7, seed: int = 0,
                 feat_dim: int = 64):
        self.pool = pool
        super().__init__(pool.base_traces, mode=mode, beta=beta,
                         voting=pool.voting, ablation=pool.ablation,
                         train_frac=train_frac, seed=seed,
                         feat_dim=feat_dim, use_kernel=pool.use_kernel,
                         core=pool.core_at(0))
        self._clock = 0
        self.horizon = pool.schedule.horizon
        self.observe_pool = observe_pool
        self._base_dim = self.state_dim
        self._cost_scale = max(float(np.max(
            [p.cost_milli_usd for p in pool.roster])), 1e-6)
        if observe_pool:
            n = self.n_providers
            status = np.zeros((len(self.features), 2 * n), np.float32)
            self.features = np.concatenate([self.features, status], axis=1)
            self.state_dim += 2 * n
            self._write_status(self.pool.view_at(0))

    # -- scenario clock --------------------------------------------------
    @property
    def clock(self) -> int:
        return self._clock

    @property
    def segment_index(self) -> int:
        return self.pool.schedule.segment_index(self._clock)

    def set_clock(self, step: int) -> None:
        before = self.segment_index
        self._clock = int(step)
        if self.observe_pool and self.segment_index != before:
            self._write_status(self.pool.view_at(self._clock))

    def _tick(self, n: int) -> bool:
        before = self.segment_index
        self._clock += int(n)
        switched = self.segment_index != before
        if switched and self.observe_pool:
            self._write_status(self.pool.view_at(self._clock))
        return switched

    # -- status features -------------------------------------------------
    def _status_vec(self, view) -> np.ndarray:
        return np.concatenate([
            view.active.astype(np.float32),
            np.asarray(view.costs, np.float32) / self._cost_scale])

    def _write_status(self, view) -> None:
        self.features[:, self._base_dim:] = self._status_vec(view)[None]
        self._features_dev = None   # the device mirror is now stale

    def features_at(self, step: int,
                    img_indices: Sequence[int]) -> np.ndarray:
        """State matrix for the given images AS OF an arbitrary step —
        post-hoc segment evaluation without touching the live clock."""
        idx = np.asarray(img_indices, np.int64)
        if not self.observe_pool:
            return self.features[idx]
        base = self.features[idx, :self._base_dim]
        status = self._status_vec(self.pool.view_at(step))
        return np.concatenate(
            [base, np.broadcast_to(status, (len(idx), len(status)))],
            axis=1)

    # -- segment-routed evaluation ---------------------------------------
    def evaluate_actions_at(self, img_indices: Sequence[int],
                            actions: np.ndarray,
                            step: int) -> Dict[str, np.ndarray]:
        """Batched evaluation under the segment active at ``step``: AP50
        from the segment core's memo, fees from the segment view, reward
        recomposed as ap50 + beta * fee (Eq.-5's -1 on empty kept)."""
        view = self.pool.view_at(step)
        core = self.pool.core_at(step)
        out = core.evaluate_batch(img_indices, actions, beta=0.0,
                                  against=self._against)
        cost = view.mask_costs(out["mask"])
        empty = out["reward"] == -1.0
        out["cost"] = cost
        out["reward"] = np.where(empty, -1.0,
                                 out["ap50"] + self.beta * cost)
        return out

    def evaluate_masks_at(self, img_indices: Sequence[int],
                          masks: Sequence[int],
                          step: int) -> Dict[str, np.ndarray]:
        """Batched evaluation of explicit subset bitmasks under the
        segment at ``step`` — the counterfactual-replay path: all
        sub-subsets of a paid set are rows of ONE cached per-image
        lattice slice, instead of per-(image, mask) memo round-trips.
        Output contract matches ``evaluate_actions_at`` bit for bit.
        """
        view = self.pool.view_at(step)
        core = self.pool.core_at(step)
        imgs = [int(i) for i in img_indices]
        m = np.asarray(masks, np.int64).reshape(-1)
        ap = np.zeros(len(imgs), np.float64)
        empty = np.zeros(len(imgs), bool)
        core.precompute(imgs)
        for t, (img, mk) in enumerate(zip(imgs, m)):
            if mk == 0:
                empty[t] = True
                continue
            lat = core.evaluate_lattice(img, against=self._against)
            row = lat.index_of(int(mk))
            ap[t] = lat.ap[row]
            empty[t] = lat.n_dets[row] == 0
        cost = view.mask_costs(m)
        return {"reward": np.where(empty, -1.0, ap + self.beta * cost),
                "ap50": np.where(empty, 0.0, ap), "cost": cost, "mask": m}

    def evaluate_actions(self, img_indices: Sequence[int],
                         actions: np.ndarray) -> Dict[str, np.ndarray]:
        return self.evaluate_actions_at(img_indices, actions, self._clock)

    def evaluate_action(self, img_idx: int, action: np.ndarray):
        out = self.evaluate_actions([img_idx], np.asarray(action)[None])
        return (float(out["reward"][0]), float(out["ap50"][0]),
                float(out["cost"][0]))

    def ensemble_for(self, img_idx: int, action: np.ndarray):
        core = self.pool.core_at(self._clock)
        return core.ensemble(img_idx, core.mask_of(action))

    def pseudo_gt(self, img_idx: int):
        return self.pool.core_at(self._clock).pseudo_gt(img_idx)

    # -- clock-advancing transitions -------------------------------------
    def step(self, action: np.ndarray):
        nxt, reward, done, info = super().step(action)
        info["segment"] = self.segment_index
        info["switched"] = self._tick(1)
        if info["switched"] and self.observe_pool:
            # the next state must carry the regime it will be acted in,
            # not the one it was computed under
            nxt = self.features[self._order[
                min(self._t, len(self._order) - 1)]]
        return nxt, reward, done, info

    def step_lanes(self, actions: np.ndarray):
        nxt, rewards, dones, infos, carry = super().step_lanes(actions)
        infos["segment"] = self.segment_index
        infos["switched"] = self._tick(len(self._lane_orders))
        if infos["switched"] and self.observe_pool:
            carry = self.lane_states()      # re-gather with fresh status
        return nxt, rewards, dones, infos, carry

    def step_batch(self, actions: np.ndarray):
        nxt, rewards, dones, infos = super().step_batch(actions)
        infos["segment"] = self.segment_index
        infos["switched"] = self._tick(len(rewards))
        return nxt, rewards, dones, infos

    # -- demand-aware episode orders -------------------------------------
    def _episode_order(self, idx: np.ndarray, shuffle: bool) -> np.ndarray:
        w = self.pool.demand_weights_at(self._clock, idx)
        if w is None or not shuffle:
            return super()._episode_order(idx, shuffle)
        # demand shift: sample the request stream WITH replacement from
        # the focus-weighted distribution (a traffic mix, not an epoch)
        return self.rng.choice(idx, size=len(idx), replace=True, p=w)
