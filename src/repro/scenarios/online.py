"""Online adaptation on a non-stationary provider pool.

``run_online`` streams an off-policy agent (SAC/TD3 — anything with the
``update`` / ``update_block`` surface) through a scenario: L parallel env
lanes collect through the batched ``step_lanes`` path, gradient steps run
as fused ``lax.scan`` blocks, and training simply CONTINUES across regime
switches.  At each switch the driver

  * closes the finished segment with a held-out evaluation *under that
    segment's pool* (post-adaptation metrics: the agent had the whole
    segment to adapt),
  * optionally boosts exploration for a while (``explore_steps``) and —
    when the state does not observe the pool — drops the now-stale replay
    buffer, since old-regime transitions label the same states with the
    wrong rewards.

Per-segment report: mean per-request agent reward (ap50 + beta * fee, -1
on empty) on the demand-weighted test split, the same quantity for the
per-image segment ORACLE (best active subset per image, Algo.-2
tie-breaking), their ratio (``recovery``), the additive gap (``regret``),
corpus AP50/cost, and the subset-evaluation cache hit rate the stream saw
inside the segment — the warm-path health of the pool's segment-keyed
caches.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.loops import _make_batch_select
from repro.core.replay_buffer import ReplayBuffer
from repro.scenarios.env import NonStationaryArmolEnv


def _swap_state(agent, state):
    """Temporarily install a parameter snapshot (both SAC and TD3 keep
    their whole learnable + rng state in ``agent.state``)."""
    live = agent.state
    agent.state = state
    return live


def _snapshot(state):
    """Host copy of an agent state.  The fused update blocks DONATE their
    input buffers, so a bare reference to ``agent.state`` is invalidated
    by the next gradient block; snapshots must own their memory."""
    import jax
    return jax.tree.map(lambda x: np.array(x), state)


def evaluate_segment(agent, env: NonStationaryArmolEnv, step: int, *,
                     deterministic: bool = True) -> Dict:
    """Held-out metrics under the segment active at ``step``.

    The test split is weighted by the segment's demand distribution (a
    flash crowd is judged on flash-crowd traffic); the oracle is the
    per-image best active subset by ap50 + beta * fee.
    """
    imgs = env.test_idx
    w = env.pool.demand_weights_at(step, imgs)
    wts = (np.full(len(imgs), 1.0 / max(len(imgs), 1))
           if w is None else w)
    select = _make_batch_select(agent, deterministic=deterministic)
    actions = select(env.features_at(step, imgs))
    out = env.evaluate_actions_at(imgs, actions, step)
    agent_r = float(np.sum(wts * out["reward"]))
    oracle_r = float(np.sum(wts * np.asarray(
        [env.pool.oracle(int(i), step, env.beta,
                         against=env._against)[1] for i in imgs])))
    if oracle_r > 1e-9:
        recovery = agent_r / oracle_r
    else:       # degenerate segment (oracle can't score) — compare gaps
        recovery = 1.0 if agent_r >= oracle_r else 0.0
    return {"seg": env.pool.schedule.segment_index(step), "step": int(step),
            "reward": round(agent_r, 4), "oracle_reward": round(oracle_r, 4),
            "recovery": round(recovery, 4),
            "regret": round(oracle_r - agent_r, 4),
            "ap50": round(100.0 * float(np.sum(wts * out["ap50"])), 2),
            "cost": round(float(np.sum(wts * out["cost"])), 3),
            "n_images": int(len(imgs))}


def _hit_rate(delta: Dict[str, int]) -> float:
    hits = delta.get("ens_hits", 0) + delta.get("ap_hits", 0)
    total = hits + delta.get("ens_misses", 0) + delta.get("ap_misses", 0)
    return hits / total if total else 1.0


def run_online(agent, env: NonStationaryArmolEnv, *, lanes: int = 4,
               batch_size: int = 64, start_steps: int = 200,
               update_every: int = 10, update_iters: int = 20,
               buffer_capacity: int = 50_000, explore_steps: int = 150,
               val_every: int = 50, val_images: int = 24,
               counterfactual_k: int = 3, switch_burst: int = 10,
               seed: int = 0, regime_memory: bool = True,
               collect_snapshots: bool = False,
               log: Optional[Callable[[str], None]] = print,
               obs=None) -> Dict:
    """Stream the whole scenario horizon once, adapting online.

    Four deployment-shaped mechanisms beyond plain continual training:

      * **counterfactual sub-subsets** — paying for providers S reveals
        every response in S, so the reward of ANY non-empty S' ⊆ S is
        exactly computable from the already-memoized evaluation core (a
        combinatorial semi-bandit's counterfactual feedback — nothing is
        peeked from unselected providers).  Each real transition spawns
        ``counterfactual_k`` random strict sub-subset transitions, so a
        300-step segment yields ~4x the labeled actions and the agent
        re-learns a regime from far fewer paid requests.  During the
        exploration window half the exploratory actions select ALL
        providers, whose counterfactuals cover the whole subset lattice;

      * **fee relabeling** — when a switch changes only economics (same
        ``dets_key``: re-pricing, latency, demand), the stored rewards are
        exactly recomputable (``reward - beta*old_fee + beta*new_fee``
        per stored action) and the observed status columns are rewritten,
        so the whole buffer becomes valid new-regime experience instantly;
      * **regime-keyed replay memory** — when detections DO change, the
        buffer is stashed under the old regime's ``dets_key`` and the new
        regime resumes its own stashed buffer (relabeled to current fees)
        or an empty one; a recovered provider re-activates the experience
        learned before its outage instead of relearning from scratch.
        ``regime_memory=False`` degrades to flush-on-switch;
      * **validated policy snapshots** — every ``val_every`` steps the
        deterministic policy is scored on a small train-split validation
        set under the CURRENT segment; each segment serves (and is
        evaluated with) its best-scoring snapshot, and snapshots are
        stashed per economic regime so a revisited regime starts from its
        best known policy.  The shadow-deployment pattern: training may
        oscillate, serving only promotes validated improvements.

    Returns ``{"segments": [...], "summary": {...}}``; ``summary`` keys
    include ``min_recovery_post_switch`` / ``mean_recovery_post_switch``
    (segments 1.. — the acceptance metric for regime-switch recovery) and
    aggregate cache hit rates.  With ``collect_snapshots=True`` the result
    also carries ``"snapshots"``: one host-copied agent state per segment
    record — the exact (validated-best) policy each segment was evaluated
    with — so callers can replay per-segment policies post hoc (the
    frontier benchmark scores its hybrid arm this way).

    Failure modes: raises ``ValueError`` on ``lanes < 1``; a horizon of 0
    returns after evaluating segment 0 untouched.  The agent is left with
    its LIVE (post-training) state — per-segment bests live only in the
    returned snapshots.
    """
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    # observability (repro.obs.Obs): structured events for every segment
    # close / regime switch (machine-readable twins of the ``log`` lines)
    # plus tick-latency / replay-occupancy / update-count metrics.  Obs
    # only reads clocks and copies already-computed values — results are
    # bit-identical with it on or off (tests/test_obs_parity.py).
    _obs_on = obs is not None and obs.enabled
    if _obs_on:
        _h_tick = obs.metrics.histogram("train.tick_ms")
        _g_occ = obs.metrics.gauge("train.replay_occupancy")
        _c_upd = obs.metrics.counter("train.update_iters")
    rng = np.random.default_rng(seed)
    buf = ReplayBuffer(buffer_capacity, env.state_dim, env.n_providers,
                       seed=seed)
    update_block = getattr(agent, "update_block", None)
    select_many = _make_batch_select(agent, deterministic=False)
    select_det = _make_batch_select(agent, deterministic=True)
    n = env.n_providers
    mask_w = np.left_shift(np.int64(1), np.arange(n, dtype=np.int64))
    t0 = time.time()
    states = env.reset_lanes(lanes, split="train")
    segments: List[Dict] = []
    snapshots: List = []
    total = 0
    explore_left = int(start_steps)
    seg = env.segment_index
    stats_mark = env.pool.agg_core_stats()
    val_idx = env.train_idx[rng.permutation(len(env.train_idx))
                            [:max(int(val_images), 1)]]
    best_state, best_val = None, -np.inf
    next_val = int(val_every)
    cur_view = env.pool.view_at(env.clock)
    buf_stash: Dict = {}        # dets_key -> (buffer, view it's labeled to)
    snap_stash: Dict = {}       # econ_key -> best agent state

    def _relabel(b: ReplayBuffer, old_view, new_view) -> None:
        """Rewrite a buffer's fee-dependent content from one economic
        regime to another (exact: fees are deterministic in the action)."""
        if b.size == 0:
            return
        if env.observe_pool:
            status = env._status_vec(new_view)
            b.state[:b.size, env._base_dim:] = status
            b.next_state[:b.size, env._base_dim:] = status
        if env.beta != 0.0:
            # one fee matvec over the whole bitmask matrix: fee deltas for
            # every stored action in a single pass, no per-bitmask
            # cost re-derivation
            bits = (b.action[:b.size] > 0.5).astype(np.float64)
            dc = env.beta * (bits @ (new_view.costs.astype(np.float64)
                                     - old_view.costs.astype(np.float64)))
            keep = b.reward[:b.size] != -1.0     # Eq.-5 empties stay -1
            b.reward[:b.size][keep] += dc[keep].astype(np.float32)

    def _score_state(state, step: Optional[int] = None) -> float:
        step = env.clock if step is None else step
        live = _swap_state(agent, state)
        acts = select_det(env.features_at(step, val_idx))
        agent.state = live
        out = env.evaluate_actions_at(val_idx, acts, step)
        return float(np.mean(out["reward"]))

    def _validate(step: Optional[int] = None) -> None:
        """Score the deterministic policy on the validation set under the
        segment at ``step`` (default: now); promote the snapshot if it
        improves."""
        nonlocal best_state, best_val
        score = _score_state(agent.state, step)
        if score > best_val:
            best_val, best_state = score, _snapshot(agent.state)

    def _close_segment(finished_seg: int) -> None:
        nonlocal stats_mark
        end = env.pool.schedule.segment_range(finished_seg)[1] - 1
        _validate(end)  # the segment's last policy gets a shot too
        live = None
        if best_state is not None:
            live = _swap_state(agent, best_state)
        rec = evaluate_segment(agent, env, end)
        if collect_snapshots:
            # the exact state rec was computed with (validated best, or
            # the live policy when no snapshot was ever promoted)
            snapshots.append(best_state if best_state is not None
                             else _snapshot(agent.state))
        if live is not None:
            agent.state = live
        now = env.pool.agg_core_stats()
        delta = {k: now.get(k, 0) - stats_mark.get(k, 0) for k in now}
        stats_mark = now
        rec["cache_hit_rate"] = round(_hit_rate(delta), 4)
        rec["steps_seen"] = total
        rec["val_reward"] = round(best_val, 4)
        segments.append(rec)
        if _obs_on:
            obs.event("segment_close", **rec)
        if log:
            log(f"[online] seg {finished_seg}: reward={rec['reward']:.3f} "
                f"oracle={rec['oracle_reward']:.3f} "
                f"recovery={rec['recovery']:.2%} AP50={rec['ap50']:.1f} "
                f"cost={rec['cost']:.2f} "
                f"cache_hit={rec['cache_hit_rate']:.2%}")

    while env.clock < env.horizon:
        _tick_t0 = time.monotonic() if _obs_on else 0.0
        acts = np.zeros((lanes, n), np.float32)
        explore = np.zeros(lanes, bool)
        if explore_left > 0:
            explore[:] = rng.random(lanes) < 0.5 if total >= start_steps \
                else True
        for lane in np.flatnonzero(explore):
            if rng.random() < 0.5:
                # full fan-out: its counterfactuals span the whole lattice
                acts[lane] = 1.0
                continue
            a = rng.integers(0, 2, n).astype(np.float32)
            if a.sum() == 0:
                a[rng.integers(n)] = 1.0
            acts[lane] = a
        on_policy = np.flatnonzero(~explore)
        if len(on_policy):
            acts[on_policy] = select_many(states[on_policy])
        step0 = env.clock        # the regime this tick's rewards come from
        nxt, r, dones, infos, carry = env.step_lanes(acts)
        buf.add_batch(states, acts, r, nxt, dones.astype(np.float32))
        if counterfactual_k > 0:
            cf_s, cf_a, cf_m, cf_img, cf_n, cf_d = [], [], [], [], [], []
            for lane in range(lanes):
                sel = np.flatnonzero(acts[lane] > 0.5)
                if len(sel) < 2:
                    continue        # no strict non-empty sub-subset
                for _ in range(int(counterfactual_k)):
                    keep = sel[rng.random(len(sel)) < 0.5]
                    if len(keep) == 0 or len(keep) == len(sel):
                        continue
                    a_cf = np.zeros(n, np.float32)
                    a_cf[keep] = 1.0
                    cf_s.append(states[lane])
                    cf_a.append(a_cf)
                    cf_m.append(int(mask_w[keep].sum()))
                    cf_img.append(int(infos["image"][lane]))
                    cf_n.append(nxt[lane])
                    cf_d.append(float(dones[lane]))
            if cf_a:
                # sub-subset rewards are lattice row-slices of the paid
                # set's image — one cached pass per image, no
                # per-(image, mask) evaluation round-trips
                out_cf = env.evaluate_masks_at(cf_img, cf_m, step0)
                buf.add_batch(np.stack(cf_s), np.stack(cf_a),
                              out_cf["reward"], np.stack(cf_n),
                              np.asarray(cf_d, np.float32))
        states = carry
        prev, total = total, total + lanes
        explore_left = max(0, explore_left - lanes)
        for _ in range(prev // update_every + 1,
                       total // update_every + 1):
            if buf.size < batch_size:
                continue
            if update_block is not None:
                update_block(buf.sample_block(update_iters, batch_size))
            else:
                for _ in range(update_iters):
                    agent.update(buf.sample(batch_size))
            if _obs_on:
                _c_upd.inc(update_iters)
        if total >= next_val and total >= start_steps:
            # score at the PRE-tick clock: on a boundary-crossing tick the
            # promotion target is still the old segment's best_state, and
            # cross-regime validation scores are not comparable
            _validate(step0)
            next_val = total + int(val_every)
        if infos["switched"]:
            # close every segment the tick crossed (ticks can straddle
            # more than one boundary at extreme lane counts)
            old_seg = seg
            for s in range(seg, env.segment_index):
                _close_segment(s)
            seg = env.segment_index
            explore_left = max(explore_left, int(explore_steps))
            next_val = total + int(val_every)
            new_view = env.pool.view_at(env.clock)
            if best_state is not None:
                snap_stash[cur_view.econ_key] = best_state
            if not regime_memory:
                buf.size = buf.ptr = 0
                buf_action = "flush"
            elif new_view.dets_key == cur_view.dets_key:
                _relabel(buf, cur_view, new_view)   # economics-only switch
                buf_action = "fee_relabel"
            else:
                buf_stash[cur_view.dets_key] = (buf, cur_view)
                stashed = buf_stash.pop(new_view.dets_key, None)
                if stashed is None:
                    buf = ReplayBuffer(buffer_capacity, env.state_dim,
                                       env.n_providers, seed=seed + seg)
                    buf_action = "fresh"
                else:
                    buf, labeled_view = stashed
                    _relabel(buf, labeled_view, new_view)
                    buf_action = "stash_restore"
            if _obs_on:
                obs.event("regime_switch", from_seg=old_seg, to_seg=seg,
                          clock=int(env.clock),
                          econ_only=new_view.dets_key == cur_view.dets_key,
                          buffer=buf_action, buffer_size=int(buf.size))
            cur_view = new_view
            # replay burst: the buffer is exact data for the new regime
            # (relabeled fees / restored regime memory) — retrain on it
            # immediately instead of waiting for the update cadence
            if regime_memory and switch_burst > 0 and \
                    buf.size >= batch_size:
                burst = int(switch_burst) * update_iters
                if update_block is not None:
                    update_block(buf.sample_block(burst, batch_size))
                else:
                    for _ in range(burst):
                        agent.update(buf.sample(batch_size))
            best_state, best_val = None, -np.inf
            prior = snap_stash.get(new_view.econ_key)
            if prior is not None:   # best known policy for this regime
                best_val, best_state = _score_state(prior), prior
            _validate()             # give the post-burst policy a shot
        if _obs_on:
            _g_occ.set(buf.size)
            _h_tick.observe((time.monotonic() - _tick_t0) * 1e3)
    _close_segment(seg)

    post = [s["recovery"] for s in segments if s["seg"] >= 1]
    summary = {
        "scenario": env.pool.schedule.name,
        "horizon": env.horizon, "lanes": lanes, "steps": total,
        "n_segments": len(segments),
        "min_recovery_post_switch": round(min(post), 4) if post else None,
        "mean_recovery_post_switch":
            round(float(np.mean(post)), 4) if post else None,
        "mean_cache_hit_rate": round(float(np.mean(
            [s["cache_hit_rate"] for s in segments])), 4),
        "wall_s": round(time.time() - t0, 1),
        "pool": env.pool.cache_report(),
    }
    if _obs_on:
        obs.event("scenario_summary",
                  **{k: v for k, v in summary.items() if k != "pool"})
    if log:
        log(f"[online] {summary['scenario']}: "
            f"min post-switch recovery="
            f"{summary['min_recovery_post_switch']} "
            f"({total} steps, {summary['wall_s']}s)")
    out = {"segments": segments, "summary": summary}
    if collect_snapshots:
        out["snapshots"] = snapshots
    return out
