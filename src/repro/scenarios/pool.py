"""DynamicProviderPool: a ScenarioSchedule applied to real trace state.

The pool owns ONE base :class:`TraceSet` over the full roster (base
providers + every scheduled arrival, so the action space never changes)
and derives, per schedule segment:

  * the effective :class:`ProviderProfile` snapshots (via the immutable
    ``replace()`` path — never in-place mutation),
  * per-provider activity, fee and latency vectors (a down provider
    yields empty detections, bills nothing, and costs a timeout if
    selected),
  * a per-segment :class:`TraceSet` whose detection streams are REUSED
    from the base traces for providers whose detection-relevant
    fingerprint is unchanged, regenerated deterministically (seeded per
    (provider, image, fingerprint) against the stored difficulty latents)
    for drifted providers, and emptied for inactive ones,
  * a memoized :class:`SubsetEvaluationCore` per distinct detection
    fingerprint (``dets_key``).  Price/latency/demand changes share the
    SAME core — and a regime that reverts to an earlier fingerprint
    re-hits that fingerprint's warm cache, so steady-state evaluation
    speed survives regime switches.

Costs are deliberately kept OUT of the cores: segment fee vectors live on
the :class:`PoolView`, and reward composition (AP50 + beta * cost) happens
in the non-stationary env / oracle against the view.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.evaluation import (ShardedSubsetEvaluationCore,
                                         SubsetEvaluationCore)
from repro.federation.providers import ProviderProfile
from repro.federation.traces import (RawDetections, TraceSet,
                                     generate_traces, provider_detections)
from repro.federation.vocab import WordGrouper
from repro.scenarios.schedule import ScenarioSchedule


def _fp_crc(fp: Tuple) -> int:
    """Stable 32-bit hash of a profile fingerprint (hash() is salted per
    process, which would break cross-run regeneration determinism)."""
    return zlib.crc32(repr(fp).encode())


def build_segment_traces(base: TraceSet,
                         profiles: Sequence[ProviderProfile],
                         dets_key: Tuple, seed: int,
                         grouper: WordGrouper, *,
                         base_det_fp: Optional[Sequence[Tuple]] = None,
                         stats: Optional[Dict[str, int]] = None) -> TraceSet:
    """Segment TraceSet: shared images/GT/difficulties, per-provider
    detection streams reused, regenerated, or emptied.

    Module-level (not a pool method) on purpose: the multi-process serving
    shards rebuild segment traces INSIDE worker processes from a shipped
    :class:`PoolSnapshot`, and regeneration must be bit-identical to the
    parent pool's — one function, one rng recipe
    (``(seed, provider, image, crc(fingerprint))``), both callers.
    """
    if base_det_fp is None:
        base_det_fp = [p.fingerprint(detection_only=True)
                       for p in base.providers]
    T = len(base)
    empty_raw = RawDetections(np.zeros((0, 4), np.float32),
                              np.zeros((0,), np.float32), [])
    raw_all: List[List[RawDetections]] = [[] for _ in range(T)]
    det_all: List[List[Detections]] = [[] for _ in range(T)]
    if stats is not None:
        stats["segments_built"] += 1
    for j, p in enumerate(profiles):
        key = dets_key[j]
        if key == ("off",):
            for t in range(T):
                raw_all[t].append(empty_raw)
                det_all[t].append(Detections.empty())
        elif key[1] == base_det_fp[j]:
            for t in range(T):
                raw_all[t].append(base.raw[t][j])
                det_all[t].append(base.dets[t][j])
        else:
            if stats is not None:
                stats["providers_regenerated"] += 1
            crc = _fp_crc(key[1])
            for t in range(T):
                rng = np.random.default_rng((seed, j, t, crc))
                rawd, det = provider_detections(
                    p, base.gts[t].boxes, base.gts[t].labels,
                    base.difficulties[t], base.categories, rng,
                    grouper)
                raw_all[t].append(rawd)
                det_all[t].append(det)
    return TraceSet(base.images, base.gts, raw_all, det_all,
                    list(profiles), base.categories,
                    difficulties=base.difficulties)


@dataclass(frozen=True)
class PoolSnapshot:
    """Picklable recipe for one segment's evaluation state.

    Everything a shared-nothing worker process (which already holds the
    pool's BASE traces) needs to materialize the segment: the effective
    profiles, the detection-content key that decides reuse/regenerate/
    empty per provider, and the pool seed for deterministic regeneration.
    Fees/latencies stay out — accounting happens in the parent against
    the :class:`PoolView`, workers only ensemble detections.
    """
    seg: int
    dets_key: Tuple
    profiles: Tuple[ProviderProfile, ...]
    seed: int


@dataclass(frozen=True)
class PoolView:
    """One segment's effective pool state (everything but detections)."""
    seg: int
    profiles: Tuple[ProviderProfile, ...]
    active: np.ndarray          # (N,) bool
    costs: np.ndarray           # (N,) float32 — 0 for inactive providers
    latencies: np.ndarray       # (N,) float64 — timeout for inactive
    dets_key: Tuple             # detection-content identity of the segment
    econ_key: Tuple             # dets_key + fees + latencies + demand
    demand: Optional[Tuple[Tuple[str, ...], float]]

    @property
    def n_providers(self) -> int:
        return len(self.profiles)

    @property
    def active_mask(self) -> int:
        return int(sum(1 << i for i in np.flatnonzero(self.active)))

    def mask_costs(self, masks: np.ndarray) -> np.ndarray:
        """(B,) summed fees for an array of subset bitmasks."""
        m = np.asarray(masks, np.int64).reshape(-1)
        bits = (m[:, None] >> np.arange(self.n_providers)) & 1
        return (bits * self.costs).sum(axis=1)


class DynamicProviderPool:
    """Applies a :class:`ScenarioSchedule` to a provider roster.

    The pool is the single source of segment-dependent truth (see
    ``docs/architecture.md``): for any schedule step it answers

      * ``view_at(step)``    — fees, latencies, activity flags, demand
        weights and cache keys (``PoolView``; a down provider bills 0);
      * ``traces_at(step)``  — the segment's detection traces (providers
        regenerate when a switch changes their detection behavior);
      * ``core_at(step)`` / ``sharded_core_at(step, W)`` /
        ``snapshot_at(step)`` — the segment's memoized subset-evaluation
        core, its W-shard serving twin, and the picklable recipe worker
        processes rebuild it from;
      * ``oracle(img, step, beta)`` — the per-image best active subset
        (exact, via the full lattice pass);
      * ``demand_weights_at(step, imgs)`` — per-image evaluation weights
        under the segment's demand mix (``None`` = uniform).

    Segments are keyed by fingerprint, so a revisited regime (price back
    to normal, provider recovered) reuses its existing traces and warm
    caches instead of rebuilding — ``stats`` counts builds vs reuses.

    Thread-safe for the serving path: lazy segment construction (traces,
    cores, sharded cores) happens under one lock, lookups after that are
    plain dict reads.  Failure modes: duplicate provider names in the
    roster (base + scheduled arrivals) raise ``ValueError`` at
    construction; ``*_at`` lookups past the schedule horizon clamp to
    the final segment.
    """

    def __init__(self, base_providers: Sequence[ProviderProfile],
                 schedule: ScenarioSchedule, *, n_images: int = 120,
                 seed: int = 0, voting: str = "affirmative",
                 ablation: str = "wbf",
                 use_kernel: Union[bool, str] = "auto",
                 outage_timeout_ms: float = 2000.0,
                 mean_objects: float = 2.2):
        self.schedule = schedule
        self.seed = int(seed)
        self.voting = voting
        self.ablation = ablation
        self.use_kernel = use_kernel
        self.outage_timeout_ms = float(outage_timeout_ms)
        self.n_base = len(base_providers)
        self.roster: List[ProviderProfile] = \
            list(base_providers) + schedule.arrivals()
        names = [p.name for p in self.roster]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate provider names in roster: {names}")
        self.base_traces = generate_traces(self.roster, n_images, seed=seed,
                                           mean_objects=mean_objects)
        self._base_det_fp = [p.fingerprint(detection_only=True)
                             for p in self.roster]
        # per-image category-name sets, for demand reweighting
        cats = self.base_traces.categories
        self._img_cats = [frozenset(cats[int(l)] for l in gt.labels)
                          for gt in self.base_traces.gts]
        self._grouper = WordGrouper()
        self._lock = threading.Lock()
        self._views: Dict[int, PoolView] = {}
        self._traces: Dict[Tuple, TraceSet] = {}
        self._cores: Dict[Tuple, SubsetEvaluationCore] = {}
        self._sharded: Dict[Tuple, ShardedSubsetEvaluationCore] = {}
        self._snapshots: Dict[int, PoolSnapshot] = {}
        self._oracle: Dict[Tuple, Tuple[int, float]] = {}
        self._fees: Dict[Tuple, np.ndarray] = {}
        self.stats = {"segments_built": 0, "cores_built": 0,
                      "cores_reused": 0, "providers_regenerated": 0}

    @property
    def n_providers(self) -> int:
        return len(self.roster)

    def __len__(self) -> int:
        return len(self.base_traces)

    # -- segment views ---------------------------------------------------
    def view_at(self, step: int) -> PoolView:
        seg = self.schedule.segment_index(step)
        hit = self._views.get(seg)
        if hit is not None:
            return hit
        with self._lock:
            hit = self._views.get(seg)
            if hit is None:
                hit = self._views[seg] = self._build_view(seg)
        return hit

    def _build_view(self, seg: int) -> PoolView:
        eff = self.schedule.effects_at(self.schedule.segment_range(seg)[0])
        price, drift, latency = eff.as_dicts()
        profiles: List[ProviderProfile] = []
        active = np.zeros(self.n_providers, bool)
        for j, base in enumerate(self.roster):
            changes = {}
            if base.name in price:
                changes["cost_milli_usd"] = base.cost_milli_usd * \
                    price[base.name]
            if base.name in latency:
                changes["latency_ms"] = base.latency_ms * latency[base.name]
            if base.name in drift:
                s = drift[base.name]
                changes["base_recall"] = float(
                    np.clip(base.base_recall * s, 0.0, 1.0))
                changes["sweet"] = {k: float(np.clip(v * s, 0.0, 1.0))
                                    for k, v in base.sweet.items()}
            profiles.append(base.replace(**changes) if changes else base)
            joined = j < self.n_base or base.name in eff.joined
            active[j] = joined and base.name not in eff.down
        costs = np.asarray(
            [p.cost_milli_usd if active[j] else 0.0
             for j, p in enumerate(profiles)], np.float32)
        lats = np.asarray(
            [p.latency_ms if active[j] else self.outage_timeout_ms
             for j, p in enumerate(profiles)], np.float64)
        # inactive slots collapse to one key entry: their detections are
        # empty no matter what the underlying profile looks like
        dets_key = tuple(
            ("on", p.fingerprint(detection_only=True)) if active[j]
            else ("off",) for j, p in enumerate(profiles))
        econ_key = (dets_key, tuple(costs.tolist()), tuple(lats.tolist()),
                    eff.demand)
        return PoolView(seg, tuple(profiles), active, costs, lats,
                        dets_key, econ_key, eff.demand)

    # -- segment traces + cores ------------------------------------------
    def traces_at(self, step: int) -> TraceSet:
        view = self.view_at(step)
        hit = self._traces.get(view.dets_key)
        if hit is not None:
            return hit
        with self._lock:
            hit = self._traces.get(view.dets_key)
            if hit is None:
                hit = self._traces[view.dets_key] = \
                    self._build_traces(view)
        return hit

    def _build_traces(self, view: PoolView) -> TraceSet:
        return build_segment_traces(self.base_traces, view.profiles,
                                    view.dets_key, self.seed,
                                    self._grouper,
                                    base_det_fp=self._base_det_fp,
                                    stats=self.stats)

    def snapshot_at(self, step: int) -> PoolSnapshot:
        """Picklable segment recipe for worker processes (memoized per
        segment).  A worker holding ``base_traces`` rebuilds the segment
        via :func:`build_segment_traces` bit-identically to this pool."""
        view = self.view_at(step)
        hit = self._snapshots.get(view.seg)
        if hit is None:
            hit = self._snapshots[view.seg] = PoolSnapshot(
                view.seg, view.dets_key, view.profiles, self.seed)
        return hit

    def core_at(self, step: int) -> SubsetEvaluationCore:
        view = self.view_at(step)
        hit = self._cores.get(view.dets_key)
        if hit is not None:
            self.stats["cores_reused"] += 1
            return hit
        traces = self.traces_at(step)
        with self._lock:
            hit = self._cores.get(view.dets_key)
            if hit is None:
                self.stats["cores_built"] += 1
                hit = self._cores[view.dets_key] = SubsetEvaluationCore(
                    traces, voting=self.voting, ablation=self.ablation,
                    use_kernel=self.use_kernel)
        return hit

    def sharded_core_at(self, step: int,
                        n_shards: int) -> ShardedSubsetEvaluationCore:
        view = self.view_at(step)
        key = (view.dets_key, int(n_shards))
        hit = self._sharded.get(key)
        if hit is not None:
            return hit
        traces = self.traces_at(step)
        with self._lock:
            hit = self._sharded.get(key)
            if hit is None:
                hit = self._sharded[key] = ShardedSubsetEvaluationCore(
                    traces, n_shards=n_shards, voting=self.voting,
                    ablation=self.ablation, use_kernel=self.use_kernel)
        return hit

    # -- demand ----------------------------------------------------------
    def demand_weights_at(self, step: int,
                          img_indices: Sequence[int]
                          ) -> Optional[np.ndarray]:
        """Normalized sampling weights over ``img_indices`` under the
        segment's demand focus; None when demand is uniform."""
        view = self.view_at(step)
        if view.demand is None:
            return None
        cats, boost = view.demand
        focus = frozenset(cats)
        w = np.asarray([boost if self._img_cats[int(i)] & focus else 1.0
                        for i in img_indices], np.float64)
        return w / w.sum()

    # -- per-segment oracle ----------------------------------------------
    def _segment_fees(self, view: PoolView,
                      masks: np.ndarray) -> np.ndarray:
        """(M,) summed segment fees per lattice row, memoized per fee
        vector.  Accumulated column by column in ascending provider order
        (adding an exact 0.0 for unset bits), so each row equals the old
        per-bitmask python sum of set-bit fees to the last float64 bit."""
        fee_key = tuple(view.costs.tolist())
        hit = self._fees.get(fee_key)
        if hit is not None:
            return hit
        bits = (masks[:, None] >> np.arange(self.n_providers)) & 1
        bc = view.costs.astype(np.float64)
        fee = np.zeros(len(masks), np.float64)
        for p in range(self.n_providers):
            fee = fee + bits[:, p] * bc[p]
        self._fees[fee_key] = fee
        return fee

    def oracle(self, img_idx: int, step: int, beta: float, *,
               against: str = "gt") -> Tuple[int, float]:
        """(best mask, best reward) for one image under one segment.

        One masked slice of the image's full lattice: rows overlapping
        inactive providers or fusing to an empty ensemble are masked out,
        rewards compose as ap50 + beta * segment fees over the whole
        lattice at once, and the first-occurrence argmax over the
        popcount-ordered rows keeps the Algo.-2 tie-breaking (cheaper
        subsets win ties).  Memoized per (segment economics, beta, image);
        the lattice itself is memoized per (image, against) on the
        segment core.
        """
        view = self.view_at(step)
        key = (view.econ_key, round(float(beta), 12), int(img_idx), against)
        hit = self._oracle.get(key)
        if hit is not None:
            return hit
        core = self.core_at(step)
        lat = core.evaluate_lattice(int(img_idx), against=against)
        valid = ((lat.masks & ~view.active_mask) == 0) & (lat.n_dets > 0)
        best_m, best_r = 0, -1.0
        if valid.any():
            r = np.where(valid,
                         lat.ap + beta * self._segment_fees(view, lat.masks),
                         -np.inf)
            i = int(np.argmax(r))
            if r[i] > -1.0:     # strict improvement over the empty action
                best_m, best_r = int(lat.masks[i]), float(r[i])
        self._oracle[key] = (best_m, best_r)
        return best_m, best_r

    # -- invalidation ----------------------------------------------------
    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Drop the images' cached artifacts from EVERY materialized
        segment core (plain and sharded) and every oracle entry touching
        them — the thread-backend counterpart of the process workers'
        all-regime fan-out: a trace mutation must not leave stale
        ensembles behind in a segment the clock later revisits.  Returns
        the number of tables dropped across all cores.

        Worker PROCESSES hold their own per-regime caches this sweep
        cannot reach: a process-backend service must be invalidated
        through ``AsyncFederationService.invalidate_images``, which
        bridges both sides."""
        drop = {int(i) for i in img_indices}
        with self._lock:
            cores = list(self._cores.values()) + list(self._sharded.values())
            for k in [k for k in self._oracle if k[2] in drop]:
                del self._oracle[k]
        dropped = 0
        for c in cores:
            dropped += c.invalidate_images(drop)
        return dropped

    # -- introspection ---------------------------------------------------
    def agg_core_stats(self) -> Dict[str, int]:
        """Summed cache-hit counters over every materialized segment core
        (the online driver diffs this around each segment)."""
        agg: Dict[str, int] = {}
        cores = list(self._cores.values()) + list(self._sharded.values())
        for c in cores:
            for k, v in c.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def cache_report(self) -> Dict[str, object]:
        return {"views": len(self._views), "trace_sets": len(self._traces),
                "cores": len(self._cores), "sharded": len(self._sharded),
                "oracle_entries": len(self._oracle),
                "stats": dict(self.stats)}
