"""Timed provider dynamics: the event language of the scenario engine.

A :class:`ScenarioSchedule` is a sorted list of :class:`ProviderEvent`s on
a step axis of length ``horizon`` (one step = one served request / env
transition).  Event kinds:

  ``price``     provider's fee  = base fee x value
  ``drift``     provider's recall (base + sweet spots) = base x value,
                clipped to [0, 1] — accuracy degradation or improvement
  ``latency``   provider's latency = base latency x value (spikes)
  ``outage``    provider hard-down: empty detections, zero fee, timeout
                latency if selected
  ``recovery``  cancels an outage
  ``arrival``   a NEW provider (event carries its profile) joins the pool;
                before its arrival step the slot exists but is inactive,
                so the action space is fixed for the whole scenario
  ``demand``    the request mix concentrates on images containing the
                given categories (comma-joined; "" resets to uniform)

Values are multipliers **against the base profile** (latest event per
(kind, provider) wins), so regimes compose predictably and returning to
``value=1.0`` restores the base state exactly — which the provider pool
exploits to re-hit warm evaluation caches.

Built-in scenarios (``price_war``, ``provider_outage``, ``accuracy_drift``,
``flash_crowd``, ``provider_churn``) live in ``BUILTIN_SCENARIOS``;
``random_scenario`` samples a seeded composition of the same event kinds;
``build_scenario`` resolves either by name.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.federation.providers import ProviderProfile

EVENT_KINDS = ("price", "drift", "latency", "outage", "recovery",
               "arrival", "demand")


@dataclass(frozen=True)
class ProviderEvent:
    step: int
    kind: str
    provider: str = ""          # provider name; for "demand": categories
    value: float = 1.0          # multiplier vs base (or demand boost)
    profile: Optional[ProviderProfile] = None   # "arrival" payload

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r} "
                             f"(one of {EVENT_KINDS})")
        if self.kind == "arrival" and self.profile is None:
            raise ValueError("arrival events must carry a profile")
        if self.step < 0:
            raise ValueError(f"event step must be >= 0, got {self.step}")


@dataclass(frozen=True)
class PoolEffects:
    """Accumulated effect of every event at or before one step: latest
    event per (kind, provider) wins; outage/recovery toggle."""
    price: Tuple[Tuple[str, float], ...] = ()
    drift: Tuple[Tuple[str, float], ...] = ()
    latency: Tuple[Tuple[str, float], ...] = ()
    down: frozenset = frozenset()
    joined: frozenset = frozenset()
    demand: Optional[Tuple[Tuple[str, ...], float]] = None

    def as_dicts(self):
        return dict(self.price), dict(self.drift), dict(self.latency)


class ScenarioSchedule:
    """An immutable, sorted event timeline over ``horizon`` steps.

    Segment s spans ``[boundaries[s], boundaries[s+1])``; segment 0 always
    starts at step 0 (the base regime) even if the first event is later.
    Steps past the horizon clamp to the final segment, so a driver that
    overruns the schedule keeps a well-defined world.
    """

    def __init__(self, name: str, horizon: int,
                 events: Sequence[ProviderEvent]):
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        bad = [e for e in events if e.step >= horizon]
        if bad:
            raise ValueError(f"events past the horizon ({horizon}): {bad}")
        self.name = name
        self.horizon = int(horizon)
        self.events: Tuple[ProviderEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self.boundaries: List[int] = sorted(
            {0} | {e.step for e in self.events})

    @property
    def n_segments(self) -> int:
        return len(self.boundaries)

    def clamp(self, step: int) -> int:
        return min(max(int(step), 0), self.horizon - 1)

    def segment_index(self, step: int) -> int:
        return bisect.bisect_right(self.boundaries, self.clamp(step)) - 1

    def segment_range(self, seg: int) -> Tuple[int, int]:
        """[start, end) step range of segment ``seg``."""
        start = self.boundaries[seg]
        end = (self.boundaries[seg + 1] if seg + 1 < self.n_segments
               else self.horizon)
        return start, end

    def arrivals(self) -> List[ProviderProfile]:
        """Every arriving provider's profile, in event order — the pool
        pre-allocates their action slots so the action space is static."""
        return [e.profile for e in self.events if e.kind == "arrival"]

    def effects_at(self, step: int) -> PoolEffects:
        price: Dict[str, float] = {}
        drift: Dict[str, float] = {}
        latency: Dict[str, float] = {}
        down: set = set()
        joined: set = set()
        demand: Optional[Tuple[Tuple[str, ...], float]] = None
        t = self.clamp(step)
        for ev in self.events:
            if ev.step > t:
                break
            if ev.kind == "price":
                price[ev.provider] = ev.value
            elif ev.kind == "drift":
                drift[ev.provider] = ev.value
            elif ev.kind == "latency":
                latency[ev.provider] = ev.value
            elif ev.kind == "outage":
                down.add(ev.provider)
            elif ev.kind == "recovery":
                down.discard(ev.provider)
            elif ev.kind == "arrival":
                joined.add(ev.profile.name)
            elif ev.kind == "demand":
                cats = tuple(c.strip() for c in ev.provider.split(",")
                             if c.strip())
                demand = (cats, ev.value) if cats else None
        return PoolEffects(tuple(sorted(price.items())),
                           tuple(sorted(drift.items())),
                           tuple(sorted(latency.items())),
                           frozenset(down), frozenset(joined), demand)

    def describe(self) -> str:
        lines = [f"scenario {self.name!r}: horizon={self.horizon} "
                 f"segments={self.n_segments}"]
        for ev in self.events:
            tgt = ev.provider or (ev.profile.name if ev.profile else "*")
            lines.append(f"  t={ev.step:>5d}  {ev.kind:<8s} {tgt} "
                         f"x{ev.value:g}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Built-in scenarios.  Each takes the BASE provider list and a horizon and
# places events at fixed fractions, so one scenario scales to any budget.
# ---------------------------------------------------------------------------

def price_war(providers: Sequence[ProviderProfile], *,
              horizon: int = 1200) -> ScenarioSchedule:
    """Two providers undercut each other, then prices normalize.

    Detections never change, so every regime shares ONE warm evaluation
    cache — the pure test of cost-sensitivity under re-pricing."""
    a, b = providers[0].name, providers[1 % len(providers)].name
    h = horizon
    return ScenarioSchedule("price_war", h, [
        ProviderEvent(h // 4, "price", a, 0.25),
        ProviderEvent(h // 2, "price", a, 1.0),
        ProviderEvent(h // 2, "price", b, 0.2),
        ProviderEvent(3 * h // 4, "price", a, 1.8),
        ProviderEvent(3 * h // 4, "price", b, 1.0),
    ])


def provider_outage(providers: Sequence[ProviderProfile], *,
                    horizon: int = 1200) -> ScenarioSchedule:
    """The strongest base provider hard-fails mid-stream and later
    recovers; a latency spike precedes the failure (brown-out)."""
    victim = max(providers, key=lambda p: p.base_recall).name
    h = horizon
    return ScenarioSchedule("provider_outage", h, [
        ProviderEvent(h // 4, "latency", victim, 6.0),
        ProviderEvent(h // 3, "outage", victim),
        ProviderEvent(2 * h // 3, "recovery", victim),
        ProviderEvent(2 * h // 3, "latency", victim, 1.0),
    ])


def accuracy_drift(providers: Sequence[ProviderProfile], *,
                   horizon: int = 1200) -> ScenarioSchedule:
    """One provider's recall decays in two steps while another's improves,
    then both revert — the w/o-retraining model-rot regime."""
    a = providers[0].name
    b = providers[1 % len(providers)].name
    h = horizon
    return ScenarioSchedule("accuracy_drift", h, [
        ProviderEvent(h // 4, "drift", a, 0.7),
        ProviderEvent(h // 2, "drift", a, 0.5),
        ProviderEvent(h // 2, "drift", b, 1.35),
        ProviderEvent(3 * h // 4, "drift", a, 1.0),
        ProviderEvent(3 * h // 4, "drift", b, 1.0),
    ])


def flash_crowd(providers: Sequence[ProviderProfile], *,
                horizon: int = 1200) -> ScenarioSchedule:
    """The request mix concentrates on the Azure sweet-spot categories
    (paper Fig. 1: bottle/cup/dining-table are AWS blind spots), then
    returns to uniform.  Providers are untouched — the SAME evaluation
    cache serves every regime; only the traffic distribution moves."""
    h = horizon
    return ScenarioSchedule("flash_crowd", h, [
        ProviderEvent(h // 3, "demand", "bottle,cup,dining table", 8.0),
        ProviderEvent(2 * h // 3, "demand", "", 1.0),
    ])


def provider_churn(providers: Sequence[ProviderProfile], *,
                   horizon: int = 1200) -> ScenarioSchedule:
    """A mid-tier provider churns out for good; a stronger, pricier
    challenger launches later."""
    leaver = providers[-1].name
    challenger = ProviderProfile(
        name="challenger", base_recall=0.82, box_jitter=0.018, fp_rate=0.4,
        score_mu=0.80, cost_milli_usd=1.6, dialect=1, latency_ms=280.0)
    h = horizon
    return ScenarioSchedule("provider_churn", h, [
        ProviderEvent(2 * h // 5, "outage", leaver),
        ProviderEvent(3 * h // 5, "arrival", profile=challenger),
    ])


def random_scenario(providers: Sequence[ProviderProfile], *,
                    horizon: int = 1200, seed: int = 0,
                    n_events: int = 6) -> ScenarioSchedule:
    """Seeded random composition of the built-in event kinds.

    Outages always schedule a matching recovery and never take the pool
    below two live providers; values are drawn from the same ranges the
    built-ins use, so random scenarios stay in-distribution."""
    rng = np.random.default_rng(seed)
    names = [p.name for p in providers]
    cat_pool = ["person", "chair", "car", "cup", "bottle", "dining table",
                "book", "handbag"]
    steps = sorted(int(s) for s in
                   rng.integers(horizon // 5, horizon - 1, n_events))
    events: List[ProviderEvent] = []
    down: Dict[str, int] = {}       # name -> recovery step
    for t in steps:
        kind = str(rng.choice(["price", "drift", "latency", "outage",
                               "demand"]))
        name = str(rng.choice(names))
        if kind == "price":
            events.append(ProviderEvent(
                t, "price", name, float(np.exp(rng.uniform(
                    np.log(0.2), np.log(3.0))))))
        elif kind == "drift":
            events.append(ProviderEvent(
                t, "drift", name, float(rng.uniform(0.4, 1.3))))
        elif kind == "latency":
            events.append(ProviderEvent(
                t, "latency", name, float(rng.uniform(0.5, 6.0))))
        elif kind == "outage":
            down_now = [n for n, r in down.items() if r > t]
            if name in down_now or len(names) - len(down_now) <= 2:
                continue            # never drop below two live providers
            recover = int(min(horizon - 1,
                              t + rng.integers(horizon // 8, horizon // 3)))
            events.append(ProviderEvent(t, "outage", name))
            if recover > t:
                events.append(ProviderEvent(recover, "recovery", name))
            down[name] = recover
        else:
            k = int(rng.integers(1, 3))
            cats = ",".join(rng.choice(cat_pool, size=k, replace=False))
            events.append(ProviderEvent(
                t, "demand", cats, float(rng.uniform(3.0, 10.0))))
    return ScenarioSchedule(f"random-{seed}", horizon, events)


BUILTIN_SCENARIOS = {
    "price_war": price_war,
    "provider_outage": provider_outage,
    "accuracy_drift": accuracy_drift,
    "flash_crowd": flash_crowd,
    "provider_churn": provider_churn,
}


def build_scenario(name: str, providers: Sequence[ProviderProfile], *,
                   horizon: int = 1200, seed: int = 0) -> ScenarioSchedule:
    """Resolve a scenario by name: a built-in, or ``random`` /
    ``random:<seed>`` for the seeded generator."""
    if name.startswith("random"):
        _, _, s = name.partition(":")
        return random_scenario(providers, horizon=horizon,
                               seed=int(s) if s else seed)
    if name in BUILTIN_SCENARIOS:
        return BUILTIN_SCENARIOS[name](providers, horizon=horizon)
    raise ValueError(f"unknown scenario {name!r} (built-ins: "
                     f"{', '.join(BUILTIN_SCENARIOS)}, or random[:seed])")
