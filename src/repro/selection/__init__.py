"""Selection policies: non-RL subset selectors behind the agent surface.

See ``docs/policies.md`` for the interface contract and knobs.
"""
from repro.selection.base import SelectorPolicy  # noqa: F401
from repro.selection.cascade import (CascadeSelector,  # noqa: F401
                                     detection_confidence)
from repro.selection.hybrid import HybridSelector  # noqa: F401
from repro.selection.mct import MCTSelector  # noqa: F401
