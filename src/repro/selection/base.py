"""Selector policies: non-RL subset-selection strategies behind the
agent interface the serving/eval stack already speaks.

A :class:`SelectorPolicy` decides provider subsets from the *request*
(image index) rather than from a learned state embedding, but it remains
a drop-in "agent" everywhere an RL agent goes:

  * ``select_for_images(imgs, step=None)`` is the canonical surface —
    (B,) image indices -> (B, N) binary actions.  ``FederationService``
    and ``AsyncFederationService`` dispatch on this attribute (skipping
    the feature forward + jit padding entirely), which is what makes the
    sync and async serving paths bit-identical for a selector: both call
    the same function on the same indices.
  * ``select_action`` / ``select_action_batch`` adapt states back to
    image indices (the env's feature rows are unique per image), so
    ``agent_policy`` / ``evaluate_policy`` / ``_make_batch_select`` work
    unchanged.

Under a scenario pool, ``step`` routes the decision to the segment
active at that schedule step (fees, activity, detection traces); the
default ``step=None`` uses the env's live clock for non-stationary envs
and the static traces otherwise.  All subset evaluation rides the shared
:class:`~repro.federation.evaluation.SubsetEvaluationCore` memo — the
selectors add no second accounting or caching path.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.federation.evaluation import mask_to_action


class SelectorPolicy:
    """Base class wiring image-indexed selection into the agent surface.

    Subclasses implement :meth:`select_masks` (image indices -> subset
    bitmasks under one resolved segment); everything else — action
    materialization, the state->image adapters, segment resolution — is
    shared here.
    """

    name = "selector"

    def __init__(self, env):
        self.env = env
        self.n_providers = env.n_providers
        # scenario envs append observable pool-status columns; the base
        # block is the per-image part, static across regime switches,
        # which makes it a stable state->image lookup key
        self._base_dim = int(getattr(env, "_base_dim", env.state_dim))
        self._img_of_row: Optional[Dict[bytes, int]] = None

    # -- segment resolution ----------------------------------------------
    def _resolve(self, step: Optional[int]):
        """(traces, core, costs, active, step) for one decision point.

        With a scenario pool: the segment state at ``step`` (default: the
        env's live clock).  Without: the env's static traces/core, all
        providers active.
        """
        pool = getattr(self.env, "pool", None)
        if pool is None:
            active = np.ones(self.n_providers, bool)
            return self.env.traces, self.env.core, self.env.costs, active, 0
        step = int(self.env.clock if step is None else step)
        view = pool.view_at(step)
        return (pool.traces_at(step), pool.core_at(step), view.costs,
                view.active, step)

    @staticmethod
    def _cheapest_active(costs: np.ndarray, active: np.ndarray) -> int:
        """Lowest-fee active provider; ties break toward the lowest
        index (argmin keeps the first minimum).  Falls back to global
        argmin if the whole roster is down."""
        idx = np.flatnonzero(active)
        if len(idx) == 0:
            return int(np.argmin(costs))
        return int(idx[np.argmin(np.asarray(costs, np.float64)[idx])])

    def _mean_reward(self, img_indices, masks, beta: float, *,
                     step: Optional[int] = None) -> float:
        """Mean Eq.-5 reward (ap50 + beta * fee, -1 on empty) of explicit
        per-image masks under the segment at ``step`` — one cached
        lattice row per (image, mask), shared with every other reader."""
        _, core, costs, _, _ = self._resolve(step)
        against = getattr(self.env, "_against", "gt")
        costs = np.asarray(costs, np.float64)
        total = 0.0
        for img, m in zip(img_indices, masks):
            m = int(m)
            if m == 0:
                total += -1.0
                continue
            lat = core.evaluate_lattice(int(img), against=against)
            row = lat.index_of(m)
            if lat.n_dets[row] == 0:
                total += -1.0
                continue
            fee = sum(costs[j] for j in range(self.n_providers)
                      if m >> j & 1)
            total += float(lat.ap[row]) + beta * fee
        return total / max(len(img_indices), 1)

    # -- canonical surface -------------------------------------------------
    def select_masks(self, img_indices: Sequence[int], *,
                     step: Optional[int] = None) -> np.ndarray:
        raise NotImplementedError

    def select_for_images(self, img_indices: Sequence[int], *,
                          step: Optional[int] = None) -> np.ndarray:
        """(B,) image indices -> (B, N) binary float32 actions."""
        masks = self.select_masks(img_indices, step=step)
        return np.stack([mask_to_action(int(m), self.n_providers)
                         for m in masks]) if len(masks) else \
            np.zeros((0, self.n_providers), np.float32)

    # -- agent-interface adapters ------------------------------------------
    def _lookup(self) -> Dict[bytes, int]:
        if self._img_of_row is None:
            base = np.ascontiguousarray(
                self.env.features[:, :self._base_dim], np.float32)
            self._img_of_row = {base[i].tobytes(): i
                                for i in range(len(base))}
        return self._img_of_row

    def _images_of(self, states: np.ndarray) -> list:
        lut = self._lookup()
        rows = np.ascontiguousarray(
            np.asarray(states, np.float32)[:, :self._base_dim])
        try:
            return [lut[r.tobytes()] for r in rows]
        except KeyError:
            raise KeyError(
                f"{type(self).__name__}: state row is not a row of "
                f"env.features — selector policies decide from image "
                f"indices; pass them via select_for_images() instead")

    def select_action(self, state: np.ndarray, *,
                      deterministic: bool = True) -> Tuple[np.ndarray, None]:
        img = self._images_of(np.asarray(state, np.float32)[None])[0]
        return self.select_for_images([img])[0], None

    def select_action_batch(self, states: np.ndarray, *,
                            deterministic: bool = True
                            ) -> Tuple[np.ndarray, None]:
        imgs = self._images_of(states)
        return self.select_for_images(imgs), None
