"""FrugalML-style calibrated cascade over the provider pool.

Query the cheapest active provider first; accept its answer when a
per-image confidence score clears a calibrated threshold, otherwise
escalate to one learned subset.  The two knobs — which subset to
escalate to and where the threshold sits — are tuned ONCE on held-out
trace images (the env's train split) by exact enumeration over the
subset lattice, so calibration is a pure read of the memoized
:class:`~repro.federation.evaluation.SubsetEvaluationCore`.

Confidence is ``max_score * k / (k + 1)`` over the base provider's k
detections (0.0 when it returns nothing): high-scoring, well-populated
answers pass; empty or hesitant ones escalate.  The contract the
property tests pin down: an image whose confidence clears the threshold
is served by the base provider ALONE — the cascade never pays a second
provider after the confidence gate passes.

Under a scenario pool the base provider re-resolves per segment (the
cheapest ACTIVE provider) and the escalation set is intersected with the
active roster, but threshold and escalation stay at their calibrated
values — the cascade is deliberately static where the RL policy adapts,
which is exactly the gap the frontier benchmark measures.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.federation.evaluation import popcount_masks
from repro.selection.base import SelectorPolicy


def detection_confidence(dets) -> float:
    """``max_score * k / (k + 1)`` over one provider's detections."""
    k = len(dets.scores)
    if k == 0:
        return 0.0
    return float(np.max(dets.scores)) * k / (k + 1.0)


class CascadeSelector(SelectorPolicy):
    """Calibrated cheap-first cascade (FrugalML-style).

    Parameters
    ----------
    env:          ``ArmolEnv`` (or a ``NonStationaryArmolEnv``, whose
                  pool's segment-0 regime anchors calibration).
    beta:         cost weight of the calibration objective
                  (``ap50 + beta * fee`` — Eq.-5 shaped, -1 on empty).
                  More negative -> a cheaper escalation subset and a more
                  permissive threshold.
    calib_images: calibration image indices (default: the env's train
                  split, held out from every evaluation path).
    threshold:    override the tuned confidence threshold (used by the
                  property tests to probe the gate contract).
    """

    name = "cascade"

    def __init__(self, env, *, beta: float = -0.05,
                 calib_images: Optional[Sequence[int]] = None,
                 threshold: Optional[float] = None):
        super().__init__(env)
        self.beta = float(beta)
        calib = (env.train_idx if calib_images is None
                 else np.asarray(calib_images, np.int64))
        traces, core, costs, active, _ = self._resolve(
            0 if getattr(env, "pool", None) is not None else None)
        self.base_idx = self._cheapest_active(costs, active)
        self.base_mask = 1 << self.base_idx
        self._calibrate(traces, core, costs, active, calib)
        if threshold is not None:
            self.threshold = float(threshold)
            self.calibration["threshold"] = self.threshold
            self.calibration["threshold_overridden"] = True

    # -- calibration -------------------------------------------------------
    def _calibrate(self, traces, core, costs, active,
                   calib: np.ndarray) -> None:
        n = self.n_providers
        against = getattr(self.env, "_against", "gt")
        active_mask = int(sum(1 << j for j in np.flatnonzero(active)))
        masks = [int(m) for m in popcount_masks(n)]
        fees = np.asarray([sum(float(costs[j]) for j in range(n)
                               if m >> j & 1) for m in masks], np.float64)
        imgs = [int(i) for i in calib]
        self.calib_imgs = np.asarray(imgs, np.int64)
        core.precompute(imgs)
        ap = np.zeros((len(imgs), len(masks)))
        n_dets = np.zeros((len(imgs), len(masks)), np.int64)
        for t, img in enumerate(imgs):
            lat = core.evaluate_lattice(img, against=against)
            # lattice rows share popcount order across images
            ap[t] = lat.ap
            n_dets[t] = lat.n_dets
        reward = np.where(n_dets > 0, ap + self.beta * fees[None, :], -1.0)

        base_row = masks.index(self.base_mask)
        cand = [k for k, m in enumerate(masks)
                if m & self.base_mask and bin(m).count("1") >= 2
                and (m & ~active_mask) == 0]
        mean_r = reward.mean(axis=0)
        esc_row = base_row
        best = -np.inf
        for k in cand:          # popcount order: cheaper subsets win ties
            if mean_r[k] > best:
                best, esc_row = float(mean_r[k]), k
        self.escal_mask = masks[esc_row]

        conf = np.asarray([detection_confidence(traces.dets[i][self.base_idx])
                           for i in imgs])
        r_base, r_esc = reward[:, base_row], reward[:, esc_row]
        # threshold sweep: conf >= theta serves base-only, below escalates.
        # Candidates ascend, so argmax lands on the SMALLEST maximizing
        # theta — the tie-break toward more base traffic (cheaper).
        cands = np.concatenate([np.unique(conf), [np.inf]])
        totals = np.asarray([
            float(r_base[conf >= th].sum() + r_esc[conf < th].sum())
            for th in cands])
        self.threshold = float(cands[int(np.argmax(totals))])
        self.calibration: Dict = {
            "base_idx": self.base_idx, "base_mask": self.base_mask,
            "escal_mask": self.escal_mask, "threshold": self.threshold,
            "beta": self.beta, "n_calib": len(imgs),
            "mean_reward_base": round(float(r_base.mean()), 4),
            "mean_reward_escalated": round(float(r_esc.mean()), 4),
            "calibrated_total": round(float(totals.max()) / len(imgs), 4),
        }

    # -- gate --------------------------------------------------------------
    def gate(self, img_indices: Sequence[int], *,
             step: Optional[int] = None
             ) -> Tuple[np.ndarray, int, int]:
        """(passes, base_idx, escalation_mask) under the segment at
        ``step``: ``passes[t]`` is True when image t's confidence clears
        the threshold (serve base-only).  The escalation mask is the
        calibrated subset restricted to active providers, always
        containing the segment's base provider."""
        traces, _, costs, active, _ = self._resolve(step)
        b = self._cheapest_active(costs, active)
        conf = np.asarray([detection_confidence(traces.dets[int(i)][b])
                           for i in img_indices])
        active_mask = int(sum(1 << j for j in np.flatnonzero(active)))
        esc = (self.escal_mask | (1 << b)) & active_mask
        if esc == 0:
            esc = 1 << b
        return conf >= self.threshold, b, esc

    def confidence(self, img_idx: int, *,
                   step: Optional[int] = None) -> float:
        """The base provider's confidence score for one image under the
        segment at ``step`` (the quantity the threshold gates)."""
        traces, _, costs, active, _ = self._resolve(step)
        b = self._cheapest_active(costs, active)
        return detection_confidence(traces.dets[int(img_idx)][b])

    def select_masks(self, img_indices: Sequence[int], *,
                     step: Optional[int] = None) -> np.ndarray:
        passes, b, esc = self.gate(img_indices, step=step)
        return np.where(passes, 1 << b, esc).astype(np.int64)
