"""Cost–accuracy frontier: RL vs cascade vs MCT vs hybrid, per scenario.

The paper reports one operating point — same accuracy as the
all-providers ensemble at ~67% lower fee.  This benchmark sweeps each
policy family's cost knob and reports the whole trade-off curve so that
point becomes one sample on a frontier:

  * **rl**       — SAC trained online per cost weight ``beta``
                   (``run_online``, validated per-segment snapshots);
  * **cascade**  — calibrated cheap-first cascade per ``beta``;
  * **hybrid**   — the same cascade gate fronting the matching-``beta``
                   RL snapshots on escalated traffic;
  * **mct**      — online budgeted per-request selection per ``budget``;
  * baselines    — cheapest active single provider, and all providers.

Every arm is scored the same way: at each segment's last step, the
policy picks subsets for the demand-weighted test split and
``evaluate_masks_at`` prices them under that segment's pool — shared
lattice memo, shared fee accounting, no per-arm evaluation code.  All
stochastic inputs (traces, schedules, SAC init, exploration) are seeded,
so the emitted curves — and the dominance invariants gated by
``tools/check_bench.py`` — are machine-invariant.

Gated invariants (1.0 = holds, margins recorded alongside):

  * ``rl_dominates_cheapest``      — some RL point matches the cheapest
    single provider's cost (+eps) at no worse AP50 (-eps);
  * ``rl_dominates_all_providers`` — some RL point matches the
    all-providers AP50 (-eps) at no higher cost (+eps);
  * ``hybrid_ge_cascade``          — at every shared ``beta``, hybrid
    reward >= cascade reward (-eps) at that beta.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.loops import _make_batch_select
from repro.core.sac import SAC, SACConfig
from repro.federation.providers import default_providers
from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                             build_scenario)
from repro.scenarios.online import _snapshot, _swap_state, run_online
from repro.selection.cascade import CascadeSelector
from repro.selection.hybrid import HybridSelector
from repro.selection.mct import MCTSelector

SCENARIOS = ("price_war", "provider_outage", "accuracy_drift")
# cost weight sweep: reward = ap50 + beta * fee.  0.0 is the accuracy
# endpoint; -1.0 is the collapse arm (with ap50 in [0,1] and unit fees,
# a second provider can never pay for itself, so the optimum is the best
# cheap single — past -1.0 the empty set starts beating paid singles and
# the arm degenerates)
BETAS = (0.0, -0.1, -0.3, -1.0)
BUDGETS = (1.0, 2.0, 3.0)           # MCT per-request fee budget (mUSD)
EPS_AP = 2.0      # AP50 slack, 0-100 scale
EPS_COST = 0.25   # fee slack, mUSD per request (a quarter unit fee)
EPS_REWARD = 0.02


def _weights(env, imgs: np.ndarray, step: int) -> np.ndarray:
    w = env.pool.demand_weights_at(step, imgs)
    return (np.full(len(imgs), 1.0 / max(len(imgs), 1))
            if w is None else np.asarray(w, np.float64))


def score_masks_fn(env, masks_fn, *, beta: float = 0.0) -> Dict:
    """Score ``masks_fn(imgs, step) -> bitmasks`` at every segment's last
    step on the demand-weighted test split; ``env`` must be the beta-0
    eval env (reward at ``beta`` is recomposed here, Eq.-5's -1 kept).
    Returns segment-mean ``{"ap50", "cost", "reward", "segments"}`` with
    AP50 on the 0-100 scale."""
    sched = env.pool.schedule
    imgs = env.test_idx
    segs: List[Dict] = []
    for seg in range(sched.n_segments):
        end = sched.segment_range(seg)[1] - 1
        wts = _weights(env, imgs, end)
        masks = np.asarray(masks_fn(imgs, end), np.int64)
        out = env.evaluate_masks_at(imgs, masks, end)
        empty = out["reward"] == -1.0      # env.beta == 0: reward==-1 <=> empty
        r = np.where(empty, -1.0, out["ap50"] + beta * out["cost"])
        segs.append({"seg": seg,
                     "ap50": round(100.0 * float(wts @ out["ap50"]), 2),
                     "cost": round(float(wts @ out["cost"]), 4),
                     "reward": round(float(wts @ r), 4)})
    return {"ap50": round(float(np.mean([s["ap50"] for s in segs])), 2),
            "cost": round(float(np.mean([s["cost"] for s in segs])), 4),
            "reward": round(float(np.mean([s["reward"] for s in segs])), 4),
            "segments": segs}


def _cheapest_mask(env, step: int) -> int:
    view = env.pool.view_at(step)
    idx = np.flatnonzero(view.active)
    if len(idx) == 0:
        return 1 << int(np.argmin(view.costs))
    return 1 << int(idx[np.argmin(np.asarray(view.costs,
                                             np.float64)[idx])])


def _rl_arm(pool, env_eval, beta: float, *, seed: int, log) -> Dict:
    """Train SAC online at cost weight ``beta``; score each segment with
    its validated-best snapshot (masks via the deterministic policy)."""
    env_rl = NonStationaryArmolEnv(pool, mode="gt", beta=beta,
                                   observe_pool=True, seed=seed + 1)
    agent = SAC(SACConfig(state_dim=env_rl.state_dim,
                          n_providers=env_rl.n_providers, alpha=0.02,
                          lr=3e-4, gamma=0.0, hidden=(32, 32), seed=seed))
    res = run_online(agent, env_rl, lanes=4, seed=seed,
                     collect_snapshots=True, log=None)
    snaps = res["snapshots"]
    select = _make_batch_select(agent, deterministic=True)
    bits = np.arange(env_rl.n_providers)

    def masks_fn(imgs, step):
        """Masks from the segment's validated snapshot for ANY image set
        (the hybrid arm calls this with calibration images too)."""
        seg = pool.schedule.segment_index(step)
        snap = snaps[min(seg, len(snaps) - 1)]
        live = _swap_state(agent, snap)
        acts = np.asarray(select(np.asarray(
            env_rl.features_at(step, np.asarray(imgs, np.int64)),
            np.float32)))
        agent.state = live
        return ((acts > 0.5).astype(np.int64) << bits).sum(axis=1)

    pt = score_masks_fn(env_eval, masks_fn, beta=beta)
    pt["knob"] = beta
    pt["recovery"] = res["summary"]["mean_recovery_post_switch"]
    if log:
        log(f"[frontier] rl beta={beta}: ap50={pt['ap50']} "
            f"cost={pt['cost']}")
    return {"point": pt, "masks_fn": masks_fn}


def _train_mct(env_eval, budget: float, *, horizon: int,
               seed: int) -> MCTSelector:
    """Warm an MCT selector on a seeded one-image-per-step train-split
    stream: explore for the first eighth of the horizon, then serve its
    own picks — every paid subset replayed into the gain regressors."""
    m = MCTSelector(env_eval, budget=budget, seed=seed)
    rng = np.random.default_rng(seed + 17)
    pool_train = env_eval.train_idx
    explore_until = max(16, horizon // 8)
    for step in range(horizon):
        img = int(pool_train[rng.integers(len(pool_train))])
        if step < explore_until or rng.random() < 0.1:
            mask = int(m.explore_masks([img], step=step)[0])
        else:
            mask = int(m.select_masks([img], step=step)[0])
        m.observe([img], [mask], step=step)
    return m


def run_scenario(name: str, *, horizon: int, n_images: int,
                 betas: Sequence[float], budgets: Sequence[float],
                 seed: int, log=print) -> Dict:
    providers = default_providers()
    schedule = build_scenario(name, providers, horizon=horizon, seed=seed)
    pool = DynamicProviderPool(providers, schedule, n_images=n_images,
                               seed=seed)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=seed + 1)

    out: Dict = {"scenario": name, "baselines": {}, "rl": [],
                 "cascade": [], "hybrid": [], "mct": []}
    out["baselines"]["cheapest"] = score_masks_fn(
        env, lambda imgs, step: np.full(len(imgs),
                                        _cheapest_mask(env, step)))
    out["baselines"]["all_providers"] = score_masks_fn(
        env, lambda imgs, step: np.full(len(imgs),
                                        (1 << env.n_providers) - 1))

    for beta in betas:
        rl = _rl_arm(pool, env, beta, seed=seed, log=log)
        out["rl"].append(rl["point"])

        cas = CascadeSelector(env, beta=beta)
        pt = score_masks_fn(
            env, lambda imgs, step: cas.select_masks(imgs, step=step),
            beta=beta)
        pt["knob"] = beta
        pt["calibration"] = dict(cas.calibration)
        out["cascade"].append(pt)

        hyb = HybridSelector(env, cascade=cas, rl_masks_fn=rl["masks_fn"])
        pt = score_masks_fn(
            env, lambda imgs, step: hyb.select_masks(imgs, step=step),
            beta=beta)
        pt["knob"] = beta
        pt["escalation"] = {
            seg: choice for seg, choice in sorted(hyb._seg_choice.items())}
        out["hybrid"].append(pt)

    for budget in budgets:
        m = _train_mct(env, budget, horizon=horizon, seed=seed)
        pt = score_masks_fn(
            env, lambda imgs, step: m.select_masks(imgs, step=step))
        pt["knob"] = budget
        pt["n_observed"] = m.n_observed
        out["mct"].append(pt)
    if log:
        base = out["baselines"]
        log(f"[frontier] {name}: cheapest ap50={base['cheapest']['ap50']} "
            f"all ap50={base['all_providers']['ap50']} "
            f"cost={base['all_providers']['cost']}")
    return out


def _mean_points(per_scenario: List[Dict], arm: str) -> List[Dict]:
    """Average each arm's k-th point across scenarios (same knob order)."""
    pts = []
    for k in range(len(per_scenario[0][arm])):
        rows = [s[arm][k] for s in per_scenario]
        pts.append({"knob": rows[0]["knob"],
                    "ap50": round(float(np.mean([r["ap50"] for r in rows])),
                                  2),
                    "cost": round(float(np.mean([r["cost"] for r in rows])),
                                  4),
                    "reward": round(float(np.mean([r["reward"]
                                                   for r in rows])), 4)})
    return pts


def _mean_baseline(per_scenario: List[Dict], which: str) -> Dict:
    rows = [s["baselines"][which] for s in per_scenario]
    return {"ap50": round(float(np.mean([r["ap50"] for r in rows])), 2),
            "cost": round(float(np.mean([r["cost"] for r in rows])), 4),
            "reward": round(float(np.mean([r["reward"] for r in rows])), 4)}


def run_frontier(*, scenarios: Sequence[str] = SCENARIOS,
                 horizon: int = 480, n_images: int = 96,
                 betas: Sequence[float] = BETAS,
                 budgets: Sequence[float] = BUDGETS,
                 seed: int = 0, log=print) -> Dict:
    """The full benchmark: every scenario, every arm, every knob.

    Returns the committed-baseline payload: per-scenario curves, the
    cross-scenario mean frontier, the gated dominance invariants (1.0 /
    0.0 flags plus their achieved margins), and the paper operating
    point (``cost_saving_frac`` = fee saved vs all-providers at matched
    accuracy)."""
    per_scenario = [run_scenario(s, horizon=horizon, n_images=n_images,
                                 betas=betas, budgets=budgets, seed=seed,
                                 log=log) for s in scenarios]
    frontier = {arm: _mean_points(per_scenario, arm)
                for arm in ("rl", "cascade", "hybrid", "mct")}
    cheapest = _mean_baseline(per_scenario, "cheapest")
    all_prov = _mean_baseline(per_scenario, "all_providers")

    rl = frontier["rl"]
    dom_cheap = [p for p in rl if p["ap50"] >= cheapest["ap50"] - EPS_AP
                 and p["cost"] <= cheapest["cost"] + EPS_COST]
    dom_all = [p for p in rl if p["ap50"] >= all_prov["ap50"] - EPS_AP
               and p["cost"] <= all_prov["cost"] + EPS_COST]
    hyb_margins = [h["reward"] - c["reward"] for h, c in
                   zip(frontier["hybrid"], frontier["cascade"])]
    invariants = {
        "rl_dominates_cheapest": 1.0 if dom_cheap else 0.0,
        "rl_dominates_all_providers": 1.0 if dom_all else 0.0,
        "hybrid_ge_cascade":
            1.0 if min(hyb_margins) >= -EPS_REWARD else 0.0,
        "hybrid_min_reward_margin": round(float(min(hyb_margins)), 4),
        "eps_ap": EPS_AP, "eps_cost": EPS_COST, "eps_reward": EPS_REWARD,
    }

    # paper operating point: cheapest RL point matching the all-providers
    # ensemble's accuracy (within eps) — the 67%-cost-saving claim's shape
    matched = dom_all or [max(rl, key=lambda p: p["ap50"])]
    best = min(matched, key=lambda p: p["cost"])
    paper_point = {
        "beta": best["knob"], "ap50": best["ap50"], "cost": best["cost"],
        "all_providers_ap50": all_prov["ap50"],
        "all_providers_cost": all_prov["cost"],
        "accuracy_matched": bool(dom_all),
        "cost_saving_frac": round(1.0 - best["cost"] /
                                  max(all_prov["cost"], 1e-9), 4),
    }
    result = {
        "config": {"scenarios": list(scenarios), "horizon": horizon,
                   "n_images": n_images, "betas": list(betas),
                   "budgets": list(budgets), "seed": seed},
        "baselines": {"cheapest": cheapest, "all_providers": all_prov},
        "frontier": frontier,
        "invariants": invariants,
        "paper_point": paper_point,
        "scenarios": {s["scenario"]: s for s in per_scenario},
    }
    if log:
        log(f"[frontier] invariants={invariants} "
            f"paper_point={paper_point}")
    return result
