"""Hybrid selector: a calibrated cascade fronting the RL policy.

Confident images (the cascade's cheap-first gate passes) are served by
the base provider alone; everything else escalates.  For the escalated
traffic the hybrid holds TWO candidate strategies — the cascade's
calibrated escalation subset, and the RL policy's per-image pick OR'd
with the base provider's bit (the base was already queried to score
confidence, so honest accounting keeps paying for it) — and, per
segment, serves whichever scores the better calibration-split reward
under that segment's pool.  This is the same validated-challenger
pattern ``run_online`` uses for policy snapshots: the RL arm is only
promoted where it demonstrably beats the static escalation, which is
what makes the frontier's ``hybrid >= cascade`` invariant hold by
construction up to train/test generalization noise.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core.loops import _make_batch_select
from repro.selection.base import SelectorPolicy
from repro.selection.cascade import CascadeSelector


class HybridSelector(SelectorPolicy):
    """Cascade gate in front of an RL agent.

    Parameters
    ----------
    env:         ``ArmolEnv`` / ``NonStationaryArmolEnv``.
    rl_agent:    the trained RL policy (anything ``_make_batch_select``
                 accepts).
    cascade:     a pre-calibrated :class:`CascadeSelector` to share (e.g.
                 with a pure-cascade arm, so both gates are identical);
                 built fresh from ``beta``/``threshold`` otherwise.
    rl_masks_fn: ``(img_indices, step) -> bitmasks`` — an explicit RL
                 decision function instead of ``rl_agent`` (the frontier
                 benchmark passes per-segment validated snapshots this
                 way).  With neither, escalated traffic always uses the
                 cascade's subset and the hybrid degenerates to it.
    validate:    score both escalation strategies on the calibration
                 split per segment and serve the winner (default).
                 ``False`` always trusts the RL arm on escalated traffic.
    """

    name = "hybrid"

    def __init__(self, env, rl_agent=None, *,
                 cascade: Optional[CascadeSelector] = None,
                 rl_masks_fn: Optional[Callable] = None,
                 beta: float = -0.05, threshold: Optional[float] = None,
                 validate: bool = True):
        super().__init__(env)
        self.cascade = cascade if cascade is not None else \
            CascadeSelector(env, beta=beta, threshold=threshold)
        self.rl_agent = rl_agent
        self.validate = bool(validate)
        if rl_masks_fn is not None:
            self._rl_fn: Optional[Callable] = rl_masks_fn
        elif rl_agent is not None:
            select = _make_batch_select(rl_agent, deterministic=True)
            self._rl_fn = lambda imgs, step: self._agent_masks(
                select, imgs, step)
            self._rl_fn.__name__ = "rl_agent_masks"
        else:
            self._rl_fn = None
        self._seg_choice: Dict[int, str] = {}   # seg -> "rl" | "cascade"

    def _agent_masks(self, select, img_indices, step) -> np.ndarray:
        idx = np.asarray(img_indices, np.int64)
        if getattr(self.env, "pool", None) is not None:
            s = int(self.env.clock if step is None else step)
            feats = self.env.features_at(s, idx)
        else:
            feats = self.env.features[idx]
        acts = np.asarray(select(np.asarray(feats, np.float32)))
        return ((acts > 0.5).astype(np.int64)
                << np.arange(self.n_providers)).sum(axis=1)

    def escalation_choice(self, *, step: Optional[int] = None) -> str:
        """``"rl"`` or ``"cascade"``: which escalation strategy serves
        the segment at ``step`` — decided once per segment by comparing
        mean calibration-split reward (at the cascade's beta) of the two
        candidates on the images the gate escalates."""
        pool = getattr(self.env, "pool", None)
        seg = 0 if pool is None else pool.schedule.segment_index(
            int(self.env.clock if step is None else step))
        if seg in self._seg_choice:
            return self._seg_choice[seg]
        if self._rl_fn is None:
            choice = "cascade"
        elif not self.validate:
            choice = "rl"
        else:
            calib = self.cascade.calib_imgs
            passes, b, esc = self.cascade.gate(calib, step=step)
            hard = calib[~passes]
            if len(hard) == 0:
                choice = "cascade"      # nothing escalates: moot
            else:
                rl = np.asarray(self._rl_fn(hard, step),
                                np.int64) | (1 << b)
                beta = self.cascade.beta
                r_rl = self._mean_reward(hard, rl, beta, step=step)
                r_cas = self._mean_reward(hard, np.full(len(hard), esc),
                                          beta, step=step)
                choice = "rl" if r_rl >= r_cas else "cascade"
        self._seg_choice[seg] = choice
        return choice

    def select_masks(self, img_indices: Sequence[int], *,
                     step: Optional[int] = None,
                     rl_masks: Optional[np.ndarray] = None) -> np.ndarray:
        """Route each image: base-only when confident, else the segment's
        validated escalation.  ``rl_masks`` (aligned with
        ``img_indices``) bypasses both the RL decision function and the
        per-segment validation — the raw-override path for tests."""
        passes, b, esc = self.cascade.gate(img_indices, step=step)
        if rl_masks is not None:
            escalated = np.asarray(rl_masks, np.int64) | (1 << b)
        elif self.escalation_choice(step=step) == "rl":
            escalated = np.asarray(self._rl_fn(img_indices, step),
                                   np.int64) | (1 << b)
        else:
            escalated = np.full(len(passes), esc, np.int64)
        return np.where(passes, 1 << b, escalated).astype(np.int64)
