"""FrugalMCT-style online per-request subset selection under a budget.

Each provider j gets a ridge regressor predicting its *marginal* AP50
gain for an image — ``ap(S) - ap(S \\ {j})`` — from cheap per-image
features (the env's base feature block plus a bias term).  At request
time the selector ranks active providers by predicted gain-per-fee and
adds them greedily while the summed fee fits the per-request budget;
when no provider clears ``min_gain`` it falls back to the cheapest
active one, so the returned subset is never empty (a soft floor of one
provider even when its fee exceeds the budget).

Training is free counterfactual replay: paying for a subset S yields
exact lattice rows for every sub-subset S' ⊆ S (``evaluate_lattice``),
so one observed request updates every provider of every S' with its
exact marginal gain — no estimator variance, no extra provider calls.
Cold start (no observations yet) predicts zero gain everywhere and
therefore serves the cheapest active provider.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.selection.base import SelectorPolicy


def submasks(mask: int):
    """All nonempty submasks of ``mask`` (standard bit trick)."""
    s = mask
    while s:
        yield s
        s = (s - 1) & mask


class MCTSelector(SelectorPolicy):
    """Online budgeted per-request selector (FrugalMCT-style).

    Parameters
    ----------
    env:      ``ArmolEnv`` / ``NonStationaryArmolEnv``.
    budget:   per-request fee budget in the traces' fee unit (mUSD for
              the bundled providers, where every fee is 1.0 — so
              ``budget=2.0`` admits up to two providers).
    ridge:    L2 regularizer of the per-provider gain regressors.
    min_gain: a provider is only added when its predicted marginal gain
              exceeds this (after the first, which may be the fallback).
    seed:     RNG seed for :meth:`explore_masks`.
    """

    name = "mct"

    def __init__(self, env, *, budget: float = 2.0, ridge: float = 1.0,
                 min_gain: float = 0.0, seed: int = 0):
        super().__init__(env)
        self.budget = float(budget)
        self.ridge = float(ridge)
        self.min_gain = float(min_gain)
        d = self._base_dim + 1
        self._A = np.zeros((self.n_providers, d, d))
        self._b = np.zeros((self.n_providers, d))
        self._w: Optional[np.ndarray] = None      # lazy (n, d) solve
        self._rng = np.random.default_rng(seed)
        self.n_observed = 0

    # -- features / regression --------------------------------------------
    def _x(self, img_indices: Sequence[int]) -> np.ndarray:
        base = np.asarray(self.env.features, np.float64)[
            np.asarray(img_indices, np.int64), :self._base_dim]
        return np.concatenate([base, np.ones((len(base), 1))], axis=1)

    def _weights(self) -> np.ndarray:
        if self._w is None:
            eye = self.ridge * np.eye(self._base_dim + 1)
            self._w = np.stack([np.linalg.solve(self._A[j] + eye, self._b[j])
                                for j in range(self.n_providers)])
        return self._w

    def predict_gains(self, img_indices: Sequence[int]) -> np.ndarray:
        """(B, N) predicted marginal AP50 gain per provider."""
        return self._x(img_indices) @ self._weights().T

    # -- online updates ----------------------------------------------------
    def observe(self, img_indices: Sequence[int], masks: Sequence[int], *,
                step: Optional[int] = None) -> int:
        """Replay paid subsets into the regressors; returns the number of
        (sub-subset, provider) training pairs absorbed.

        For each paid (image, mask) the lattice supplies exact AP50 for
        every sub-subset, so every provider j of every S' ⊆ mask trains
        on its exact marginal gain ``ap(S') - ap(S' \\ {j})``.
        """
        _, core, _, _, _ = self._resolve(step)
        against = getattr(self.env, "_against", "gt")
        X = self._x(img_indices)
        pairs = 0
        for x, img, mask in zip(X, img_indices, masks):
            mask = int(mask)
            if mask == 0:
                continue
            lat = core.evaluate_lattice(int(img), against=against)
            xxT = np.outer(x, x)
            for sub in submasks(mask):
                ap_s = lat.ap_of(sub)
                j = sub
                while j:
                    bit = j & -j
                    rest = sub ^ bit
                    gain = ap_s - (lat.ap_of(rest) if rest else 0.0)
                    p = bit.bit_length() - 1
                    self._A[p] += xxT
                    self._b[p] += gain * x
                    pairs += 1
                    j ^= bit
        if pairs:
            self._w = None
            self.n_observed += len(img_indices)
        return pairs

    def explore_masks(self, img_indices: Sequence[int], *,
                      step: Optional[int] = None) -> np.ndarray:
        """Random nonempty active subsets (seeded) for warm-up streams."""
        _, _, _, active, _ = self._resolve(step)
        idx = np.flatnonzero(active)
        if len(idx) == 0:
            idx = np.arange(self.n_providers)
        out = np.empty(len(img_indices), np.int64)
        for t in range(len(img_indices)):
            take = self._rng.random(len(idx)) < 0.5
            if not take.any():
                take[self._rng.integers(len(idx))] = True
            out[t] = int((1 << idx[take]).sum())
        return out

    # -- selection ---------------------------------------------------------
    def select_masks(self, img_indices: Sequence[int], *,
                     step: Optional[int] = None) -> np.ndarray:
        _, _, costs, active, _ = self._resolve(step)
        fees = np.asarray(costs, np.float64)
        gains = self.predict_gains(img_indices)
        act = np.flatnonzero(active)
        out = np.empty(len(img_indices), np.int64)
        for t in range(len(img_indices)):
            order = act[np.argsort(-(gains[t, act] / np.maximum(fees[act],
                                                                1e-12)))]
            mask, spent = 0, 0.0
            for j in order:
                if gains[t, j] <= self.min_gain:
                    break
                if spent + fees[j] > self.budget and mask != 0:
                    continue
                mask |= 1 << int(j)
                spent += fees[j]
            if mask == 0:       # cold start / nothing profitable
                mask = 1 << self._cheapest_active(fees, active)
            out[t] = mask
        return out
