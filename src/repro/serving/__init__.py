from repro.serving.engine import ServeEngine, Request  # noqa: F401
from repro.serving.federation_service import (  # noqa: F401
    FederationResult, FederationService)
from repro.serving.async_service import AsyncFederationService  # noqa: F401
from repro.serving.mp_shards import (  # noqa: F401
    ProcessShardedSubsetEvaluationCore, ShardWorkerError)
