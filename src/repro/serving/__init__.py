from repro.serving.engine import ServeEngine, Request  # noqa: F401
from repro.serving.federation_service import FederationService  # noqa: F401
