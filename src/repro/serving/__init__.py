"""Public serving API.

The serving plane in one import: the LM engine, the sync + async
federation services, the transport seam (``ShardTransport`` registry:
thread / process / socket planes), the client facade shared by
in-process and HTTP callers, and the HTTP front door.  Everything here
is covered by ``docs/serving.md``; anything not exported is internal.
"""
from repro.serving.async_service import AsyncFederationService
from repro.serving.client import (FederationClient, result_from_dict,
                                  result_to_dict)
from repro.serving.engine import Request, ServeEngine
from repro.serving.federation_service import (FederationResult,
                                              FederationService)
from repro.serving.http_front import (HttpFrontDoor, HttpServingClient,
                                      create_app)
from repro.serving.mp_shards import (ProcessShardedSubsetEvaluationCore,
                                     ShardWorkerError)
from repro.serving.socket_shards import SocketShardedSubsetEvaluationCore
from repro.serving.transports import (ProcessTransport, ShardTransport,
                                      SocketTransport, ThreadTransport,
                                      available_transports,
                                      get_transport, register_transport)

__all__ = [
    "ServeEngine", "Request",
    "FederationService", "FederationResult", "AsyncFederationService",
    "FederationClient", "result_to_dict", "result_from_dict",
    "HttpFrontDoor", "HttpServingClient", "create_app",
    "ShardTransport", "ThreadTransport", "ProcessTransport",
    "SocketTransport", "register_transport", "get_transport",
    "available_transports",
    "ProcessShardedSubsetEvaluationCore",
    "SocketShardedSubsetEvaluationCore", "ShardWorkerError",
]
