"""Async federation serving: micro-batching + sharded caches.

``FederationService.handle`` pays one jitted agent dispatch per request —
fine for a demo, hopeless under traffic.  ``AsyncFederationService``
turns the service into an open system:

  * **submit/handle** — clients (any number of threads) enqueue requests;
    each gets a ``concurrent.futures.Future`` of a ``FederationResult``.
  * **micro-batching** — a dispatcher thread coalesces queued requests
    and flushes when ``max_batch`` are waiting or the oldest has waited
    ``max_wait_ms``.  Each flush costs ONE batched agent forward (the
    whole point: the per-call jit dispatch overhead is amortized over the
    flush) and one batched IoU precompute per touched shard.
  * **sharded caches** — the subset-evaluation memo is split across W
    shared-nothing shards by ``img_idx % W``.  With the default
    ``shard_backend="thread"`` (``ShardedSubsetEvaluationCore``) each
    shard is owned by its own single-thread executor, so concurrent
    flushes never contend on one dict and no locks guard the hot lookup
    path — but ensemble assembly still serializes on the GIL.
    ``shard_backend="process"`` promotes the shards to worker processes
    (``ProcessShardedSubsetEvaluationCore``): same routing rule, same
    merge order, bit-identical results, with assembly running on real
    cores.  Accounting stays in the parent either way
    (``FederationService._route_batch``); only ensemble rows cross the
    process boundary.
  * **overlap** — the dispatcher hands each shard's slice of the flush to
    that shard's worker and immediately returns to batching: provider
    fan-out/ensemble assembly (the thread pool over the vectorized
    ``_account_batch``; provider "inference" is parallel-latency in the
    paper's model, Sec. II-B) overlaps the NEXT flush's agent forward.

At ``max_batch=1, workers=1`` every request is its own flush through the
same single-state ``select_action`` call ``handle`` makes, so results are
identical to the synchronous service (``tests/test_async_service.py``).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional, Sequence

import numpy as np

from repro.federation.env import ArmolEnv
from repro.federation.evaluation import ShardedSubsetEvaluationCore
from repro.serving.federation_service import (FederationResult,
                                              FederationService)


class AsyncFederationService:
    """Micro-batching front-end over ``FederationService``.

    Parameters
    ----------
    max_batch:    flush when this many requests are queued.
    max_wait_ms:  ... or when the oldest queued request is this old.
    workers:      cache shards == ensemble workers (threads or processes).
    shard_backend: ``"thread"`` (default — in-process shards, zero IPC)
                  or ``"process"`` (one worker process per shard, off the
                  GIL; results are bit-identical to the thread backend).
    mp_context:   multiprocessing start method for the process backend
                  (``"spawn"`` default — the parent runs jax, whose
                  runtime threads do not survive ``fork``).
    adaptive:     deadline-aware flush sizing — queue depth scales the
                  wait budget down (see ``_flush_deadline``).  Off by
                  default: fixed ``max_batch``/``max_wait_ms`` behavior
                  is bit-identical to the non-adaptive service.
    pool:         optional scenario provider pool; the service keeps a
                  scenario clock (one step per request) and accounts each
                  flush under the pool's segment at that clock — cores,
                  fees and latencies swap mid-stream at flush boundaries.

    Use as a context manager (or call ``close()``): a dispatcher thread
    and W worker threads run behind the scenes.
    """

    def __init__(self, env: ArmolEnv, agent, *, deterministic: bool = True,
                 transmission_ms: float = 20.0, max_batch: int = 16,
                 max_wait_ms: float = 2.0, workers: int = 2,
                 adaptive: bool = False, pool=None,
                 shard_backend: str = "thread",
                 mp_context: str = "spawn"):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if shard_backend not in ("thread", "process"):
            raise ValueError(f"shard_backend must be 'thread' or "
                             f"'process', got {shard_backend!r}")
        self.env = env
        self.agent = agent
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.workers = int(workers)
        self.adaptive = bool(adaptive)
        self.shard_backend = shard_backend
        # scenario pool (``repro.scenarios.pool.DynamicProviderPool`` or
        # anything with view_at/sharded_core_at/snapshot_at): each flush
        # is accounted under the pool state at the service's scenario
        # clock, which advances one step per request — mid-stream regime
        # swaps apply at flush boundaries, never inside one.  The thread
        # backend swaps the whole sharded core; the process backend keeps
        # ONE worker pool for the service's lifetime and ships each
        # segment across the boundary as a PoolSnapshot recipe.
        self.pool = pool
        self._scn_clock = 0
        if shard_backend == "process":
            from repro.serving.mp_shards import \
                ProcessShardedSubsetEvaluationCore
            if pool is not None:
                self.core = ProcessShardedSubsetEvaluationCore.for_pool(
                    pool, self.workers, mp_context=mp_context)
            else:
                self.core = ProcessShardedSubsetEvaluationCore.like(
                    env.core, self.workers, mp_context=mp_context)
        elif pool is not None:
            self.core = pool.sharded_core_at(0, self.workers)
        else:
            self.core = ShardedSubsetEvaluationCore.like(env.core, workers)
        self._svc = FederationService(env, agent,
                                      deterministic=deterministic,
                                      transmission_ms=transmission_ms)
        from repro.core.loops import agent_policy
        self._policy = agent_policy(agent, deterministic=deterministic)

        self._cv = threading.Condition()
        self._queue: deque = deque()    # (img_idx, enqueue_t, future)
        self._closed = False
        # flush_full/flush_timeout/flush_drain: WHY each flush fired —
        # queue hit max_batch, the oldest request's deadline expired, or
        # close() drained the queue.  Tests assert on these instead of
        # wall-clock sleeps (timer behavior without timing flakiness).
        self.stats = {"requests": 0, "flushes": 0, "batched_requests": 0,
                      "max_flush": 0, "flush_full": 0, "flush_timeout": 0,
                      "flush_drain": 0}
        self._shard_pools = [
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix=f"fed-shard-{i}")
            for i in range(self.workers)]
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="fed-dispatch", daemon=True)
        self._dispatcher.start()

    # -- client surface --------------------------------------------------
    def submit(self, img_idx: int) -> "Future[FederationResult]":
        """Enqueue one request; returns immediately.

        Args:  ``img_idx`` — trace image id (int()-able).
        Returns: a ``concurrent.futures.Future`` resolving to the
          request's :class:`FederationResult` once its flush is assembled
          (``.result()`` blocks; ``handle`` is the blocking shorthand).
        Failure modes: raises ``RuntimeError`` when the service is
          closed; a failed flush (dead shard worker, evaluation error)
          sets that exception on every future of the affected flush —
          the service itself keeps serving subsequent requests.
        """
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncFederationService is closed")
            self._queue.append((int(img_idx), time.monotonic(), fut))
            self._cv.notify()
        return fut

    def handle(self, img_idx: int) -> FederationResult:
        return self.submit(img_idx).result()

    def handle_many(self, img_indices: Sequence[int]
                    ) -> List[FederationResult]:
        futs = [self.submit(i) for i in img_indices]
        return [f.result() for f in futs]

    # -- dispatcher ------------------------------------------------------
    def _flush_deadline(self, enqueue_t: float, depth: int) -> float:
        """When the oldest queued request must flush.

        Fixed mode (default): enqueue time + ``max_wait_ms`` — unchanged
        seed behavior.  Adaptive mode scales the wait DOWN with queue
        depth (deadline-aware flush sizing): an empty queue waits the
        full budget hoping to coalesce, a queue at ``max_batch`` flushes
        immediately — under load the service stops holding requests
        hostage to the timer, near idle it still batches aggressively.
        """
        if not self.adaptive:
            return enqueue_t + self.max_wait_s
        frac = min(depth / self.max_batch, 1.0)
        return enqueue_t + self.max_wait_s * (1.0 - frac)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:     # closed and drained
                    return
                while len(self._queue) < self.max_batch and not self._closed:
                    deadline = self._flush_deadline(self._queue[0][1],
                                                    len(self._queue))
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                # why this flush fired — decided while the queue state is
                # still visible, counted with the other stats in _flush
                if len(self._queue) >= self.max_batch:
                    reason = "flush_full"
                elif self._closed:
                    reason = "flush_drain"
                else:
                    reason = "flush_timeout"
                batch = [self._queue.popleft()
                         for _ in range(min(self.max_batch,
                                            len(self._queue)))]
                clock = self._scn_clock
                if self.pool is not None:
                    self._scn_clock += len(batch)
            try:
                self._flush(batch, clock, reason)
            except BaseException as e:   # keep serving after a bad flush
                for _, _, fut in batch:
                    if not fut.done():
                        fut.set_exception(e)

    def _flush(self, batch, clock: int, reason: str = "flush_full") -> None:
        imgs = np.asarray([b[0] for b in batch], np.int64)
        costs = lats = None
        snapshot = None
        core = self.core
        if self.pool is not None:
            # one consistent (core, fee/latency) snapshot per flush:
            # in-flight assembly keeps its captured segment even if the
            # clock crosses a boundary while it overlaps the next flush
            view = self.pool.view_at(clock)
            costs, lats = view.costs, view.latencies
            if self.shard_backend == "process":
                # the worker pool persists across segments; the segment
                # itself rides along with each shard request as a recipe
                snapshot = self.pool.snapshot_at(clock)
            else:
                core = self.pool.sharded_core_at(clock, self.workers)
                self.core = core
        sel = getattr(self.agent, "select_for_images", None)
        if sel is not None:
            # selector policy: decide straight from the image indices —
            # no feature forward, no padding; the same call the sync
            # service makes, so both paths are bit-identical by
            # construction.  The flush clock pins the pool segment.
            if self.pool is not None:
                actions = np.asarray(sel(imgs, step=clock), np.float32)
            else:
                actions = np.asarray(sel(imgs), np.float32)
        elif len(batch) == 1:
            # same single-state act path as FederationService.handle, so
            # max_batch=1 is result-identical to the synchronous service
            a, _ = self.agent.select_action(
                self.env.features[imgs[0]],
                deterministic=self._svc.deterministic)
            actions = np.asarray(a, np.float32).reshape(1, -1)
        else:
            # pad the flush to max_batch so the batched forward is shape-
            # stable: one jit compile for the service's lifetime instead
            # of one per distinct queue depth (row-independent MLP heads
            # make the padding rows inert)
            feats = self.env.features[imgs]
            if len(batch) < self.max_batch:
                pad = np.broadcast_to(
                    feats[-1], (self.max_batch - len(batch),
                                feats.shape[1]))
                feats = np.concatenate([feats, pad], axis=0)
            actions = np.asarray(self._policy.select_batch(feats),
                                 np.float32)[:len(batch)]
        with self._cv:      # counters race with reset_stats() otherwise
            self.stats["flushes"] += 1
            self.stats[reason] += 1
            self.stats["requests"] += len(batch)
            if len(batch) > 1:
                self.stats["batched_requests"] += len(batch)
            self.stats["max_flush"] = max(self.stats["max_flush"],
                                          len(batch))
        # fan out by home shard; the dispatcher does NOT wait — ensemble
        # assembly overlaps the next flush's agent forward
        if self.shard_backend == "process":
            # routing/accounting math stays in the parent (one vectorized
            # pass); only (image, mask) rows cross the process boundary
            acts, n_sel, masks, cost, lat = self._svc._route_batch(
                imgs, actions, costs=costs, latency_ms=lats)
            for sid, positions in self._partition(imgs).items():
                self._shard_pools[sid].submit(
                    self._account_shard_mp, core, sid,
                    [batch[p] for p in positions], positions, snapshot,
                    acts, n_sel, masks, cost, lat)
        else:
            for sid, positions in self._partition(imgs).items():
                self._shard_pools[sid].submit(
                    self._account_shard, core, sid,
                    [batch[p] for p in positions], actions[positions],
                    costs, lats)

    def _partition(self, imgs: np.ndarray):
        groups: dict = {}
        for pos, img in enumerate(imgs):
            groups.setdefault(self.core.shard_id(img), []).append(pos)
        return groups

    def _account_shard(self, core, sid: int, items, actions: np.ndarray,
                       costs, lats) -> None:
        """Runs on shard ``sid``'s dedicated thread — the only thread that
        ever touches that shard's dicts (for the flush's captured core)."""
        try:
            shard = core.shards[sid]
            imgs = [it[0] for it in items]
            shard.precompute(imgs)      # one batched IoU launch per shard
            results = self._svc._account_batch(imgs, actions, core=shard,
                                               costs=costs,
                                               latency_ms=lats)
            for (_, _, fut), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, _, fut in items:
                if not fut.done():
                    fut.set_exception(e)

    def _account_shard_mp(self, core, sid: int, items, positions,
                          snapshot, acts, n_sel, masks, cost,
                          lat) -> None:
        """Process-backend twin of ``_account_shard``: runs on shard
        ``sid``'s parent-side thread, which owns that worker's pipe for
        the duration (one batched RPC per flush per shard).  Accounting
        was already routed in the dispatcher; only ensembles come back.
        A dead worker fails this shard's futures cleanly — other shards
        and the dispatcher keep serving."""
        try:
            imgs = [it[0] for it in items]
            ens = core.eval_on(sid, imgs, masks[positions], snapshot)
            results = self._svc._results_from_ensembles(
                acts[positions], n_sel[positions], cost[positions],
                lat[positions], ens)
            for (_, _, fut), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, _, fut in items:
                if not fut.done():
                    fut.set_exception(e)

    # -- cache invalidation ----------------------------------------------
    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Drop the images' cached artifacts EVERYWHERE this service
        could read them back: the live shard backend (all regimes on all
        worker processes for the process backend) and, when a pool is
        attached, every segment core the pool has materialized on the
        parent side.  This is the one invalidation entry point callers
        should use — invalidating only the pool (or only the core)
        leaves the other side serving stale ensembles."""
        dropped = 0
        if self.pool is not None:
            dropped += self.pool.invalidate_images(img_indices)
            if self.shard_backend == "thread":
                # the live sharded core is one of the pool's _sharded
                # entries, already swept above
                return dropped
        return dropped + self.core.invalidate_images(img_indices)

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        self._dispatcher.join()
        for pool in self._shard_pools:
            pool.shutdown(wait=True)
        if self.shard_backend == "process":
            self.core.close()       # reap the worker processes

    def __enter__(self) -> "AsyncFederationService":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None

    def mean_flush_size(self) -> float:
        return self.stats["requests"] / max(self.stats["flushes"], 1)

    # -- scenario clock --------------------------------------------------
    @property
    def clock(self) -> int:
        with self._cv:
            return self._scn_clock

    def set_clock(self, step: int) -> None:
        """Jump the scenario clock (e.g. to force a regime for tests or
        to sync with an external scheduler).  Takes effect at the next
        flush boundary; flushes already dispatched keep their snapshot."""
        with self._cv:
            self._scn_clock = int(step)

    def reset_stats(self) -> None:
        """Zero the flush counters (e.g. after warm-up traffic), so
        reported batching stats cover only the measured window."""
        with self._cv:
            for k in self.stats:
                self.stats[k] = 0
