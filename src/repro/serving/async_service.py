"""Async federation serving: micro-batching + sharded caches.

``FederationService.handle`` pays one jitted agent dispatch per request —
fine for a demo, hopeless under traffic.  ``AsyncFederationService``
turns the service into an open system:

  * **submit/handle** — clients (any number of threads) enqueue requests;
    each gets a ``concurrent.futures.Future`` of a ``FederationResult``.
  * **micro-batching** — a dispatcher thread coalesces queued requests
    and flushes when ``max_batch`` are waiting or the oldest has waited
    ``max_wait_ms``.  Each flush costs ONE batched agent forward (the
    whole point: the per-call jit dispatch overhead is amortized over the
    flush) and one batched IoU precompute per touched shard.
  * **sharded caches behind a transport** — the subset-evaluation memo
    is split across W shared-nothing shards, each owned by one
    dispatcher-side thread.  The evaluation plane is pluggable
    (``transport=``, resolved through ``repro.serving.transports``):
    ``"thread"`` (default, ``ShardedSubsetEvaluationCore`` — in-process,
    zero IPC, assembly serializes on the GIL), ``"process"``
    (``ProcessShardedSubsetEvaluationCore`` — one worker process per
    shard, off the GIL) or ``"socket"``
    (``SocketShardedSubsetEvaluationCore`` — H shard HOSTS over TCP with
    consistent-hash routing and health-checked requeue).  All planes
    answer bit-identical results.  Accounting stays in the parent either
    way (``FederationService._route_batch``); only ensemble rows cross
    the transport boundary.
  * **overlap** — the dispatcher hands each shard's slice of the flush to
    that shard's worker and immediately returns to batching: provider
    fan-out/ensemble assembly (the thread pool over the vectorized
    ``_account_batch``; provider "inference" is parallel-latency in the
    paper's model, Sec. II-B) overlaps the NEXT flush's agent forward.

At ``max_batch=1, workers=1`` every request is its own flush through the
same single-state ``select_action`` call ``handle`` makes, so results are
identical to the synchronous service (``tests/test_async_service.py``).
"""
from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.federation.env import ArmolEnv
from repro.obs.metrics import MetricsRegistry, merge_snapshots
from repro.obs.tracing import NULL_SPAN
from repro.serving.federation_service import (FederationResult,
                                              FederationService)
from repro.serving.transports import ShardTransport, get_transport

# the dict-shaped stats contract: key order and names are part of the
# public accessor (tests and benches read these directly)
_STAT_KEYS = ("requests", "flushes", "batched_requests", "max_flush",
              "flush_full", "flush_timeout", "flush_drain")


class AsyncFederationService:
    """Micro-batching front-end over ``FederationService``.

    Parameters
    ----------
    max_batch:    flush when this many requests are queued.
    max_wait_ms:  ... or when the oldest queued request is this old.
    workers:      cache shards == ensemble workers (threads, processes
                  or locally spawned hosts, per the transport).
    transport:    the evaluation plane — a registered name (``"thread"``
                  default: in-process shards, zero IPC; ``"process"``:
                  one worker process per shard, off the GIL;
                  ``"socket"``: H shard hosts over TCP with health-
                  checked requeue) or a prebuilt
                  :class:`~repro.serving.transports.ShardTransport`
                  instance.  All planes answer bit-identical results.
    transport_options: transport-specific knobs passed to the registry
                  build (the socket plane's ``hosts=["addr:port", ...]``
                  / health intervals).
    shard_backend: DEPRECATED alias of ``transport`` (names resolve
                  through the same registry); emits a
                  ``DeprecationWarning``.
    mp_context:   multiprocessing start method for the process/socket
                  planes (``"spawn"`` default — the parent runs jax,
                  whose runtime threads do not survive ``fork``).
    adaptive:     deadline-aware flush sizing — queue depth scales the
                  wait budget down (see ``_flush_deadline``).  Off by
                  default: fixed ``max_batch``/``max_wait_ms`` behavior
                  is bit-identical to the non-adaptive service.
    pool:         optional scenario provider pool; the service keeps a
                  scenario clock (one step per request) and accounts each
                  flush under the pool's segment at that clock — cores,
                  fees and latencies swap mid-stream at flush boundaries.

    Use as a context manager (or call ``close()``): a dispatcher thread
    and W worker threads run behind the scenes.
    """

    def __init__(self, env: ArmolEnv, agent, *, deterministic: bool = True,
                 transmission_ms: float = 20.0, max_batch: int = 16,
                 max_wait_ms: float = 2.0, workers: int = 2,
                 adaptive: bool = False, pool=None,
                 transport: Union[str, ShardTransport, None] = None,
                 transport_options: Optional[dict] = None,
                 shard_backend: Optional[str] = None,
                 mp_context: str = "spawn", obs=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if shard_backend is not None:
            # legacy string kwarg: same names, same registry, loud exit
            # path.  Kept strict — the old surface only ever accepted
            # these two values, so typos stay errors, not new planes.
            warnings.warn(
                "shard_backend= is deprecated; use transport="
                "'thread'|'process'|'socket' (or a ShardTransport "
                "instance) instead", DeprecationWarning, stacklevel=2)
            if shard_backend not in ("thread", "process"):
                raise ValueError(f"shard_backend must be 'thread' or "
                                 f"'process', got {shard_backend!r}")
            if transport is None:
                transport = shard_backend
        if transport is None:
            transport = "thread"
        self.env = env
        self.agent = agent
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.adaptive = bool(adaptive)
        # scenario pool (``repro.scenarios.pool.DynamicProviderPool`` or
        # anything with view_at/sharded_core_at/snapshot_at): each flush
        # is accounted under the pool state at the service's scenario
        # clock, which advances one step per request — mid-stream regime
        # swaps apply at flush boundaries, never inside one.  The inline
        # (thread) plane swaps the whole sharded core; RPC planes keep
        # ONE worker/host pool for the service's lifetime and ship each
        # segment across the boundary as a PoolSnapshot recipe.
        self.pool = pool
        self._scn_clock = 0
        if isinstance(transport, str):
            transport = get_transport(transport).build(
                env=env, pool=pool, workers=int(workers),
                mp_context=mp_context, options=transport_options)
        self.transport = transport
        self.core = transport.core
        # the transport decides the real shard count (joined socket
        # hosts may outnumber ``workers``); one parent-side accounting
        # thread per shard id
        self.workers = int(transport.n_shards)
        self.shard_backend = transport.name
        self._svc = FederationService(env, agent,
                                      deterministic=deterministic,
                                      transmission_ms=transmission_ms)
        from repro.core.loops import agent_policy
        self._policy = agent_policy(agent, deterministic=deterministic)

        self._cv = threading.Condition()
        self._queue: deque = deque()  # (img_idx, enqueue_t, future, trace)
        self._closed = False
        # observability: the service's flush counters live on a metrics
        # registry (the obs handle's when given — so serve-level metrics
        # land in its metrics.json — else a private always-on one, which
        # keeps the ``stats`` accessor live with obs off).  flush_full /
        # flush_timeout/flush_drain: WHY each flush fired — queue hit
        # max_batch, the oldest request's deadline expired, or close()
        # drained the queue.  Tests assert on these instead of
        # wall-clock sleeps (timer behavior without timing flakiness).
        self.obs = obs
        self._obs_on = obs is not None and obs.enabled
        self._metrics = obs.metrics if self._obs_on else MetricsRegistry()
        self._tracer = obs.tracer if self._obs_on else None
        if self._tracer is not None and not self._tracer.enabled:
            self._tracer = None
        self._svc.obs = obs
        self._stat = {k: (self._metrics.gauge("serving." + k)
                          if k == "max_flush"
                          else self._metrics.counter("serving." + k))
                      for k in _STAT_KEYS}
        if self._obs_on:
            self._h_flush_size = self._metrics.histogram(
                "serving.flush_size",
                bounds=tuple(float(b) for b in range(1, 65)))
            self._h_queue_wait = self._metrics.histogram(
                "serving.queue_wait_ms")
        # per-shard RPC latency histograms + condemned-shard counters
        # always land in the service's registry; worker-shipped spans
        # only when tracing is on (no-op for inline transports)
        self.transport.bind_obs(self._metrics, self._tracer)
        self._shard_pools = [
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix=f"fed-shard-{i}")
            for i in range(self.workers)]
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="fed-dispatch", daemon=True)
        self._dispatcher.start()

    # -- client surface --------------------------------------------------
    def submit(self, img_idx: int) -> "Future[FederationResult]":
        """Enqueue one request; returns immediately.

        Args:  ``img_idx`` — trace image id (int()-able).
        Returns: a ``concurrent.futures.Future`` resolving to the
          request's :class:`FederationResult` once its flush is assembled
          (``.result()`` blocks; ``handle`` is the blocking shorthand).
        Failure modes: raises ``RuntimeError`` when the service is
          closed; a failed flush (dead shard worker, evaluation error)
          sets that exception on every future of the affected flush —
          the service itself keeps serving subsequent requests.
        """
        fut: Future = Future()
        tid = self._tracer.sample_request() if self._tracer is not None \
            else None
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncFederationService is closed")
            self._queue.append((int(img_idx), time.monotonic(), fut, tid))
            self._cv.notify()
        if tid is not None:
            # the request span: enqueue -> future resolution (covers the
            # queue wait, the flush, the shard RPC and assembly)
            t_sub = time.monotonic()
            ts = time.time()
            img = int(img_idx)

            def _done(f, tid=tid, t_sub=t_sub, ts=ts, img=img):
                self._tracer.record({
                    "name": "request", "trace": tid, "span": tid,
                    "parent": None, "ts": ts,
                    "dur_ms": (time.monotonic() - t_sub) * 1e3,
                    "attrs": {"img": img,
                              "error": f.exception() is not None}})
            fut.add_done_callback(_done)
        return fut

    def handle(self, img_idx: int) -> FederationResult:
        return self.submit(img_idx).result()

    def handle_many(self, img_indices: Sequence[int]
                    ) -> List[FederationResult]:
        futs = [self.submit(i) for i in img_indices]
        return [f.result() for f in futs]

    # -- dispatcher ------------------------------------------------------
    def _flush_deadline(self, enqueue_t: float, depth: int) -> float:
        """When the oldest queued request must flush.

        Fixed mode (default): enqueue time + ``max_wait_ms`` — unchanged
        seed behavior.  Adaptive mode scales the wait DOWN with queue
        depth (deadline-aware flush sizing): an empty queue waits the
        full budget hoping to coalesce, a queue at ``max_batch`` flushes
        immediately — under load the service stops holding requests
        hostage to the timer, near idle it still batches aggressively.
        """
        if not self.adaptive:
            return enqueue_t + self.max_wait_s
        frac = min(depth / self.max_batch, 1.0)
        return enqueue_t + self.max_wait_s * (1.0 - frac)

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue:     # closed and drained
                    return
                while len(self._queue) < self.max_batch and not self._closed:
                    deadline = self._flush_deadline(self._queue[0][1],
                                                    len(self._queue))
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                # why this flush fired — decided while the queue state is
                # still visible, counted with the other stats in _flush
                if len(self._queue) >= self.max_batch:
                    reason = "flush_full"
                elif self._closed:
                    reason = "flush_drain"
                else:
                    reason = "flush_timeout"
                batch = [self._queue.popleft()
                         for _ in range(min(self.max_batch,
                                            len(self._queue)))]
                clock = self._scn_clock
                if self.pool is not None:
                    self._scn_clock += len(batch)
            try:
                self._flush(batch, clock, reason)
            except BaseException as e:   # keep serving after a bad flush
                for _, _, fut, _ in batch:
                    if not fut.done():
                        fut.set_exception(e)

    def _flush(self, batch, clock: int, reason: str = "flush_full") -> None:
        t0 = time.monotonic() if self._tracer is not None else 0.0
        imgs = np.asarray([b[0] for b in batch], np.int64)
        costs = lats = None
        snapshot = None
        core = self.core
        if self.pool is not None:
            # one consistent (core, fee/latency) snapshot per flush:
            # in-flight assembly keeps its captured segment even if the
            # clock crosses a boundary while it overlaps the next flush
            view = self.pool.view_at(clock)
            costs, lats = view.costs, view.latencies
            if not self.transport.inline:
                # the worker/host pool persists across segments; the
                # segment rides along with each shard request as a recipe
                snapshot = self.pool.snapshot_at(clock)
            else:
                core = self.transport.core_at(clock)
                self.core = core
        sel = getattr(self.agent, "select_for_images", None)
        if sel is not None:
            # selector policy: decide straight from the image indices —
            # no feature forward, no padding; the same call the sync
            # service makes, so both paths are bit-identical by
            # construction.  The flush clock pins the pool segment.
            if self.pool is not None:
                actions = np.asarray(sel(imgs, step=clock), np.float32)
            else:
                actions = np.asarray(sel(imgs), np.float32)
        elif len(batch) == 1:
            # same single-state act path as FederationService.handle, so
            # max_batch=1 is result-identical to the synchronous service
            a, _ = self.agent.select_action(
                self.env.features[imgs[0]],
                deterministic=self._svc.deterministic)
            actions = np.asarray(a, np.float32).reshape(1, -1)
        else:
            # pad the flush to max_batch so the batched forward is shape-
            # stable: one jit compile for the service's lifetime instead
            # of one per distinct queue depth (row-independent MLP heads
            # make the padding rows inert)
            feats = self.env.features[imgs]
            if len(batch) < self.max_batch:
                pad = np.broadcast_to(
                    feats[-1], (self.max_batch - len(batch),
                                feats.shape[1]))
                feats = np.concatenate([feats, pad], axis=0)
            actions = np.asarray(self._policy.select_batch(feats),
                                 np.float32)[:len(batch)]
        with self._cv:      # counters race with reset_stats() otherwise
            self._stat["flushes"].inc()
            self._stat[reason].inc()
            self._stat["requests"].inc(len(batch))
            if len(batch) > 1:
                self._stat["batched_requests"].inc(len(batch))
            self._stat["max_flush"].set_max(len(batch))
        if self._obs_on:
            now = time.monotonic()
            self._h_flush_size.observe(len(batch))
            self._h_queue_wait.observe_batch(
                [(now - b[1]) * 1e3 for b in batch])
        # span + log context for the fan-out: the flush span hangs off
        # the first sampled request of the batch (reason, size, clock);
        # the serving log gets the flush's segment/reason.  Both are
        # None-cheap when obs is off.
        trace_ctx = None
        if self._tracer is not None:
            tids = [b[3] for b in batch if b[3] is not None]
            if tids:
                # the flush span covers the agent decision + routing; the
                # per-shard RPC/assembly hangs off it as child spans
                dur_ms = (time.monotonic() - t0) * 1e3
                span_id = self._tracer._next_span_id()
                self._tracer.record({
                    "name": "flush", "trace": tids[0], "span": span_id,
                    "parent": tids[0], "ts": time.time() - dur_ms / 1e3,
                    "dur_ms": dur_ms,
                    "attrs": {"reason": reason, "size": len(batch),
                              "clock": int(clock),
                              "n_traced": len(tids)}})
                trace_ctx = (tids[0], span_id)
        log_ctx = None
        if self.obs is not None and self.obs.serving_log is not None:
            seg = None if self.pool is None else \
                int(self.pool.schedule.segment_index(clock))
            log_ctx = {"seg": seg, "clock": int(clock), "reason": reason,
                       "backend": self.shard_backend, "costs": costs}
        # fan out by home shard; the dispatcher does NOT wait — ensemble
        # assembly overlaps the next flush's agent forward
        if not self.transport.inline:
            # routing/accounting math stays in the parent (one vectorized
            # pass); only (image, mask) rows cross the transport boundary
            acts, n_sel, masks, cost, lat = self._svc._route_batch(
                imgs, actions, costs=costs, latency_ms=lats)
            for sid, positions in self._partition(imgs).items():
                self._shard_pools[sid].submit(
                    self._account_shard_mp, sid,
                    [batch[p] for p in positions], positions, snapshot,
                    acts, n_sel, masks, cost, lat, trace_ctx, log_ctx)
        else:
            for sid, positions in self._partition(imgs).items():
                self._shard_pools[sid].submit(
                    self._account_shard, core, sid,
                    [batch[p] for p in positions], actions[positions],
                    costs, lats, trace_ctx, log_ctx)

    def _partition(self, imgs: np.ndarray):
        groups: dict = {}
        route = (self.core.shard_id if self.transport.inline
                 else self.transport.route)
        for pos, img in enumerate(imgs):
            groups.setdefault(route(int(img)), []).append(pos)
        return groups

    def _trace_parent(self, trace_ctx):
        """The (trace_id, parent_span_id) a shard-side span hangs off —
        ``(None, None)`` when this flush carries no sampled request."""
        if self._tracer is None or trace_ctx is None:
            return None, None
        return trace_ctx

    def _account_shard(self, core, sid: int, items, actions: np.ndarray,
                       costs, lats, trace_ctx=None, log_ctx=None) -> None:
        """Runs on shard ``sid``'s dedicated thread — the only thread that
        ever touches that shard's dicts (for the flush's captured core)."""
        tid, parent = self._trace_parent(trace_ctx)
        try:
            with self._tracer.span("shard_assemble", tid, parent=parent,
                                   shard=sid, n=len(items)) \
                    if tid is not None else NULL_SPAN:
                shard = core.shards[sid]
                imgs = [it[0] for it in items]
                shard.precompute(imgs)  # one batched IoU launch per shard
                results = self._svc._account_batch(
                    imgs, actions, core=shard, costs=costs,
                    latency_ms=lats, log_ctx=log_ctx)
            for (_, _, fut, _), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, _, fut, _ in items:
                if not fut.done():
                    fut.set_exception(e)

    def _account_shard_mp(self, sid: int, items, positions,
                          snapshot, acts, n_sel, masks, cost, lat,
                          trace_ctx=None, log_ctx=None) -> None:
        """RPC twin of ``_account_shard``: runs on shard ``sid``'s
        parent-side thread, which owns that worker/host connection for
        the duration (one batched RPC per flush per shard).  Accounting
        was already routed in the dispatcher; only ensembles come back.
        A dead worker fails this shard's futures cleanly (the socket
        plane first requeues to surviving hosts) — other shards and the
        dispatcher keep serving."""
        tid, parent = self._trace_parent(trace_ctx)
        try:
            span = (self._tracer.span("shard_assemble", tid, parent=parent,
                                      shard=sid, n=len(items))
                    if tid is not None else NULL_SPAN)
            with span:
                imgs = [it[0] for it in items]
                shard_masks = masks[positions]
                # the worker's eval span hangs off THIS assemble span, so
                # the assembled trace reads request -> flush ->
                # shard_assemble -> worker_eval
                wire = (self._tracer.wire_context(span)
                        if tid is not None else None)
                ens = self.transport.eval_batch(sid, imgs, shard_masks,
                                                snapshot, trace=wire)
                results = self._svc._results_from_ensembles(
                    acts[positions], n_sel[positions], cost[positions],
                    lat[positions], ens)
                if log_ctx is not None:
                    # the process plane never reaches _account_batch, so
                    # the serving log is fed here (same record shape)
                    self._svc._log_serving(
                        imgs, [int(m) for m in shard_masks],
                        log_ctx.get("costs"), results, log_ctx)
            for (_, _, fut, _), res in zip(items, results):
                fut.set_result(res)
        except BaseException as e:
            for _, _, fut, _ in items:
                if not fut.done():
                    fut.set_exception(e)

    # -- cache invalidation ----------------------------------------------
    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Drop the images' cached artifacts EVERYWHERE this service
        could read them back: the live shard backend (all regimes on all
        worker processes for the process backend) and, when a pool is
        attached, every segment core the pool has materialized on the
        parent side.  This is the one invalidation entry point callers
        should use — invalidating only the pool (or only the core)
        leaves the other side serving stale ensembles."""
        dropped = 0
        if self.pool is not None:
            dropped += self.pool.invalidate_images(img_indices)
            if self.transport.inline:
                # the live sharded core is one of the pool's _sharded
                # entries, already swept above
                return dropped
        return dropped + self.transport.invalidate(img_indices)

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        self._dispatcher.join()
        for pool in self._shard_pools:
            pool.shutdown(wait=True)
        self.transport.close()      # reap workers/hosts (inline: no-op)

    def __enter__(self) -> "AsyncFederationService":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None

    def mean_flush_size(self) -> float:
        return self.stats["requests"] / max(self.stats["flushes"], 1)

    # -- scenario clock --------------------------------------------------
    @property
    def clock(self) -> int:
        with self._cv:
            return self._scn_clock

    def set_clock(self, step: int) -> None:
        """Jump the scenario clock (e.g. to force a regime for tests or
        to sync with an external scheduler).  Takes effect at the next
        flush boundary; flushes already dispatched keep their snapshot."""
        with self._cv:
            self._scn_clock = int(step)

    # -- observability accessors ------------------------------------------
    @property
    def stats(self) -> dict:
        """The dict-shaped flush-counter accessor (key order is part of
        the contract): live values read off the metrics registry."""
        return {k: int(m.value) for k, m in self._stat.items()}

    def reset_stats(self) -> None:
        """Zero the flush counters (e.g. after warm-up traffic), so
        reported batching stats cover only the measured window."""
        with self._cv:     # same guard the counters increment under
            self._metrics.reset(prefix="serving.")

    def extra_metric_snapshots(self) -> list:
        """Shard-side snapshots NOT already in the service's registry:
        each worker/host registry shipped back over the transport (RPC
        planes) or the sharded core's hit/miss counters (inline).  Feed
        these to ``Obs.write_metrics`` — the obs registry itself is the
        service's registry, so only these extras need merging in."""
        return [self.transport.snapshot()]

    def metrics_snapshot(self, include_workers: bool = True) -> dict:
        """One merged counters/gauges/histograms snapshot for this
        service: its registry plus each shard's side of the story
        (worker/host registries over RPC, the sharded core's hit/miss
        counters inline).  Plain dicts, mergeable with
        :func:`repro.obs.merge_snapshots`."""
        snaps = [self._metrics.snapshot()]
        if include_workers:
            snaps.extend(self.extra_metric_snapshots())
        return merge_snapshots(*snaps)
