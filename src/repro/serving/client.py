"""The client-facing serving surface: one facade, two wire forms.

:class:`FederationClient` is the documented way to talk to a federation
service — in-process callers wrap the service object, HTTP callers sit
behind the same five calls via ``repro.serving.http_front`` (the routes
are a thin adapter over this facade, so both paths stay in lockstep):

    ``submit(img)``            -> Future[FederationResult]
    ``handle(img)``            -> FederationResult (blocking)
    ``handle_many(imgs)``      -> List[FederationResult]
    ``invalidate_images(imgs)``-> int entries dropped
    ``stats``                  -> dict (flush counters / request totals)

The facade accepts either service flavor: the micro-batching
``AsyncFederationService`` (requests coalesce into flushes) or the
synchronous ``FederationService`` (each ``submit`` is served inline and
returned as an already-resolved future — same types, degenerate
batching), so callers and tests can swap services without touching call
sites.

:func:`result_to_dict` / :func:`result_from_dict` define the JSON body
of a served result — the HTTP door's response schema.
"""
from __future__ import annotations

from concurrent.futures import Future
from typing import Dict, List, Sequence

import numpy as np

from repro.ensemble.boxes import Detections
from repro.serving.federation_service import (FederationResult,
                                              FederationService)


def result_to_dict(res: FederationResult) -> Dict[str, object]:
    """JSON-safe view of one ``FederationResult`` (the HTTP response
    body).  Arrays become nested lists; the detections keep their
    box/score/label/provider columns."""
    det = res.detections
    return {"action": [int(a) for a in np.asarray(res.action).ravel()],
            "cost_milli_usd": float(res.cost_milli_usd),
            "latency_ms": float(res.latency_ms),
            "detections": {
                "boxes": np.asarray(det.boxes, np.float64).tolist(),
                "scores": np.asarray(det.scores, np.float64).tolist(),
                "labels": np.asarray(det.labels, np.int64).tolist(),
                "providers": np.asarray(det.providers,
                                        np.int64).tolist()}}


def result_from_dict(d: Dict[str, object]) -> FederationResult:
    """Rebuild a ``FederationResult`` from :func:`result_to_dict` output
    (the HTTP client's side of the contract)."""
    det = d["detections"]
    boxes = np.asarray(det["boxes"], np.float64).reshape(-1, 4)
    return FederationResult(
        detections=Detections.fast(
            boxes, np.asarray(det["scores"], np.float64),
            np.asarray(det["labels"], np.int64),
            np.asarray(det["providers"], np.int64)),
        action=np.asarray(d["action"], np.float32),
        cost_milli_usd=float(d["cost_milli_usd"]),
        latency_ms=float(d["latency_ms"]))


class FederationClient:
    """Uniform client handle over a federation service.

    Parameters
    ----------
    service: an ``AsyncFederationService`` (futures resolve when the
        request's flush assembles) or a ``FederationService`` (each
        submit is served inline; the returned future is already done).

    The facade never owns the service's lifecycle unless asked:
    ``close()`` closes the underlying service only when constructed with
    ``own_service=True`` (the HTTP door uses this to tie service
    shutdown to server shutdown).
    """

    def __init__(self, service, *, own_service: bool = False):
        self._svc = service
        self._own = bool(own_service)
        self._async = hasattr(service, "submit")

    @property
    def service(self):
        return self._svc

    # -- the five-call surface -------------------------------------------
    def submit(self, img_idx: int) -> "Future[FederationResult]":
        """Enqueue one request; returns a future of its result.  On the
        sync service the work happens here and the future arrives
        resolved (or failed) — same observable types either way."""
        if self._async:
            return self._svc.submit(int(img_idx))
        fut: Future = Future()
        try:
            fut.set_result(self._svc.handle(int(img_idx)))
        except BaseException as e:
            fut.set_exception(e)
        return fut

    def handle(self, img_idx: int) -> FederationResult:
        return self.submit(img_idx).result()

    def handle_many(self, img_indices: Sequence[int]
                    ) -> List[FederationResult]:
        futs = [self.submit(i) for i in img_indices]
        return [f.result() for f in futs]

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        return int(self._svc.invalidate_images(
            [int(i) for i in img_indices]))

    @property
    def stats(self) -> Dict[str, int]:
        svc = self._svc
        if isinstance(svc, FederationService):
            # the sync service keeps no flush counters; present the
            # same keys with the degenerate truth (1 request = 1 flush)
            return {}
        return dict(svc.stats)

    # -- passthroughs the HTTP door needs ---------------------------------
    def metrics_snapshot(self) -> dict:
        fn = getattr(self._svc, "metrics_snapshot", None)
        return {} if fn is None else fn()

    def condemned(self) -> List[int]:
        tr = getattr(self._svc, "transport", None)
        return [] if tr is None else list(tr.condemned)

    def close(self) -> None:
        if self._own:
            close = getattr(self._svc, "close", None)
            if close is not None:
                close()
