"""Batched serving engine: static-batch prefill + decode over a model zoo
backend.  Requests are padded to a common prompt length, prefilled once,
then decoded greedily (or by temperature sampling) to their per-request
stop length with a shared KV cache — the provider-side serving loop that a
federation sits on top of.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model


@dataclass
class Request:
    prompt_tokens: np.ndarray            # (L,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    rid: int = 0


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray
    latency_s: float


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None, *, dtype=jnp.float32,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg, dtype=dtype)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(seed))
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len))
        self._decode = jax.jit(self.model.decode_step)

    def _pad_batch(self, requests: List[Request]):
        L = max(len(r.prompt_tokens) for r in requests)
        toks = np.zeros((len(requests), L), np.int32)
        for i, r in enumerate(requests):
            toks[i, L - len(r.prompt_tokens):] = r.prompt_tokens  # left-pad
        return toks

    def serve(self, requests: List[Request], *, seed: int = 0,
              extra_inputs: Optional[dict] = None) -> List[Completion]:
        t0 = time.time()
        toks = self._pad_batch(requests)
        batch = {"tokens": jnp.asarray(toks)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        if self.cfg.family == "vlm" and "image_embeds" not in batch:
            batch["image_embeds"] = jnp.zeros(
                (len(requests), self.cfg.num_image_tokens,
                 self.cfg.d_vision), jnp.float32)
        if self.cfg.family == "audio" and "audio_frames" not in batch:
            batch["audio_frames"] = jnp.zeros(
                (len(requests), self.cfg.num_audio_frames,
                 self.cfg.d_model), jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        max_new = max(r.max_new_tokens for r in requests)
        out = np.zeros((len(requests), max_new), np.int32)
        cur = self._sample(logits, requests, key)
        for t in range(max_new):
            out[:, t] = np.asarray(cur)
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur)[:, None])
            key, sub = jax.random.split(key)
            cur = self._sample(logits, requests, sub)
        dt = time.time() - t0
        return [Completion(r.rid, out[i, :r.max_new_tokens], dt)
                for i, r in enumerate(requests)]

    def _sample(self, logits, requests, key):
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests])
        if float(jnp.max(temps)) == 0.0:
            return greedy
        noisy = jax.random.categorical(key, logits / jnp.maximum(
            temps[:, None], 1e-6))
        return jnp.where(temps > 0, noisy.astype(jnp.int32), greedy)
