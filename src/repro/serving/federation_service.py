"""Federation service: the deployable form of Armol.

Wires the trained RL selector onto a pool of provider endpoints.  In
production each endpoint is a ServeEngine (or a remote MLaaS); here the
providers come from the trace substrate, so the service demonstrates the
full path: image -> features -> SAC proto action -> tau -> fan-out to the
selected providers -> word grouping -> ensemble -> final detections,
with per-request cost/latency accounting (inference latency is the max
over selected providers + per-provider transmission, Sec. II-B).

``handle_many`` is the batch path for heavy traffic: ONE agent forward
pass over all request features, one batched IoU precompute, then per-
request assembly from the memoized subset-evaluation core — repeat images
and repeat (image, subset) pairs cost a dict lookup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.env import ArmolEnv


@dataclass
class FederationResult:
    detections: Detections
    action: np.ndarray
    cost_milli_usd: float
    latency_ms: float


class FederationService:
    def __init__(self, env: ArmolEnv, agent, *, deterministic: bool = True,
                 transmission_ms: float = 20.0):
        self.env = env
        self.agent = agent
        self.deterministic = deterministic
        self.transmission_ms = transmission_ms

    def _account(self, img_idx: int,
                 action: np.ndarray) -> FederationResult:
        """Ensemble + cost/latency bookkeeping for one routed request."""
        sel = np.where(action > 0.5)[0]
        ens = self.env.core.ensemble(img_idx,
                                     self.env.core.mask_of(action))
        cost = float(np.sum(self.env.costs[sel]))
        # transmission is sequential over selected providers; inference is
        # parallel -> max latency (paper Sec. II-B)
        lats = [self.env.traces.providers[i].latency_ms for i in sel]
        latency = self.transmission_ms * len(sel) + (max(lats) if lats
                                                     else 0.0)
        return FederationResult(ens, action, cost, latency)

    def handle(self, img_idx: int) -> FederationResult:
        s = self.env.features[img_idx]
        a, _ = self.agent.select_action(s, deterministic=self.deterministic)
        return self._account(img_idx, np.asarray(a))

    def handle_many(self, img_indices: Sequence[int]
                    ) -> List[FederationResult]:
        imgs = [int(i) for i in img_indices]
        if not imgs:
            return []
        from repro.core.loops import agent_policy
        policy = agent_policy(self.agent, deterministic=self.deterministic)
        actions = policy.select_batch(self.env.features[np.asarray(imgs)])
        self.env.core.precompute(imgs)
        return [self._account(img, np.asarray(a))
                for img, a in zip(imgs, actions)]
