"""Federation service: the deployable form of Armol.

Wires the trained RL selector onto a pool of provider endpoints.  In
production each endpoint is a ServeEngine (or a remote MLaaS); here the
providers come from the trace substrate, so the service demonstrates the
full path: image -> features -> SAC proto action -> tau -> fan-out to the
selected providers -> word grouping -> ensemble -> final detections,
with per-request cost/latency accounting (inference latency is the max
over selected providers + per-provider transmission, Sec. II-B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.ensemble.boxes import Detections
from repro.ensemble.pipeline import ensemble_detections
from repro.federation.env import ArmolEnv


@dataclass
class FederationResult:
    detections: Detections
    action: np.ndarray
    cost_milli_usd: float
    latency_ms: float


class FederationService:
    def __init__(self, env: ArmolEnv, agent, *, deterministic: bool = True,
                 transmission_ms: float = 20.0):
        self.env = env
        self.agent = agent
        self.deterministic = deterministic
        self.transmission_ms = transmission_ms

    def handle(self, img_idx: int) -> FederationResult:
        s = self.env.features[img_idx]
        a, _ = self.agent.select_action(s, deterministic=self.deterministic)
        sel = np.where(a > 0.5)[0]
        dets = [self.env.traces.dets[img_idx][i] for i in sel]
        ens = ensemble_detections(dets, voting=self.env.voting,
                                  ablation=self.env.ablation) if dets else \
            Detections.empty()
        cost = float(np.sum(self.env.costs[sel]))
        # transmission is sequential over selected providers; inference is
        # parallel -> max latency (paper Sec. II-B)
        lats = [self.env.traces.providers[i].latency_ms for i in sel]
        latency = self.transmission_ms * len(sel) + (max(lats) if lats
                                                     else 0.0)
        return FederationResult(ens, a, cost, latency)

    def handle_many(self, img_indices) -> List[FederationResult]:
        return [self.handle(int(i)) for i in img_indices]
