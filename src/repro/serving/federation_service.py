"""Federation service: the deployable form of Armol.

Wires the trained RL selector onto a pool of provider endpoints.  In
production each endpoint is a ServeEngine (or a remote MLaaS); here the
providers come from the trace substrate, so the service demonstrates the
full path: image -> features -> SAC proto action -> tau -> fan-out to the
selected providers -> word grouping -> ensemble -> final detections,
with per-request cost/latency accounting (inference latency is the max
over selected providers + per-provider transmission, Sec. II-B).

``handle_many`` is the batch path for heavy traffic: ONE agent forward
pass over all request features, one batched IoU precompute, then per-
request assembly from the memoized subset-evaluation core — repeat images
and repeat (image, subset) pairs cost a dict lookup.  Cost/latency
accounting is vectorized over the whole flush (``_account_batch``); the
empty selection returns an explicit zero-cost/zero-latency result.

``repro.serving.async_service.AsyncFederationService`` layers a
micro-batching queue and a sharded cache on top of this service for
concurrent clients.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.env import ArmolEnv


@dataclass
class FederationResult:
    detections: Detections
    action: np.ndarray
    cost_milli_usd: float
    latency_ms: float


class FederationService:
    def __init__(self, env: ArmolEnv, agent, *, deterministic: bool = True,
                 transmission_ms: float = 20.0, obs=None):
        self.env = env
        self.agent = agent
        self.deterministic = deterministic
        self.transmission_ms = transmission_ms
        # optional repro.obs.Obs handle: when its serving log is open,
        # every accounting path appends one structured record per
        # request (the off-policy-evaluation input).  Results are
        # bit-identical with or without it — logging only copies values.
        self.obs = obs
        self.provider_latency_ms = np.asarray(
            [p.latency_ms for p in env.traces.providers], np.float64)
        self._mask_weights = np.left_shift(
            np.int64(1), np.arange(env.n_providers, dtype=np.int64))

    def _route_batch(self, imgs: Sequence[int], actions: np.ndarray,
                     *, costs: Optional[np.ndarray] = None,
                     latency_ms: Optional[np.ndarray] = None):
        """One numpy pass over a flush: every request's binary action,
        selection count, subset mask, summed fee, and modeled latency
        (transmission is sequential over selected providers; inference is
        parallel -> max latency, paper Sec. II-B).  ``costs`` /
        ``latency_ms`` override the static per-provider fee/latency
        vectors for one flush; a scenario pool swap passes the current
        segment's vectors (a down provider bills 0 and, if selected,
        costs its timeout latency).

        This is the shard-merge contract shared by both shard backends:
        routing/accounting math happens here (parent side, vectorized),
        only the ensemble lookups go to a shard — a thread's dict or a
        worker process's pipe.
        """
        costs = self.env.costs if costs is None else \
            np.asarray(costs, np.float32)
        lat_v = self.provider_latency_ms if latency_ms is None else \
            np.asarray(latency_ms, np.float64)
        acts = np.asarray(actions, np.float32).reshape(
            len(imgs), self.env.n_providers)
        sel = acts > 0.5
        n_sel = sel.sum(axis=1)
        masks = (sel * self._mask_weights).sum(axis=1)
        cost = np.where(sel, costs, np.float32(0.0)).sum(axis=1)
        inf_lat = np.max(np.where(sel, lat_v, -np.inf), axis=1)
        latency = np.where(n_sel > 0,
                           self.transmission_ms * n_sel + inf_lat, 0.0)
        return acts, n_sel, masks, cost, latency

    def _results_from_ensembles(self, acts: np.ndarray, n_sel: np.ndarray,
                                cost: np.ndarray, latency: np.ndarray,
                                ensembles: Sequence[Detections]
                                ) -> List[FederationResult]:
        """Assemble FederationResults from routed accounting + per-request
        ensembles (memo lookups or worker-process rows — identical merge
        either way).  The empty selection keeps its explicit zero-cost /
        zero-latency route."""
        out = []
        for t, ens in enumerate(ensembles):
            if n_sel[t] == 0:
                # explicit empty route: nothing selected, nothing billed
                out.append(FederationResult(Detections.empty(), acts[t],
                                            0.0, 0.0))
                continue
            out.append(FederationResult(ens, acts[t], float(cost[t]),
                                        float(latency[t])))
        return out

    def _log_serving(self, imgs: Sequence[int], masks: Sequence[int],
                     costs_vec, results: List[FederationResult],
                     log_ctx: Optional[dict] = None, core=None) -> None:
        """Append one serving-log record per request (both accounting
        paths funnel here: ``_account_batch`` for the sync/thread plane,
        ``_account_shard_mp`` for the process plane).

        When the flush was accounted on an in-process ``core``, AP50 is
        read off that core's memo/lattice (a dict or table hit on the
        warm path) instead of rescored inside the log; the process plane
        passes no core and the log scores against its own gts memo.
        """
        obs = self.obs
        if obs is None or obs.serving_log is None:
            return
        ctx = log_ctx or {}
        aps = None
        if core is not None and obs.serving_log.gts is not None:
            aps = [core.ap50(int(i), int(m)) if m else 0.0
                   for i, m in zip(imgs, masks)]
        obs.serving_log.log_flush(
            imgs, masks,
            self.env.costs if costs_vec is None else costs_vec, results,
            seg=ctx.get("seg"), clock=ctx.get("clock"),
            reason=ctx.get("reason"),
            backend=ctx.get("backend", "sync"), aps=aps)

    def _account_batch(self, imgs: Sequence[int], actions: np.ndarray,
                       *, core=None, costs: Optional[np.ndarray] = None,
                       latency_ms: Optional[np.ndarray] = None,
                       log_ctx: Optional[dict] = None
                       ) -> List[FederationResult]:
        """Vectorized ensemble + cost/latency bookkeeping for one flush.

        ``core`` defaults to the env's shared cache — the async service
        passes the request's home shard instead; only the memoized
        ensemble lookups remain per-request.
        """
        core = self.env.core if core is None else core
        acts, n_sel, masks, cost, latency = self._route_batch(
            imgs, actions, costs=costs, latency_ms=latency_ms)
        ensembles = [
            Detections.empty() if n_sel[t] == 0
            else core.ensemble(int(img), int(masks[t]))
            for t, img in enumerate(imgs)]
        results = self._results_from_ensembles(acts, n_sel, cost, latency,
                                               ensembles)
        if self.obs is not None:
            self._log_serving(imgs, masks, costs, results, log_ctx,
                              core=core)
        return results

    def _account(self, img_idx: int,
                 action: np.ndarray) -> FederationResult:
        """Single-request accounting (thin wrapper over the batch path)."""
        return self._account_batch([img_idx], np.asarray(action)[None])[0]

    def handle(self, img_idx: int) -> FederationResult:
        sel = getattr(self.agent, "select_for_images", None)
        if sel is not None:     # selector policy: decide from the index
            return self._account(img_idx, sel([int(img_idx)])[0])
        s = self.env.features[img_idx]
        a, _ = self.agent.select_action(s, deterministic=self.deterministic)
        return self._account(img_idx, np.asarray(a))

    def handle_many(self, img_indices: Sequence[int]
                    ) -> List[FederationResult]:
        """Serve a batch of requests: ONE policy decision pass, one IoU
        precompute, then vectorized accounting.

        Args:  ``img_indices`` — trace image ids (anything int()-able).
        Returns: one :class:`FederationResult` per request, input order —
          fused detections, the binary action taken, summed provider fee
          (mUSD), and modeled latency (max inference + sequential
          transmission); an empty selection is an explicit zero-cost /
          zero-latency result with empty detections.  ``[]`` in, ``[]``
          out.
        Dispatch: an agent exposing ``select_for_images`` (the
          ``repro.selection`` policies) is called directly on the image
          indices — bit-identical to the async path by construction;
          RL agents go through one batched feature forward.
        Failure modes: an out-of-range image id raises ``IndexError``
          (no partial billing: it raises before any accounting).
        """
        imgs = [int(i) for i in img_indices]
        if not imgs:
            return []
        sel = getattr(self.agent, "select_for_images", None)
        if sel is not None:
            actions = sel(imgs)
        else:
            from repro.core.loops import agent_policy
            policy = agent_policy(self.agent,
                                  deterministic=self.deterministic)
            actions = policy.select_batch(
                self.env.features[np.asarray(imgs)])
        self.env.core.precompute(imgs)
        return self._account_batch(imgs, actions)
