"""The HTTP front door: the serving plane's network-facing edge.

Three routes, one behavior, two server stacks:

  * ``POST /submit``  — body ``{"img": <trace image id>}``; answers the
    request's :class:`FederationResult` as JSON
    (:func:`repro.serving.client.result_to_dict` schema).  The handler
    thread parks on the service future while the micro-batcher
    coalesces it into a flush — an open-loop client gets true
    concurrent batching over HTTP.
  * ``POST /invalidate`` — body ``{"imgs": [...]}``; drops the images'
    cached artifacts everywhere (answers ``{"dropped": n}``).
  * ``GET /healthz``  — liveness + the transport's condemn state:
    ``{"status": "ok"|"degraded", "transport", "shards", "condemned"}``
    (degraded = serving, but at least one shard host is condemned).
  * ``GET /metrics``  — the service's merged metrics snapshot (parent
    registry + every shard/host registry) in Prometheus text exposition
    (``repro.obs.prom``), scrapeable by a stock Prometheus and parseable
    by ``obs_report --prom``.
  * ``GET /stats``    — the flush-counter dict (JSON), test/debug sugar.

:func:`create_app` builds a FastAPI app (asyncio lifespan owns the
client's shutdown; ``/submit`` awaits the service future on a worker
thread so the event loop never blocks on a flush) when FastAPI is
installed — it is an OPTIONAL dependency (`requirements.txt`), imported
lazily so the serving stack works without it.  :class:`HttpFrontDoor`
serves the identical routes on the stdlib ``ThreadingHTTPServer`` — no
dependencies, one thread per in-flight request — and is what tests and
the ``serving_socket`` benchmark run; both stacks dispatch through the
same :func:`route_request`, so they cannot drift.

:class:`HttpServingClient` is the matching client: the
``FederationClient`` five-call surface over ``urllib`` (futures run on
a small thread pool), so in-process and over-HTTP callers are
interchangeable in tests and benches.
"""
from __future__ import annotations

import json
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Sequence, Tuple
from urllib import request as _urlreq

from repro.serving.client import (FederationClient, result_from_dict,
                                  result_to_dict)


def route_request(client: FederationClient, method: str, path: str,
                  body: Optional[bytes]) -> Tuple[int, str, bytes]:
    """The one shared dispatch: ``(status, content_type, payload)`` for
    an HTTP request against the serving surface.  Both server stacks
    (FastAPI and the stdlib fallback) adapt their I/O to this function;
    route semantics live here only."""
    try:
        if method == "GET" and path == "/healthz":
            condemned = client.condemned()
            svc = client.service
            doc = {"status": "degraded" if condemned else "ok",
                   "transport": getattr(svc, "shard_backend", "inline"),
                   "shards": getattr(svc, "workers", 1),
                   "condemned": condemned}
            return 200, "application/json", json.dumps(doc).encode()
        if method == "GET" and path == "/metrics":
            from repro.obs.prom import render_prometheus
            text = render_prometheus(client.metrics_snapshot())
            return (200, "text/plain; version=0.0.4; charset=utf-8",
                    text.encode())
        if method == "GET" and path == "/stats":
            return (200, "application/json",
                    json.dumps(client.stats).encode())
        if method == "POST" and path == "/submit":
            try:
                doc = json.loads(body or b"")
                img = int(doc["img"])
            except (ValueError, KeyError, TypeError):
                return (400, "application/json", json.dumps(
                    {"error": "body must be {\"img\": <int>}"}).encode())
            res = client.handle(img)
            return (200, "application/json",
                    json.dumps(result_to_dict(res)).encode())
        if method == "POST" and path == "/invalidate":
            try:
                doc = json.loads(body or b"")
                imgs = [int(i) for i in doc["imgs"]]
            except (ValueError, KeyError, TypeError):
                return (400, "application/json", json.dumps(
                    {"error": "body must be {\"imgs\": [<int>...]}"}
                ).encode())
            return (200, "application/json", json.dumps(
                {"dropped": client.invalidate_images(imgs)}).encode())
        return (404, "application/json",
                json.dumps({"error": f"no route {method} {path}"}
                           ).encode())
    except Exception as e:      # a failed flush is the request's 500,
        return (500, "application/json",          # not the server's end
                json.dumps({"error": f"{type(e).__name__}: {e}"}
                           ).encode())


def create_app(client: FederationClient):
    """FastAPI application over the facade (requires the optional
    ``fastapi`` dependency; raise with guidance when absent).  The
    asyncio lifespan closes the client on shutdown; ``/submit`` resolves
    the service future via ``run_in_executor`` so a parked flush never
    blocks the event loop."""
    try:
        from contextlib import asynccontextmanager

        from fastapi import FastAPI, Request, Response
    except ImportError as e:
        raise ImportError(
            "the FastAPI front door needs the optional 'fastapi' "
            "dependency (pip install fastapi uvicorn); the stdlib "
            "HttpFrontDoor serves the same routes without it") from e

    @asynccontextmanager
    async def _lifespan(app):
        yield
        client.close()

    app = FastAPI(lifespan=_lifespan)

    async def _route(req: Request) -> Response:
        import asyncio
        body = await req.body()
        loop = asyncio.get_running_loop()
        status, ctype, payload = await loop.run_in_executor(
            None, route_request, client, req.method, req.url.path, body)
        return Response(content=payload, status_code=status,
                        media_type=ctype)

    for method, path in (("GET", "/healthz"), ("GET", "/metrics"),
                         ("GET", "/stats"), ("POST", "/submit"),
                         ("POST", "/invalidate")):
        app.add_api_route(path, _route, methods=[method])
    return app


class HttpFrontDoor:
    """The same routes on ``http.server.ThreadingHTTPServer`` — the
    dependency-free stack tests and benchmarks drive.  One daemon thread
    accepts; each request gets its own handler thread, which parks on
    the service future (that is the batching model: N in-flight HTTP
    requests = N queued submits = flush-sized batches).

    ``own_service=True`` ties the underlying service's shutdown to
    :meth:`close` (the CLI path); default leaves lifecycle with the
    caller (tests share one service across doors).
    """

    def __init__(self, service_or_client, host: str = "127.0.0.1",
                 port: int = 0, *, own_service: bool = False):
        if isinstance(service_or_client, FederationClient):
            self.client = service_or_client
        else:
            self.client = FederationClient(service_or_client,
                                           own_service=own_service)
        front = self

        class _Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def _serve(self, method: str) -> None:
                n = int(self.headers.get("Content-Length") or 0)
                body = self.rfile.read(n) if n else b""
                status, ctype, payload = route_request(
                    front.client, method, self.path, body)
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)

            def do_GET(self):           # noqa: N802 (stdlib contract)
                self._serve("GET")

            def do_POST(self):          # noqa: N802
                self._serve("POST")

            def log_message(self, *a):  # keep test output quiet
                pass

        class _Server(ThreadingHTTPServer):
            daemon_threads = True
            # the stdlib default backlog of 5 RSTs connect bursts from
            # open-loop load generators; size it to a flush-heavy pool
            request_queue_size = 128

        self._server = _Server((host, port), _Handler)
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="fed-http-front",
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)
        self.client.close()

    def __enter__(self) -> "HttpFrontDoor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class HttpServingClient:
    """``FederationClient``'s five-call surface over HTTP (urllib; no
    dependencies).  ``submit`` returns a real future backed by a small
    thread pool — open-loop load generators submit without blocking and
    the door's handler threads do the parking."""

    def __init__(self, base_url: str, *, timeout_s: float = 60.0,
                 pool_size: int = 32):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = float(timeout_s)
        self._pool = ThreadPoolExecutor(max_workers=pool_size,
                                        thread_name_prefix="fed-http-cli")

    def _call(self, method: str, path: str, doc=None) -> dict:
        body = None if doc is None else json.dumps(doc).encode()
        req = _urlreq.Request(self.base_url + path, data=body,
                              method=method,
                              headers={"Content-Type":
                                       "application/json"})
        with _urlreq.urlopen(req, timeout=self.timeout_s) as resp:
            payload = resp.read()
        return json.loads(payload)

    def _get_text(self, path: str) -> str:
        req = _urlreq.Request(self.base_url + path, method="GET")
        with _urlreq.urlopen(req, timeout=self.timeout_s) as resp:
            return resp.read().decode()

    def submit(self, img_idx: int) -> Future:
        return self._pool.submit(self.handle, img_idx)

    def handle(self, img_idx: int):
        doc = self._call("POST", "/submit", {"img": int(img_idx)})
        return result_from_dict(doc)

    def handle_many(self, img_indices: Sequence[int]) -> List:
        futs = [self.submit(i) for i in img_indices]
        return [f.result() for f in futs]

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        return int(self._call("POST", "/invalidate",
                              {"imgs": [int(i) for i in img_indices]}
                              )["dropped"])

    @property
    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    def metrics_text(self) -> str:
        return self._get_text("/metrics")

    def close(self) -> None:
        self._pool.shutdown(wait=False)
