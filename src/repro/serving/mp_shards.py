"""Multi-process serving shards: the subset-evaluation plane off the GIL.

``ShardedSubsetEvaluationCore`` splits the (image, subset) memo across W
shards, but its workers are Python *threads*: every ensemble assembly —
grouping loops, WBF, AP bookkeeping — serializes on one interpreter
lock, so W shards buy concurrency, not parallelism.  This module
promotes the shards to OS processes:

  * **shared-nothing workers** — each worker process owns a private
    :class:`SubsetEvaluationCore` built from the same traces + config;
    no shared memory, no locks, no cache entry ever lives in two places
    (``img % W`` routing is total and deterministic, exactly the thread
    path's rule).
  * **batched pipe RPC** — the parent sends one message per (flush,
    shard): the shard's image/mask rows.  The worker precomputes tables
    in one batch and answers with raw ``(boxes, scores, labels,
    providers)`` arrays (``SubsetEvaluationCore.ensemble_rows``, the wire
    contract); the parent rewraps them with ``Detections.fast``.  Merge
    order is the caller's request order — identical to the thread path.
  * **mid-stream pool swap** — a scenario segment crosses the process
    boundary as a :class:`~repro.scenarios.pool.PoolSnapshot` (a
    picklable *recipe*, not a trace dump): workers hold the pool's base
    traces and rebuild each segment's TraceSet + core locally, keyed by
    detection fingerprint, so revisited regimes re-hit their warm
    per-process caches.  Snapshots install lazily, at most once per
    (worker, fingerprint).
  * **failure isolation** — a dead or wedged worker surfaces as
    :class:`ShardWorkerError` on the next call touching that shard
    (never a hang); ``close()`` always reaps the children.

Workers start via the ``spawn`` context by default: the parent runs a
jit-compiled agent and jax's runtime threads do not survive ``fork``.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.evaluation import (LatticeResult,
                                         SubsetEvaluationCore,
                                         action_to_mask)
from repro.federation.traces import TraceSet


class ShardWorkerError(RuntimeError):
    """A shard worker process died, wedged, or raised — the shard's
    in-flight requests fail cleanly; the parent never blocks forever."""


def trace_content_digest(traces: TraceSet) -> str:
    """Content hash of the roster's detection streams (gt + per-provider
    boxes/scores/labels).  Provider fingerprints only capture *config* —
    two rosters generated from different seeds share fingerprints yet
    answer different rows — so cross-HOST compatibility checks must hash
    the actual data."""
    import hashlib
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(len(traces.gts)).tobytes())
    for img in range(len(traces.gts)):
        for det in [traces.gts[img]] + list(traces.dets[img]):
            h.update(np.ascontiguousarray(det.boxes, np.float64).tobytes())
            h.update(np.ascontiguousarray(det.scores,
                                          np.float64).tobytes())
            h.update(np.ascontiguousarray(det.labels, np.int64).tobytes())
    return h.hexdigest()


class ShardOpHandler:
    """Transport-agnostic implementation of the shard op contract.

    One instance owns a shard's private cores (``cores[None]`` is the
    static core over the shipped traces; scenario segments install under
    their ``dets_key`` and regenerate from the SNAPSHOT's seed, never
    shard-local state) and executes one op per call, returning
    ``(status, payload)`` with ``status`` in ``{"ok", "err"}``.  The
    *transport* frames the reply: the pipe worker (:func:`_worker_main`)
    and the TCP shard host (``repro.serving.socket_shards``) both speak
    ``(rid, op, *args)`` -> ``(rid, status, payload)`` around this same
    dispatch, so a shard answers identically whether it sits behind a
    multiprocessing pipe or a socket.

    Observability: the handler keeps its own dependency-free
    :class:`~repro.obs.metrics.MetricsRegistry` (per-op latency
    histograms) plus per-op wall-time totals; ``introspect`` ships both
    as plain dicts, which the parent merges with its own registry —
    shard metrics cross the wire as snapshots, never as live objects.
    A traced ``eval`` (trace context rides the message as a
    ``(trace_id, parent_span_id)`` tuple) answers with the rows AND a
    finished span dict; untraced messages keep the seed wire shape.
    """

    def __init__(self, traces: TraceSet, cfg: Dict[str, object]):
        from repro.federation.vocab import WordGrouper
        from repro.obs.metrics import MetricsRegistry
        self.traces = traces
        self.cfg = cfg
        self.cores: Dict[object, SubsetEvaluationCore] = {
            None: SubsetEvaluationCore(traces, **cfg)}
        self._grouper = WordGrouper()
        self._base_fp = tuple(p.fingerprint(detection_only=True)
                              for p in traces.providers)
        self.wreg = MetricsRegistry()
        self.wall: Dict[str, float] = {}
        self._n_spans = 0
        # introspection/wall updates may come from several connection
        # threads on a socket host (the pipe worker is single-threaded,
        # where this lock is simply uncontended)
        self._wall_lock = threading.Lock()

    def hello(self) -> Dict[str, object]:
        """Roster identity for connect-time compatibility checks: a
        client must refuse to serve through a host whose traces or
        ensemble config differ from its own (answers would be valid but
        not bit-identical to its other shards)."""
        return {"pid": os.getpid(),
                "n_providers": self.traces.n_providers,
                "n_images": len(self.traces.gts),
                "det_fingerprint": self._base_fp,
                "trace_digest": trace_content_digest(self.traces),
                "costs": [float(c) for c in self.traces.costs()],
                "cfg": dict(self.cfg)}

    def __call__(self, rid, op: str, args: tuple):
        """Execute one op; returns ``(status, payload)``."""
        cores = self.cores
        t_op = time.perf_counter()
        try:
            if op == "eval":
                imgs, masks, key, trace = args
                rows = cores[key].ensemble_rows(imgs, masks)
                if trace is None:
                    return "ok", rows
                with self._wall_lock:
                    self._n_spans += 1
                    n_spans = self._n_spans
                return "ok", (rows, {
                    "name": "worker_eval", "trace": trace[0],
                    "span": f"w{os.getpid():x}.{n_spans:x}",
                    "parent": trace[1], "ts": time.time(),
                    "dur_ms": (time.perf_counter() - t_op) * 1e3,
                    "attrs": {"pid": os.getpid(), "n": len(imgs)}})
            elif op == "ap":
                img, mask, against, key = args
                return "ok", cores[key].ap50(img, mask, against=against)
            elif op == "lattice":
                # ONE RPC answers every subset of the image: the shard
                # runs the vectorized full-lattice pass and ships the
                # concatenated row arrays (LatticeResult.to_wire)
                img, against, key = args
                return "ok", cores[key].evaluate_lattice(
                    img, against=against).to_wire()
            elif op == "precompute":
                imgs, key = args
                cores[key].precompute(imgs)
                return "ok", None
            elif op == "install":
                snap = args[0]
                if snap.dets_key not in cores:
                    # lazy import: serving must not pull the scenario
                    # engine unless a pool actually crosses the boundary
                    from repro.scenarios.pool import build_segment_traces
                    seg_traces = build_segment_traces(
                        self.traces, snap.profiles, snap.dets_key,
                        snap.seed, self._grouper,
                        base_det_fp=self._base_fp)
                    cores[snap.dets_key] = SubsetEvaluationCore(
                        seg_traces, **self.cfg)
                return "ok", None
            elif op == "invalidate":
                # fan out across every installed core: the images' cached
                # artifacts must die in ALL regimes, or a later segment
                # swap would serve stale ensembles (the thread backend's
                # counterpart is DynamicProviderPool.invalidate_images,
                # which sweeps every materialized segment core)
                return "ok", sum(c.invalidate_images(args[0])
                                 for c in cores.values())
            elif op == "introspect":
                return "ok", self._introspect(args[0])
            elif op == "hello":
                return "ok", self.hello()
            elif op == "ping":
                return "ok", "pong"
            elif op == "stall":
                # test hook: wedge this op for a fixed time (a shard that
                # stops answering but stays alive)
                time.sleep(float(args[0]))
                return "ok", None
            elif op == "crash":
                # test hook: die without cleanup, as a real crash would
                os._exit(13)
            elif op == "stop":
                return "ok", None
            else:
                return "err", f"unknown op {op!r}"
        except BaseException as e:       # noqa: BLE001 — ship it back
            return "err", f"{type(e).__name__}: {e}"
        finally:
            # per-shard wall-time accounting: lattice/eval RPCs and
            # segment installs used to vanish on the floor — they are
            # exactly the quantities a capacity plan needs
            dt_ms = (time.perf_counter() - t_op) * 1e3
            with self._wall_lock:
                self.wall[op] = self.wall.get(op, 0.0) + dt_ms / 1e3
            self.wreg.histogram(f"worker.op_ms.{op}").observe(dt_ms)

    def _introspect(self, key) -> Dict[str, object]:
        # stats/cache sizes aggregate over EVERY core this shard holds
        # (all regimes), mirroring the thread path's pool.agg_core_stats
        # — a scenario-serving shard's activity lives in its segment
        # cores, not the base one.  cache_sizes_by_core keeps the
        # per-fingerprint partition visible (a shard serving three
        # regimes reports three entries, not one opaque sum);
        # cached_images stays scoped to the requested key: it is the
        # per-core partition-corruption check surface.
        import zlib

        from repro.obs.metrics import counters_snapshot, merge_snapshots

        def _fp_label(k) -> str:
            # compact, stable per-fingerprint label: dets_keys are
            # nested tuples (unwieldy as report keys); crc32 of the repr
            # is enough to tell regimes apart in a cache report
            return "base" if k is None else \
                f"fp{zlib.crc32(repr(k).encode()) & 0xffffffff:08x}"

        agg_stats: Dict[str, int] = {}
        agg_sizes: Dict[str, int] = {}
        by_core: Dict[str, Dict[str, int]] = {}
        for ck, c in self.cores.items():
            by_core[_fp_label(ck)] = sizes = c.cache_sizes()
            for k, v in c.stats.items():
                agg_stats[k] = agg_stats.get(k, 0) + v
            for k, v in sizes.items():
                agg_sizes[k] = agg_sizes.get(k, 0) + v
        with self._wall_lock:
            wall = {k: round(v, 6) for k, v in sorted(self.wall.items())}
        return {"cache_sizes": agg_sizes,
                "cache_sizes_by_core": by_core,
                "stats": agg_stats,
                "wall_s": wall,
                "metrics": merge_snapshots(
                    self.wreg.snapshot(),
                    counters_snapshot(agg_stats, "core.")),
                "cached_images": self.cores[key].cached_images(),
                "n_cores": len(self.cores),
                "pid": os.getpid()}


def _worker_main(conn, traces: TraceSet,
                 cfg: Dict[str, object]) -> None:
    """Worker process body: recv -> :class:`ShardOpHandler` -> send.

    Every message is ``(rid, op, *args)`` and every answer echoes the
    request id — ``(rid, "ok", payload)`` or ``(rid, "err", message)``
    — so the parent can verify reply correlation explicitly instead of
    trusting pipe order (the contract remote/socket shards inherit; a
    desynced reply is detected, never mis-attributed).  An unreadable
    pipe means the parent is gone and the worker exits.
    """
    handler = ShardOpHandler(traces, cfg)
    conn.send((0, "ok", "ready"))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        rid, op = msg[0], msg[1]
        status, payload = handler(rid, op, tuple(msg[2:]))
        conn.send((rid, status, payload))
        if op == "stop" and status == "ok":
            conn.close()
            return


class ProcessShardedSubsetEvaluationCore:
    """W shared-nothing worker *processes* keyed by ``img_idx % W``.

    Exposes the same routing + evaluation surface as
    :class:`ShardedSubsetEvaluationCore` (``shard_id`` / ``partition`` /
    ``ensemble`` / ``ap50`` / ``cost`` / ``precompute`` /
    ``invalidate_images`` / ``cache_sizes`` / ``stats`` /
    ``shard_images``) so the async service can hold either backend, plus
    the batched per-shard entry point the dispatcher actually uses
    (:meth:`eval_on`).  Results are bit-identical to the thread path:
    same routing rule, same core math, same merge order.

    Thread safety: any thread may call any method; one lock per worker
    serializes that worker's pipe (the async service keeps its
    one-parent-thread-per-shard layout, so the locks are uncontended on
    the hot path).
    """

    def __init__(self, traces: TraceSet, *, n_shards: int = 4,
                 voting: str = "affirmative", ablation: str = "wbf",
                 iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto",
                 mp_context: str = "spawn",
                 start_timeout_s: float = 180.0,
                 op_timeout_s: float = 300.0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        from repro.ensemble.pipeline import resolve_use_kernel
        self.n_shards = int(n_shards)
        self.traces = traces
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1
        self.op_timeout_s = float(op_timeout_s)
        # resolve "auto" in the parent: every worker must make the same
        # kernel decision the parent would, regardless of its own env
        self._cfg = {"voting": voting, "ablation": ablation,
                     "iou_thr": iou_thr,
                     "use_kernel": resolve_use_kernel(use_kernel)}
        self._ctx = mp.get_context(mp_context)
        self._procs: List[mp.Process] = []
        self._conns = []
        self._locks = [threading.Lock() for _ in range(self.n_shards)]
        # per-shard monotonically increasing request ids: every reply
        # must echo the id of the request it answers (0 is the ready
        # handshake), so a desynchronized pipe is DETECTED instead of
        # silently mis-attributing rows to the wrong request
        self._rids = [0] * self.n_shards
        self._installed: List[set] = [set() for _ in range(self.n_shards)]
        self._failed = [False] * self.n_shards
        self._closed = False
        # observability (bind_obs): parent-side per-shard RPC latency
        # histograms, a condemned-shard counter, and a span recorder for
        # worker-shipped eval spans.  Unbound, the hot path pays one
        # ``is None`` check per RPC.
        self._rpc_hists = None
        self._m_condemned = None
        self._tracer = None
        # spawn everything first (children import in parallel), then wait
        # for each ready handshake — a failed import surfaces here, not
        # as a hang on the first eval
        for i in range(self.n_shards):
            parent_conn, child_conn = self._ctx.Pipe(duplex=True)
            proc = self._ctx.Process(
                target=_worker_main,
                args=(child_conn, traces, self._cfg),
                name=f"fed-mp-shard-{i}", daemon=True)
            proc.start()
            child_conn.close()
            self._procs.append(proc)
            self._conns.append(parent_conn)
        try:
            for sid in range(self.n_shards):
                self._recv(sid, "start", timeout_s=start_timeout_s,
                           expect_rid=0)
        except BaseException:
            self.close()
            raise

    @classmethod
    def like(cls, core: SubsetEvaluationCore, n_shards: int, *,
             mp_context: str = "spawn",
             **kw) -> "ProcessShardedSubsetEvaluationCore":
        """A process-sharded core with the same ensemble configuration as
        ``core`` (fresh, shared-nothing caches — a process shard never
        migrates another core's memo)."""
        return cls(core.traces, n_shards=n_shards, mp_context=mp_context,
                   **core.config(), **kw)

    @classmethod
    def for_pool(cls, pool, n_shards: int, *, mp_context: str = "spawn",
                 **kw) -> "ProcessShardedSubsetEvaluationCore":
        """Workers seeded with the pool's BASE traces: any segment of
        ``pool`` can then cross the boundary as a ``PoolSnapshot`` recipe
        (which carries the pool's regeneration seed) and be rebuilt
        bit-identically in-process."""
        return cls(pool.base_traces, n_shards=n_shards,
                   mp_context=mp_context,
                   voting=pool.voting, ablation=pool.ablation,
                   use_kernel=pool.use_kernel, **kw)

    def bind_obs(self, metrics=None, tracer=None) -> None:
        """Attach a :class:`~repro.obs.metrics.MetricsRegistry` (and
        optionally a tracer for worker-shipped spans): every RPC's pipe
        round-trip lands in a per-shard latency histogram and condemned
        shards are counted.  The async service binds its own registry so
        ``metrics_snapshot`` folds parent and worker views together."""
        if metrics is not None:
            self._rpc_hists = [
                metrics.histogram(f"serving.shard_rpc_ms.s{sid}")
                for sid in range(self.n_shards)]
            self._m_condemned = metrics.counter("serving.shards_condemned")
        self._tracer = tracer

    # -- pipe plumbing ---------------------------------------------------
    def _dead(self, sid: int, during: str, why: str) -> ShardWorkerError:
        code = self._procs[sid].exitcode
        return ShardWorkerError(
            f"shard {sid} worker {why} during {during!r}"
            f" (exitcode={code})")

    def _fail_shard(self, sid: int, during: str,
                    why: str) -> ShardWorkerError:
        """Condemn shard ``sid`` permanently.  After a timeout the pipe is
        desynchronized — the worker's late reply would be read as the
        answer to the NEXT request, silently returning wrong ensembles —
        so the only safe move is to reap the worker and fail every
        subsequent call on this shard fast."""
        self._failed[sid] = True
        if self._m_condemned is not None:
            self._m_condemned.inc()
        proc = self._procs[sid]
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2.0)
        if proc.is_alive():
            proc.kill()
            proc.join(timeout=2.0)
        return self._dead(sid, during, why)

    def _recv(self, sid: int, during: str, *,
              timeout_s: Optional[float] = None,
              expect_rid: Optional[int] = None):
        conn, proc = self._conns[sid], self._procs[sid]
        deadline = time.monotonic() + (timeout_s if timeout_s is not None
                                       else self.op_timeout_s)
        while not conn.poll(0.05):
            if not proc.is_alive():
                raise self._fail_shard(sid, during, "died")
            if time.monotonic() > deadline:
                raise self._fail_shard(sid, during, "timed out")
        try:
            rid, status, payload = conn.recv()
        except (EOFError, OSError):
            raise self._fail_shard(sid, during, "died") from None
        if expect_rid is not None and rid != expect_rid:
            # explicit reply correlation: a reply carrying the wrong
            # request id means the pipe is desynchronized (e.g. a stale
            # answer to an earlier timed-out request) — condemn the
            # shard rather than attribute rows to the wrong request
            raise self._fail_shard(
                sid, during, f"broke reply correlation (reply id {rid} "
                             f"!= request id {expect_rid})")
        if status != "ok":
            # the worker answered: the pipe is still in sync, the shard
            # survives — only THIS op failed (e.g. an unknown segment key)
            raise ShardWorkerError(f"shard {sid} worker error during "
                                   f"{during!r}: {payload}")
        return payload

    def _rpc_locked(self, sid: int, msg: tuple):
        """Send + receive on shard ``sid``'s pipe; caller holds the lock."""
        if self._closed:
            raise ShardWorkerError("process shard pool is closed")
        if self._failed[sid]:
            raise ShardWorkerError(
                f"shard {sid} worker is gone (earlier crash/timeout); "
                f"restart the service to restore it")
        t0 = time.perf_counter() if self._rpc_hists is not None else 0.0
        self._rids[sid] += 1
        rid = self._rids[sid]
        try:
            self._conns[sid].send((rid,) + msg)
        except (BrokenPipeError, OSError):
            raise self._fail_shard(sid, msg[0], "died") from None
        payload = self._recv(sid, msg[0], expect_rid=rid)
        if self._rpc_hists is not None:
            self._rpc_hists[sid].observe(
                (time.perf_counter() - t0) * 1e3)
        return payload

    def _rpc(self, sid: int, msg: tuple):
        with self._locks[sid]:
            return self._rpc_locked(sid, msg)

    def _ensure_installed_locked(self, sid: int, snapshot) -> object:
        key = snapshot.dets_key
        if key not in self._installed[sid]:
            self._rpc_locked(sid, ("install", snapshot))
            self._installed[sid].add(key)
        return key

    # -- shard addressing (same rule as the thread path) ------------------
    def shard_id(self, img_idx: int) -> int:
        return int(img_idx) % self.n_shards

    def partition(self, img_indices: Sequence[int]) -> Dict[int, List[int]]:
        groups: Dict[int, List[int]] = {}
        for i in img_indices:
            groups.setdefault(self.shard_id(i), []).append(int(i))
        return groups

    # -- batched per-shard entry point (the dispatcher hot path) ----------
    def eval_on(self, sid: int, img_indices: Sequence[int],
                masks: Sequence[int], snapshot=None,
                trace=None) -> List[Detections]:
        """Ensembles for (image, mask) rows homed on shard ``sid``, in
        request order.  ``snapshot`` scopes the rows to a scenario
        segment (installed lazily, once per worker per fingerprint).
        ``trace`` is an optional ``(trace_id, parent_span_id)`` wire
        context: the worker times its evaluation and ships a span back,
        recorded on the bound tracer — the untraced reply shape is
        unchanged."""
        imgs = [int(i) for i in img_indices]
        ms = [int(m) for m in masks]
        if self._tracer is None:
            trace = None
        with self._locks[sid]:
            key = None if snapshot is None else \
                self._ensure_installed_locked(sid, snapshot)
            rows = self._rpc_locked(sid, ("eval", imgs, ms, key, trace))
        if trace is not None:
            rows, span = rows
            self._tracer.record(span)
        return [Detections.fast(*r) for r in rows]

    # -- delegated single-pair surface ------------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def ensemble(self, img_idx: int, mask: int,
                 snapshot=None) -> Detections:
        return self.eval_on(self.shard_id(img_idx), [img_idx], [mask],
                            snapshot)[0]

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt",
             snapshot=None) -> float:
        sid = self.shard_id(img_idx)
        with self._locks[sid]:
            key = None if snapshot is None else \
                self._ensure_installed_locked(sid, snapshot)
            return float(self._rpc_locked(
                sid, ("ap", int(img_idx), int(mask), against, key)))

    def evaluate_lattice(self, img_idx: int, *, against: str = "gt",
                         snapshot=None) -> LatticeResult:
        """All 2^N-1 subset rows of one image in ONE pipe round-trip: the
        image's home worker runs the vectorized lattice pass (cached
        worker-side per (image, against)) and answers with the wire
        arrays; the parent rewraps them without copying."""
        sid = self.shard_id(img_idx)
        with self._locks[sid]:
            key = None if snapshot is None else \
                self._ensure_installed_locked(sid, snapshot)
            wire = self._rpc_locked(
                sid, ("lattice", int(img_idx), against, key))
        return LatticeResult.from_wire(wire, against)

    def cost(self, mask: int) -> float:
        # mask costs are image-independent config, not cache state: answer
        # locally instead of a pipe round-trip
        bits = np.asarray([(int(mask) >> i) & 1
                           for i in range(self.n_providers)], bool)
        return float(np.sum(self.costs * bits))

    def precompute(self, img_indices: Sequence[int],
                   snapshot=None) -> None:
        for sid, imgs in self.partition(img_indices).items():
            with self._locks[sid]:
                key = None if snapshot is None else \
                    self._ensure_installed_locked(sid, snapshot)
                self._rpc_locked(sid, ("precompute", imgs, key))

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Same partition rule as every delegated call; each worker drops
        the images from every core it holds (all regimes), preserving the
        invalidation fan-out across the process boundary."""
        dropped = 0
        for sid, imgs in self.partition(img_indices).items():
            dropped += int(self._rpc(sid, ("invalidate", imgs)))
        return dropped

    # -- aggregate introspection (one pipe round-trip per worker) ---------
    def _introspect(self, key=None) -> List[dict]:
        return [self._rpc(sid, ("introspect", key))
                for sid in range(self.n_shards)]

    def cache_sizes(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for rep in self._introspect():
            for k, v in rep["cache_sizes"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def cache_sizes_by_core(self) -> Dict[str, Dict[str, int]]:
        """Cache sizes keyed by detection fingerprint (``"base"`` for the
        static core, ``"fp<crc32>"`` per installed regime), summed across
        workers — a scenario-serving pool reports each regime's cache
        partition instead of one opaque total."""
        agg: Dict[str, Dict[str, int]] = {}
        for rep in self._introspect():
            for fp, sizes in rep.get("cache_sizes_by_core", {}).items():
                slot = agg.setdefault(fp, {})
                for k, v in sizes.items():
                    slot[k] = slot.get(k, 0) + v
        return agg

    def worker_wall_s(self) -> Dict[str, float]:
        """Wall seconds each worker spent inside ops (``eval``,
        ``lattice``, ``install``, ...), summed across workers."""
        agg: Dict[str, float] = {}
        for rep in self._introspect():
            for k, v in rep.get("wall_s", {}).items():
                agg[k] = agg.get(k, 0.0) + v
        return agg

    def metrics_snapshot(self) -> Dict[str, dict]:
        """Every worker's metrics registry (per-op latency histograms +
        core cache-stat counters) merged into one plain-dict snapshot —
        the cross-process half of the parent's unified metrics view."""
        from repro.obs.metrics import merge_snapshots
        return merge_snapshots(*[rep.get("metrics")
                                 for rep in self._introspect()])

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for rep in self._introspect():
            for k, v in rep["stats"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def shard_images(self) -> List[List[int]]:
        """Per-shard cached image ids (default core) — the same corruption
        check surface as the thread path: every entry of
        ``shard_images()[s]`` must satisfy ``img % W == s``."""
        return [rep["cached_images"] for rep in self._introspect()]

    def worker_pids(self) -> List[int]:
        return [p.pid for p in self._procs]

    # -- lifecycle --------------------------------------------------------
    def close(self, *, join_timeout_s: float = 10.0) -> None:
        """Graceful stop: ask every live worker to exit, join, escalate
        to terminate/kill; always reaps, idempotent, never raises."""
        if self._closed:
            return
        self._closed = True
        for sid, (proc, conn) in enumerate(zip(self._procs, self._conns)):
            try:
                if proc.is_alive():
                    self._rids[sid] += 1
                    conn.send((self._rids[sid], "stop"))
            except (BrokenPipeError, OSError):
                pass
        for proc, conn in zip(self._procs, self._conns):
            proc.join(timeout=join_timeout_s)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=2.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=2.0)
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "ProcessShardedSubsetEvaluationCore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):      # best-effort: tests that forget close()
        try:
            self.close(join_timeout_s=1.0)
        except BaseException:
            pass
