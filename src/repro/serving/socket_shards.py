"""Multi-HOST serving shards: the subset-evaluation plane over TCP.

``ProcessShardedSubsetEvaluationCore`` put the shards on worker
*processes* behind a batched pipe RPC — W cores on one box.  This module
generalizes that plane to H **hosts**:

  * **shard host** — :func:`serve_host` runs a TCP server that owns a
    private :class:`~repro.serving.mp_shards.ShardOpHandler` (one
    :class:`SubsetEvaluationCore` per detection fingerprint) and answers
    the *identical* op contract the pipe workers speak: one RPC per
    (flush, shard) returning raw ``(boxes, scores, labels, providers)``
    rows, ``lattice`` in one round trip, ``install`` for
    ``PoolSnapshot`` recipes, ``invalidate`` fanned across every regime.
    Hosts are spawned locally (:meth:`SocketShardedSubsetEvaluationCore`
    with ``n_shards=H``) or started standalone via
    ``python -m repro.launch.shard_host`` and joined with ``hosts=``.
  * **wire format** — length-prefixed pickle frames (8-byte big-endian
    length + payload) carrying ``(rid, op, *args)`` requests and
    ``(rid, status, payload)`` replies.  Reply correlation is *explicit*:
    a reply with the wrong ``rid`` condemns the connection, so a late
    answer from a previously wedged host can never be attributed to the
    current request.
  * **consistent-hash routing** — images map to hosts through a hash
    ring (``virtual_nodes`` points per host), so condemning a host
    re-homes only that host's images; entries cached on survivors keep
    their home.  Every host holds a full core over the same traces
    (shared-nothing), so any host answers any (image, mask) row
    bit-identically — routing is a cache-locality policy, not a
    correctness constraint.
  * **condemn + requeue** — a host that dies, wedges past
    ``op_timeout_s``, or breaks reply correlation is condemned (its
    socket closed, never reused — the ``ShardWorkerError`` discipline
    extended to remote shards) and its in-flight rows are re-routed to
    the survivors through the rebuilt ring; the caller's futures resolve
    with correct rows, never a hang or a stale answer.
  * **health checks** — an optional background thread pings every
    healthy host each ``health_interval_s`` over a *separate* connection
    (pings never queue behind a long eval).  A host must fail
    ``health_failures_to_condemn`` CONSECUTIVE pings to be condemned, so
    one slow ping (a flap) marks it suspect and a subsequent success
    clears it.

The docs contract lives in ``docs/serving.md``.
"""
from __future__ import annotations

import hashlib
import multiprocessing as mp
import os
import pickle
import socket
import struct
import threading
import time
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ensemble.boxes import Detections
from repro.federation.evaluation import (LatticeResult,
                                         SubsetEvaluationCore,
                                         action_to_mask)
from repro.federation.traces import TraceSet
from repro.serving.mp_shards import (ShardOpHandler, ShardWorkerError,
                                     trace_content_digest)

_LEN = struct.Struct(">Q")


# -- framing ----------------------------------------------------------------

def send_msg(sock: socket.socket, obj) -> None:
    """One length-prefixed pickle frame: 8-byte big-endian payload length
    followed by the payload.  ``sendall`` either ships the whole frame or
    raises — a partial frame can only be produced by a dying peer, which
    the reader surfaces as a ``ConnectionError``."""
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("socket closed mid-frame")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket):
    """Read one frame; raises ``ConnectionError`` on EOF and
    ``socket.timeout`` when the peer stops answering (both are condemn
    conditions for the client)."""
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    return pickle.loads(_recv_exact(sock, n))


# -- host (server) side -----------------------------------------------------

def serve_host(srv: socket.socket, traces: TraceSet,
               cfg: Dict[str, object]) -> None:
    """Serve the shard op contract on an already-listening socket until a
    ``stop`` op arrives (or the listener is closed externally).

    One thread per accepted connection; core-touching ops serialize on
    one lock (the cores' dicts are not thread-safe), while ``ping`` /
    ``hello`` / ``stall`` answer lock-free so health checks stay honest
    under load.  Every reply echoes the request id of the message it
    answers.
    """
    handler = ShardOpHandler(traces, cfg)
    stop = threading.Event()
    core_lock = threading.Lock()

    def _client(conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            while not stop.is_set():
                try:
                    msg = recv_msg(conn)
                except (ConnectionError, OSError, EOFError):
                    return
                rid, op = msg[0], msg[1]
                if op in ("ping", "hello", "stall"):
                    status, payload = handler(rid, op, tuple(msg[2:]))
                else:
                    with core_lock:
                        status, payload = handler(rid, op, tuple(msg[2:]))
                try:
                    send_msg(conn, (rid, status, payload))
                except (ConnectionError, OSError):
                    return
                if op == "stop" and status == "ok":
                    stop.set()
                    srv.close()
                    return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    while not stop.is_set():
        try:
            conn, _addr = srv.accept()
        except OSError:         # listener closed -> shut down
            return
        threading.Thread(target=_client, args=(conn,),
                         name="shard-host-conn", daemon=True).start()


def _host_main(report_conn, traces: TraceSet, cfg: Dict[str, object],
               host: str = "127.0.0.1", port: int = 0) -> None:
    """Spawned shard-host process body: bind, report the bound port back
    over ``report_conn`` (ephemeral ports — the parent learns where to
    connect), then serve until stopped."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(16)
    if report_conn is not None:
        report_conn.send(("ready", srv.getsockname()[1]))
        report_conn.close()
    serve_host(srv, traces, cfg)


# -- client side ------------------------------------------------------------

def _hash64(data: bytes) -> int:
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "big")


class SocketShardedSubsetEvaluationCore:
    """H shared-nothing shard HOSTS behind consistent-hash routing.

    Exposes the same routing + evaluation surface as
    :class:`ProcessShardedSubsetEvaluationCore` (``shard_id`` /
    ``partition`` / ``eval_on`` / ``ensemble`` / ``ap50`` /
    ``evaluate_lattice`` / ``cost`` / ``precompute`` /
    ``invalidate_images`` / ``cache_sizes`` / ``stats`` /
    ``shard_images`` / ``close``), so the async service and the
    transport registry can hold either.  Differences from the process
    plane:

      * ``shard_id`` is a hash-ring lookup over HEALTHY hosts, not a
        modulo — condemning a host re-homes only its images.
      * every host holds a full core over the same traces, so results
        are bit-identical no matter which host answers a row.
      * a condemned host's in-flight rows are REQUEUED to survivors
        (:meth:`eval_on` retries through the rebuilt ring) instead of
        failing the caller; only "all hosts condemned" is fatal.

    Construction: ``n_shards=H`` spawns H local host processes on
    ephemeral ports (the benchmark/test path); ``hosts=[(addr, port),
    ...]`` joins externally started hosts (``python -m
    repro.launch.shard_host``) after a connect-time ``hello`` handshake
    verifying roster fingerprint + ensemble config compatibility.

    Thread safety: any thread may call any method; one lock per host
    serializes that host's main connection (the async service keeps its
    one-parent-thread-per-shard layout, so locks are uncontended on the
    hot path).  Health pings use separate connections.
    """

    def __init__(self, traces: TraceSet, *, n_shards: int = 2,
                 hosts: Optional[Sequence[Tuple[str, int]]] = None,
                 voting: str = "affirmative", ablation: str = "wbf",
                 iou_thr: float = 0.5,
                 use_kernel: Union[bool, str] = "auto",
                 mp_context: str = "spawn",
                 start_timeout_s: float = 180.0,
                 op_timeout_s: float = 300.0,
                 connect_timeout_s: float = 10.0,
                 health_interval_s: float = 0.0,
                 health_timeout_s: float = 2.0,
                 health_failures_to_condemn: int = 2,
                 virtual_nodes: int = 64):
        from repro.ensemble.pipeline import resolve_use_kernel
        self.traces = traces
        self.n_providers = traces.n_providers
        self.costs = traces.costs()
        self.full_mask = (1 << self.n_providers) - 1
        self.op_timeout_s = float(op_timeout_s)
        self.connect_timeout_s = float(connect_timeout_s)
        self.health_interval_s = float(health_interval_s)
        self.health_timeout_s = float(health_timeout_s)
        self.health_failures_to_condemn = int(health_failures_to_condemn)
        self.virtual_nodes = int(virtual_nodes)
        # resolve "auto" client-side: every host must make the same
        # kernel decision this client would, regardless of its own env
        self._cfg = {"voting": voting, "ablation": ablation,
                     "iou_thr": iou_thr,
                     "use_kernel": resolve_use_kernel(use_kernel)}
        self._closed = False
        self._procs: List[Optional[mp.process.BaseProcess]] = []
        self._addrs: List[Tuple[str, int]] = []
        self._socks: List[Optional[socket.socket]] = []
        self._health_socks: List[Optional[socket.socket]] = []
        self._rids: List[int] = []
        self._hrids: List[int] = []
        self._suspect: List[int] = []
        self._rpc_hists = None
        self._m_condemned = None
        self._m_requeued = None
        self._tracer = None
        self._trace_digest = None   # computed lazily, once, at connect
        if hosts is not None:
            if not hosts:
                raise ValueError("hosts must name at least one shard host")
            self.n_shards = len(hosts)
            self._procs = [None] * self.n_shards
            self._addrs = [(str(h), int(p)) for h, p in hosts]
        else:
            if n_shards < 1:
                raise ValueError(f"n_shards must be >= 1, got {n_shards}")
            self.n_shards = int(n_shards)
        self._locks = [threading.Lock() for _ in range(self.n_shards)]
        self._installed: List[set] = [set() for _ in range(self.n_shards)]
        self._failed = [False] * self.n_shards
        self._socks = [None] * self.n_shards
        self._health_socks = [None] * self.n_shards
        self._rids = [0] * self.n_shards
        self._hrids = [0] * self.n_shards
        self._suspect = [0] * self.n_shards
        try:
            if hosts is None:
                self._spawn_local_hosts(traces, mp_context,
                                        start_timeout_s)
            for hid in range(self.n_shards):
                self._connect(hid)
            self._ring = self._build_ring()
        except BaseException:
            self.close()
            raise
        self._health_stop = threading.Event()
        self._health_thread = None
        if self.health_interval_s > 0:
            self._health_thread = threading.Thread(
                target=self._health_loop, name="fed-socket-health",
                daemon=True)
            self._health_thread.start()

    @classmethod
    def like(cls, core: SubsetEvaluationCore, n_shards: int,
             **kw) -> "SocketShardedSubsetEvaluationCore":
        """A socket-sharded core with the same ensemble configuration as
        ``core`` (fresh, shared-nothing caches on every host)."""
        return cls(core.traces, n_shards=n_shards, **core.config(), **kw)

    @classmethod
    def for_pool(cls, pool, n_shards: int,
                 **kw) -> "SocketShardedSubsetEvaluationCore":
        """Hosts seeded with the pool's BASE traces: any segment of
        ``pool`` can cross the wire as a ``PoolSnapshot`` recipe and be
        rebuilt bit-identically host-side (same contract as the process
        plane)."""
        return cls(pool.base_traces, n_shards=n_shards,
                   voting=pool.voting, ablation=pool.ablation,
                   use_kernel=pool.use_kernel, **kw)

    # -- startup ---------------------------------------------------------
    def _spawn_local_hosts(self, traces: TraceSet, mp_context: str,
                           start_timeout_s: float) -> None:
        ctx = mp.get_context(mp_context)
        reports = []
        for i in range(self.n_shards):
            parent_conn, child_conn = ctx.Pipe(duplex=False)
            proc = ctx.Process(target=_host_main,
                               args=(child_conn, traces, self._cfg),
                               name=f"fed-shard-host-{i}", daemon=True)
            proc.start()
            child_conn.close()
            self._procs.append(proc)
            reports.append(parent_conn)
        deadline = time.monotonic() + start_timeout_s
        for hid, conn in enumerate(reports):
            while not conn.poll(0.05):
                if not self._procs[hid].is_alive():
                    raise ShardWorkerError(
                        f"shard host {hid} died during startup "
                        f"(exitcode={self._procs[hid].exitcode})")
                if time.monotonic() > deadline:
                    raise ShardWorkerError(
                        f"shard host {hid} timed out during startup")
            tag, port = conn.recv()
            assert tag == "ready"
            self._addrs.append(("127.0.0.1", int(port)))
            conn.close()

    def _open_conn(self, hid: int,
                   timeout_s: Optional[float] = None) -> socket.socket:
        sock = socket.create_connection(
            self._addrs[hid], timeout=self.connect_timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.op_timeout_s if timeout_s is None
                        else timeout_s)
        return sock

    def _connect(self, hid: int) -> None:
        """Open the host's main connection and verify compatibility: the
        ``hello`` reply must describe the same roster (detection
        fingerprints + fees) and ensemble config this client serves, or
        its answers would be valid-but-different from the other shards'
        — a silent parity break, refused at connect time."""
        try:
            sock = self._open_conn(hid)
            self._rids[hid] += 1
            rid = self._rids[hid]
            send_msg(sock, (rid, "hello"))
            r_rid, status, info = recv_msg(sock)
        except (OSError, ConnectionError, socket.timeout) as e:
            raise ShardWorkerError(
                f"shard host {hid} at {self._addrs[hid]} unreachable "
                f"during connect: {e}") from None
        if r_rid != rid or status != "ok":
            raise ShardWorkerError(
                f"shard host {hid} failed the hello handshake: "
                f"{(r_rid, status, info)!r}")
        if self._trace_digest is None:
            self._trace_digest = trace_content_digest(self.traces)
        mine = {"n_providers": self.traces.n_providers,
                "n_images": len(self.traces.gts),
                "det_fingerprint": tuple(
                    p.fingerprint(detection_only=True)
                    for p in self.traces.providers),
                "trace_digest": self._trace_digest,
                "costs": [float(c) for c in self.costs],
                "cfg": dict(self._cfg)}
        for key, want in mine.items():
            got = info.get(key)
            if got != want:
                raise ShardWorkerError(
                    f"shard host {hid} at {self._addrs[hid]} serves a "
                    f"different world: {key} differs "
                    f"(host={got!r} vs client={want!r})")
        self._socks[hid] = sock

    # -- consistent-hash ring --------------------------------------------
    def _build_ring(self) -> Tuple[List[int], List[int]]:
        """(sorted points, host id per point) over HEALTHY hosts."""
        pts: List[Tuple[int, int]] = []
        for hid in range(self.n_shards):
            if self._failed[hid]:
                continue
            for v in range(self.virtual_nodes):
                pts.append((_hash64(f"host-{hid}-vnode-{v}".encode()),
                            hid))
        pts.sort()
        return [p for p, _ in pts], [h for _, h in pts]

    def healthy_hosts(self) -> List[int]:
        return [h for h in range(self.n_shards) if not self._failed[h]]

    def condemned(self) -> List[int]:
        return [h for h in range(self.n_shards) if self._failed[h]]

    def shard_id(self, img_idx: int) -> int:
        """The image's home host on the CURRENT ring (healthy hosts
        only).  Raises ``ShardWorkerError`` once every host is gone."""
        points, owners = self._ring
        if not points:
            raise ShardWorkerError("all shard hosts are condemned")
        i = bisect_left(points, _hash64(f"img-{int(img_idx)}".encode()))
        return owners[i % len(owners)]

    def partition(self, img_indices: Sequence[int]) -> Dict[int, List[int]]:
        groups: Dict[int, List[int]] = {}
        for i in img_indices:
            groups.setdefault(self.shard_id(i), []).append(int(i))
        return groups

    # -- observability ----------------------------------------------------
    def bind_obs(self, metrics=None, tracer=None) -> None:
        """Attach a :class:`~repro.obs.metrics.MetricsRegistry` (and
        optionally a tracer for host-shipped spans): every RPC's socket
        round-trip lands in a per-host latency histogram; condemned
        hosts and requeued rows are counted."""
        if metrics is not None:
            self._rpc_hists = [
                metrics.histogram(f"serving.host_rpc_ms.h{hid}")
                for hid in range(self.n_shards)]
            self._m_condemned = metrics.counter("serving.hosts_condemned")
            self._m_requeued = metrics.counter("serving.rows_requeued")
        self._tracer = tracer

    # -- failure + RPC plumbing ------------------------------------------
    def _fail_host(self, hid: int, during: str,
                   why: str) -> ShardWorkerError:
        """Condemn host ``hid`` permanently: close its connections (a
        desynced socket must never answer a later request), drop it from
        the ring, reap its process if we spawned it.  Idempotent —
        concurrent failures on one host condemn once."""
        first = not self._failed[hid]
        self._failed[hid] = True
        if first and self._m_condemned is not None:
            self._m_condemned.inc()
        for table in (self._socks, self._health_socks):
            sock, table[hid] = table[hid], None
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
        proc = self._procs[hid] if hid < len(self._procs) else None
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=2.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=2.0)
        self._ring = self._build_ring()
        return ShardWorkerError(
            f"shard host {hid} at "
            f"{self._addrs[hid] if hid < len(self._addrs) else '?'} "
            f"{why} during {during!r}")

    def _rpc_locked(self, hid: int, msg: tuple):
        if self._closed:
            raise ShardWorkerError("socket shard pool is closed")
        if self._failed[hid]:
            raise ShardWorkerError(
                f"shard host {hid} is condemned (earlier crash/timeout); "
                f"its images are served by the surviving hosts")
        sock = self._socks[hid]
        t0 = time.perf_counter() if self._rpc_hists is not None else 0.0
        self._rids[hid] += 1
        rid = self._rids[hid]
        try:
            send_msg(sock, (rid,) + msg)
            r_rid, status, payload = recv_msg(sock)
        except socket.timeout:
            raise self._fail_host(hid, msg[0], "timed out") from None
        except (ConnectionError, OSError, EOFError,
                pickle.UnpicklingError) as e:
            raise self._fail_host(
                hid, msg[0], f"died ({type(e).__name__})") from None
        if r_rid != rid:
            # explicit reply correlation, same discipline as the pipe:
            # a mismatched id means the stream is desynchronized — the
            # host is condemned rather than rows mis-attributed
            raise self._fail_host(
                hid, msg[0], f"broke reply correlation (reply id {r_rid}"
                             f" != request id {rid})")
        if status != "ok":
            # the host answered coherently: only THIS op failed
            raise ShardWorkerError(f"shard host {hid} error during "
                                   f"{msg[0]!r}: {payload}")
        if self._rpc_hists is not None:
            self._rpc_hists[hid].observe((time.perf_counter() - t0) * 1e3)
        return payload

    def _rpc(self, hid: int, msg: tuple):
        with self._locks[hid]:
            return self._rpc_locked(hid, msg)

    def _ensure_installed_locked(self, hid: int, snapshot) -> object:
        key = snapshot.dets_key
        if key not in self._installed[hid]:
            self._rpc_locked(hid, ("install", snapshot))
            self._installed[hid].add(key)
        return key

    # -- health checking --------------------------------------------------
    def _ping(self, hid: int) -> None:
        """One health ping on the host's dedicated health connection
        (created lazily; never the main conn, so a long eval can't fail
        a ping).  Any error propagates to the caller."""
        sock = self._health_socks[hid]
        if sock is None:
            sock = self._open_conn(hid, timeout_s=self.health_timeout_s)
            self._health_socks[hid] = sock
        self._hrids[hid] += 1
        rid = self._hrids[hid]
        try:
            send_msg(sock, (rid, "ping"))
            r_rid, status, payload = recv_msg(sock)
        except BaseException:
            # a broken health conn must not be retried against: rebuild
            # next ping so one stale socket can't fail a healthy host
            self._health_socks[hid] = None
            try:
                sock.close()
            except OSError:
                pass
            raise
        if r_rid != rid or status != "ok" or payload != "pong":
            self._health_socks[hid] = None
            raise ShardWorkerError(
                f"shard host {hid} answered a malformed ping: "
                f"{(r_rid, status, payload)!r}")

    def health_tick(self) -> List[int]:
        """One health-check pass over every non-condemned host; returns
        the hosts condemned BY this tick.  A host is condemned only
        after ``health_failures_to_condemn`` consecutive failed pings —
        a single flap marks it suspect, and a later success clears the
        suspicion.  (The background loop calls this; tests call it
        directly for deterministic churn.)"""
        newly = []
        for hid in range(self.n_shards):
            if self._failed[hid] or self._closed:
                continue
            try:
                self._ping(hid)
                self._suspect[hid] = 0
            except BaseException:
                self._suspect[hid] += 1
                if self._suspect[hid] >= self.health_failures_to_condemn:
                    self._fail_host(
                        hid, "health_check",
                        f"failed {self._suspect[hid]} consecutive "
                        f"health checks")
                    newly.append(hid)
        return newly

    def _health_loop(self) -> None:
        while not self._health_stop.wait(self.health_interval_s):
            if self._closed:
                return
            health_err = None
            try:
                self.health_tick()
            except ShardWorkerError as e:
                health_err = e      # all hosts gone: nothing to watch
            if health_err is not None and not self.healthy_hosts():
                return

    # -- batched per-shard entry point (the dispatcher hot path) ----------
    def eval_on(self, hid: int, img_indices: Sequence[int],
                masks: Sequence[int], snapshot=None,
                trace=None) -> List[Detections]:
        """Ensembles for (image, mask) rows, request order preserved.

        ``hid`` is the rows' home host per the caller's routing; if that
        host is (or becomes) condemned, the rows are REQUEUED: re-routed
        through the rebuilt ring and evaluated by the survivors.  The
        caller observes only correct rows or — with every host gone — a
        ``ShardWorkerError``.  ``snapshot`` scopes rows to a scenario
        segment (installed lazily, once per host per fingerprint);
        ``trace`` is the ``(trace_id, parent_span_id)`` wire context.
        """
        imgs = [int(i) for i in img_indices]
        ms = [int(m) for m in masks]
        if self._tracer is None:
            trace = None
        out: List[Optional[Detections]] = [None] * len(imgs)
        pending = list(range(len(imgs)))
        target: Optional[int] = hid if not self._failed[hid] else None
        requeued = False
        while pending:
            if target is not None:
                groups = {target: list(pending)}
            else:
                groups = {}
                for p in pending:
                    groups.setdefault(self.shard_id(imgs[p]),
                                      []).append(p)
            target = None
            for ghid, positions in groups.items():
                try:
                    rows = self._eval_on_host(
                        ghid, [imgs[p] for p in positions],
                        [ms[p] for p in positions], snapshot, trace)
                except ShardWorkerError:
                    if not self._failed[ghid]:
                        raise       # op-level error: host is fine
                    # condemned mid-call: leave these rows pending; the
                    # next loop iteration re-routes them via the ring
                    # rebuilt by _fail_host (all-hosts-gone surfaces
                    # from shard_id)
                    if self._m_requeued is not None:
                        self._m_requeued.inc(len(positions))
                    requeued = True
                    continue
                for p, det in zip(positions, rows):
                    out[p] = det
                pending = [p for p in pending if out[p] is None]
        return out  # type: ignore[return-value]

    def _eval_on_host(self, hid: int, imgs: List[int], ms: List[int],
                      snapshot, trace) -> List[Detections]:
        with self._locks[hid]:
            key = None if snapshot is None else \
                self._ensure_installed_locked(hid, snapshot)
            rows = self._rpc_locked(hid, ("eval", imgs, ms, key, trace))
        if trace is not None:
            rows, span = rows
            self._tracer.record(span)
        return [Detections.fast(*r) for r in rows]

    # -- delegated single-pair surface ------------------------------------
    def mask_of(self, action: np.ndarray) -> int:
        return action_to_mask(action)

    def ensemble(self, img_idx: int, mask: int,
                 snapshot=None) -> Detections:
        return self.eval_on(self.shard_id(img_idx), [img_idx], [mask],
                            snapshot)[0]

    def _rpc_rerouted(self, img_idx: int, msg_of, snapshot=None):
        """One RPC against the image's home host, re-routed through the
        rebuilt ring when that host is condemned mid-call — the same
        requeue discipline :meth:`eval_on` applies to batches.  Op-level
        errors (the host answered coherently) propagate; only
        condemnation reroutes; all-hosts-gone surfaces from
        ``shard_id``."""
        while True:
            hid = self.shard_id(img_idx)
            try:
                with self._locks[hid]:
                    key = None if snapshot is None else \
                        self._ensure_installed_locked(hid, snapshot)
                    return self._rpc_locked(hid, msg_of(key))
            except ShardWorkerError:
                if not self._failed[hid]:
                    raise
                if self._m_requeued is not None:
                    self._m_requeued.inc()

    def ap50(self, img_idx: int, mask: int, *, against: str = "gt",
             snapshot=None) -> float:
        return float(self._rpc_rerouted(
            img_idx, lambda key: ("ap", int(img_idx), int(mask),
                                  against, key), snapshot))

    def evaluate_lattice(self, img_idx: int, *, against: str = "gt",
                         snapshot=None) -> LatticeResult:
        """All 2^N-1 subset rows of one image in ONE socket round trip
        (same wire arrays as the pipe plane)."""
        wire = self._rpc_rerouted(
            img_idx, lambda key: ("lattice", int(img_idx), against, key),
            snapshot)
        return LatticeResult.from_wire(wire, against)

    def cost(self, mask: int) -> float:
        # mask costs are image-independent config: answer locally
        bits = np.asarray([(int(mask) >> i) & 1
                           for i in range(self.n_providers)], bool)
        return float(np.sum(self.costs * bits))

    def precompute(self, img_indices: Sequence[int],
                   snapshot=None) -> None:
        pending = [int(i) for i in img_indices]
        while pending:
            done = []
            for hid, imgs in self.partition(pending).items():
                try:
                    with self._locks[hid]:
                        key = None if snapshot is None else \
                            self._ensure_installed_locked(hid, snapshot)
                        self._rpc_locked(hid, ("precompute", imgs, key))
                    done.extend(imgs)
                except ShardWorkerError:
                    if not self._failed[hid]:
                        raise       # op-level error, host healthy
                    # condemned: these images re-partition next pass
            pending = [i for i in pending if i not in set(done)]

    def invalidate_images(self, img_indices: Sequence[int]) -> int:
        """Fan out to EVERY healthy host: churn means an image's cached
        artifacts may live on any survivor (requeues re-homed it), and
        each host drops the images from every core it holds (all
        regimes)."""
        imgs = [int(i) for i in img_indices]
        dropped = 0
        for hid in self.healthy_hosts():
            try:
                dropped += int(self._rpc(hid, ("invalidate", imgs)))
            except ShardWorkerError:
                if not self._failed[hid]:
                    raise
                # a host condemned mid-sweep held caches that died with
                # it — nothing left there to invalidate
        return dropped

    # -- aggregate introspection (healthy hosts only) ---------------------
    def _introspect(self, key=None) -> List[dict]:
        reps = []
        for hid in self.healthy_hosts():
            try:
                reps.append(self._rpc(hid, ("introspect", key)))
            except ShardWorkerError:
                if not self._failed[hid]:
                    raise       # answered coherently: a real op error
        return reps

    def cache_sizes(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for rep in self._introspect():
            for k, v in rep["cache_sizes"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def cache_sizes_by_core(self) -> Dict[str, Dict[str, int]]:
        agg: Dict[str, Dict[str, int]] = {}
        for rep in self._introspect():
            for fp, sizes in rep.get("cache_sizes_by_core", {}).items():
                slot = agg.setdefault(fp, {})
                for k, v in sizes.items():
                    slot[k] = slot.get(k, 0) + v
        return agg

    def worker_wall_s(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for rep in self._introspect():
            for k, v in rep.get("wall_s", {}).items():
                agg[k] = agg.get(k, 0.0) + v
        return agg

    def metrics_snapshot(self) -> Dict[str, dict]:
        """Every healthy host's registry merged into one plain-dict
        snapshot — the cross-HOST half of the parent's unified metrics
        view."""
        from repro.obs.metrics import merge_snapshots
        return merge_snapshots(*[rep.get("metrics")
                                 for rep in self._introspect()])

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for rep in self._introspect():
            for k, v in rep["stats"].items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def shard_images(self) -> List[List[int]]:
        """Per-HEALTHY-host cached image ids (default core): the
        cache-locality surface.  Unlike the modulo planes this is not a
        hard invariant — requeues legitimately re-home images — but
        under no churn every cached image satisfies
        ``shard_id(img) == host``."""
        return [rep["cached_images"] for rep in self._introspect()]

    def host_pids(self) -> List[Optional[int]]:
        return [p.pid if p is not None else None for p in self._procs]

    def host_addrs(self) -> List[Tuple[str, int]]:
        return list(self._addrs)

    # -- lifecycle --------------------------------------------------------
    def close(self, *, join_timeout_s: float = 10.0) -> None:
        """Graceful stop: stop spawned hosts (externally started hosts
        are only disconnected — their other clients keep serving), close
        every socket, reap children; idempotent, never raises."""
        if self._closed:
            return
        self._closed = True
        stop_ev = getattr(self, "_health_stop", None)
        if stop_ev is not None:
            stop_ev.set()
        for hid in range(len(self._socks)):
            sock = self._socks[hid]
            owned = hid < len(self._procs) and self._procs[hid] is not None
            if sock is not None and owned and not self._failed[hid]:
                try:
                    self._rids[hid] += 1
                    send_msg(sock, (self._rids[hid], "stop"))
                    sock.settimeout(2.0)
                    recv_msg(sock)
                except (OSError, ConnectionError, socket.timeout,
                        pickle.UnpicklingError, EOFError):
                    pass
            for table in (self._socks, self._health_socks):
                s = table[hid] if hid < len(table) else None
                table[hid] = None
                if s is not None:
                    try:
                        s.close()
                    except OSError:
                        pass
        for proc in self._procs:
            if proc is None:
                continue
            proc.join(timeout=join_timeout_s)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=2.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=2.0)

    def __enter__(self) -> "SocketShardedSubsetEvaluationCore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):      # best-effort: tests that forget close()
        try:
            self.close(join_timeout_s=1.0)
        except BaseException:
            pass
