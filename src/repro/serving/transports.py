"""The shard transport seam: one protocol, a registry, three planes.

``AsyncFederationService`` used to pick its evaluation plane through a
``shard_backend="thread"|"process"`` string threaded through the
constructor, the flush path, invalidation, metrics and close — adding a
third plane meant touching every one of those branches.  This module
puts the seam behind an object:

  * :class:`ShardTransport` — the protocol the service programs against:
    ``route`` (image -> shard id), ``eval_batch`` (one batched RPC per
    (flush, shard), the ``eval_on`` wire contract), ``invalidate``,
    ``snapshot`` (shard-side metrics extras), ``close``, plus the
    ``condemned`` status property and ``inline`` capability flag
    (inline transports keep ensembles + accounting on parent threads;
    RPC transports ship (image, mask) rows to shard workers/hosts).
  * a **registry** — transports self-register under their wire name;
    ``AsyncFederationService(transport="socket")`` resolves through
    :func:`get_transport`, so downstream planes (and tests) can register
    their own without touching the service.
  * :class:`ThreadTransport` / :class:`ProcessTransport` /
    :class:`SocketTransport` — the in-process shards, the W-worker
    process plane, and the H-host socket plane, all answering
    bit-identical rows (``tests/test_serving_socket.py`` holds the
    three-way parity).

The legacy ``shard_backend=`` kwarg still works behind a
``DeprecationWarning`` (resolved through this registry); see
``docs/serving.md`` for the migration note.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Type

from repro.ensemble.boxes import Detections

_REGISTRY: Dict[str, Type["ShardTransport"]] = {}


def register_transport(name: str):
    """Class decorator: publish a transport under its wire name."""
    def _reg(cls: Type["ShardTransport"]) -> Type["ShardTransport"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return _reg


def get_transport(name: str) -> Type["ShardTransport"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown shard transport {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_transports() -> List[str]:
    return sorted(_REGISTRY)


class ShardTransport:
    """What the async service needs from an evaluation plane.

    A transport OWNS its core (built in :meth:`build`, reaped in
    :meth:`close`) and answers:

      * ``route(img)`` — the image's home shard id in
        ``[0, n_shards)``; the service runs one parent-side accounting
        thread per shard id.
      * ``eval_batch(sid, imgs, masks, snapshot, trace)`` — ensembles
        for the rows, request order preserved; ``snapshot`` is a
        picklable ``PoolSnapshot`` recipe scoping the rows to a scenario
        segment; ``trace`` the wire trace context.  RPC transports may
        REQUEUE rows to surviving shards when ``sid`` is condemned
        mid-call.
      * ``invalidate(imgs)`` — drop cached artifacts on every shard, all
        regimes; returns entries dropped.
      * ``snapshot()`` — shard-side metrics as one plain-dict snapshot
        (:func:`repro.obs.metrics.merge_snapshots`-compatible): what the
        parent's registry does NOT already hold.
      * ``condemned`` — shard ids permanently failed (never reused).
      * ``inline`` — True when ensembles run on the parent's own shard
        threads (the service then calls ``core.shards[sid]`` directly
        and accounting touches the core; ``eval_batch`` stays unused).

    ``core`` stays public: the underlying sharded evaluation core, for
    surfaces the protocol deliberately does not wrap (tests, benches,
    ``precompute`` warm-up).
    """

    name = "?"
    inline = False

    def __init__(self, core):
        self.core = core

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, *, env=None, pool=None, workers: int = 2,
              mp_context: str = "spawn",
              options: Optional[dict] = None) -> "ShardTransport":
        """Build the transport's core for a service: from ``pool``'s base
        traces when a scenario pool is attached, else from ``env.core``'s
        traces + config.  ``options`` carries transport-specific knobs
        (the socket plane's ``hosts``/health parameters)."""
        raise NotImplementedError

    # -- the service-facing protocol --------------------------------------
    @property
    def n_shards(self) -> int:
        return self.core.n_shards

    def route(self, img_idx: int) -> int:
        return self.core.shard_id(int(img_idx))

    def eval_batch(self, sid: int, imgs: Sequence[int],
                   masks: Sequence[int], snapshot=None,
                   trace=None) -> List[Detections]:
        return self.core.eval_on(sid, imgs, masks, snapshot, trace=trace)

    def invalidate(self, img_indices: Sequence[int]) -> int:
        return self.core.invalidate_images(img_indices)

    def snapshot(self) -> dict:
        return self.core.metrics_snapshot()

    @property
    def condemned(self) -> List[int]:
        return []

    def bind_obs(self, metrics=None, tracer=None) -> None:
        """Attach the parent's registry/tracer to the plane (RPC latency
        histograms, condemned counters, shard-shipped spans)."""

    def close(self) -> None:
        self.core.close()


@register_transport("thread")
class ThreadTransport(ShardTransport):
    """In-process shards (``ShardedSubsetEvaluationCore``): zero IPC,
    ensembles serialize on the GIL.  Inline — the service's shard
    threads touch ``core.shards[sid]`` directly and do their own
    accounting, so ``eval_batch`` is never called on this transport."""

    inline = True

    def __init__(self, core, pool=None, workers: int = 0):
        super().__init__(core)
        self._pool = pool
        self._workers = workers or core.n_shards

    @classmethod
    def build(cls, *, env=None, pool=None, workers: int = 2,
              mp_context: str = "spawn",
              options: Optional[dict] = None) -> "ThreadTransport":
        from repro.federation.evaluation import \
            ShardedSubsetEvaluationCore
        if pool is not None:
            return cls(pool.sharded_core_at(0, workers), pool, workers)
        return cls(ShardedSubsetEvaluationCore.like(env.core, workers),
                   workers=workers)

    def core_at(self, clock: int):
        """The pool's sharded core for this flush's segment (warm,
        memoized pool-side); updates ``self.core`` so routing follows the
        live segment.  Identity without a pool."""
        if self._pool is not None:
            self.core = self._pool.sharded_core_at(clock, self._workers)
        return self.core

    def snapshot(self) -> dict:
        from repro.obs.metrics import counters_snapshot
        return counters_snapshot(self.core.stats, "core.")

    def close(self) -> None:    # thread shards hold no OS resources
        pass


@register_transport("process")
class ProcessTransport(ShardTransport):
    """W shard worker processes on this box behind batched pipe RPC
    (``ProcessShardedSubsetEvaluationCore``): ``img % W`` routing,
    condemn-never-reuse on worker death."""

    @classmethod
    def build(cls, *, env=None, pool=None, workers: int = 2,
              mp_context: str = "spawn",
              options: Optional[dict] = None) -> "ProcessTransport":
        from repro.serving.mp_shards import \
            ProcessShardedSubsetEvaluationCore
        if pool is not None:
            core = ProcessShardedSubsetEvaluationCore.for_pool(
                pool, workers, mp_context=mp_context)
        else:
            core = ProcessShardedSubsetEvaluationCore.like(
                env.core, workers, mp_context=mp_context)
        return cls(core)

    @property
    def condemned(self) -> List[int]:
        return [sid for sid, dead in enumerate(self.core._failed) if dead]

    def bind_obs(self, metrics=None, tracer=None) -> None:
        self.core.bind_obs(metrics, tracer)


@register_transport("socket")
class SocketTransport(ShardTransport):
    """H shard HOSTS over TCP (``SocketShardedSubsetEvaluationCore``):
    consistent-hash routing over healthy hosts, health-checked
    condemn + requeue.  ``options`` accepts ``hosts=[(addr, port), ...]``
    to join externally started ``repro.launch.shard_host`` servers
    (spawns ``workers`` local hosts otherwise) plus the socket core's
    health/timeout knobs (``health_interval_s``, ``op_timeout_s``,
    ``virtual_nodes``, ...)."""

    @classmethod
    def build(cls, *, env=None, pool=None, workers: int = 2,
              mp_context: str = "spawn",
              options: Optional[dict] = None) -> "SocketTransport":
        from repro.serving.socket_shards import \
            SocketShardedSubsetEvaluationCore
        opts = dict(options or {})
        hosts = opts.pop("hosts", None)
        if hosts is not None:
            opts["hosts"] = [(str(h), int(p)) for h, p in
                             (hp.rsplit(":", 1) if isinstance(hp, str)
                              else hp for hp in hosts)]
        else:
            opts["n_shards"] = workers
        opts.setdefault("mp_context", mp_context)
        if pool is not None:
            core = SocketShardedSubsetEvaluationCore.for_pool(
                pool, opts.pop("n_shards", workers), **opts)
        else:
            core = SocketShardedSubsetEvaluationCore.like(
                env.core, opts.pop("n_shards", workers), **opts)
        return cls(core)

    @property
    def condemned(self) -> List[int]:
        return self.core.condemned()

    def bind_obs(self, metrics=None, tracer=None) -> None:
        self.core.bind_obs(metrics, tracer)
