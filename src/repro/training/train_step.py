"""LM training step: loss, grads, clipping, AdamW update.

``make_train_step(model)`` returns a pure function suitable for jax.jit /
pjit: (state, batch) -> (state, metrics).  Remat (jax.checkpoint around each
layer scan body) is enabled for the production shapes via ``remat=True``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, \
    clip_by_global_norm
from repro.optim.schedules import cosine_schedule

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def lm_loss(model: Model, params, batch, *, remat=False):
    logits, aux = model.forward(params, batch, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, (loss, aux)


def chunked_lm_loss(model: Model, params, batch, *, n_chunks: int,
                    remat=False):
    """Sequence-chunked cross-entropy (§Perf optimisation).

    The naive loss materialises fp32 logits of shape (B, S, V) — for a 256k
    vocab at 1M tokens that is ~1 PB globally and forces a vocab-axis
    all-gather for the label lookup.  Here the unembedding + log-softmax run
    chunk-by-chunk over the sequence inside a checkpointed lax.map, so peak
    logits memory drops by S/chunk and the label gather stays local.
    """
    hidden, aux = model.forward(params, batch, remat=remat,
                                return_hidden=True)
    labels = batch["labels"]
    B, S = labels.shape
    assert S % n_chunks == 0, (S, n_chunks)
    C = S // n_chunks
    hid = hidden.reshape(B, n_chunks, C, -1).swapaxes(0, 1)
    lab = labels.reshape(B, n_chunks, C).swapaxes(0, 1)

    @jax.checkpoint
    def one(args):
        h_c, l_c = args
        logits = model.unembed(params, h_c)          # (B, C, V) fp32
        logp = jax.nn.log_softmax(logits, axis=-1)
        mask = (l_c >= 0).astype(jnp.float32)
        safe = jnp.maximum(l_c, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask), jnp.sum(mask)

    sums, counts = jax.lax.map(one, (hid, lab))
    loss = jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)
    return loss + aux, (loss, aux)


def make_train_step(model: Model, *, peak_lr=3e-4, warmup_steps=100,
                    total_steps=10_000, weight_decay=0.1, clip_norm=1.0,
                    remat=False, loss_chunks: int = 0):
    def train_step(state: TrainState, batch):
        def loss_fn(p):
            if loss_chunks:
                return chunked_lm_loss(model, p, batch,
                                       n_chunks=loss_chunks, remat=remat)
            return lm_loss(model, p, batch, remat=remat)
        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(state.params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = cosine_schedule(state.opt.step, peak_lr=peak_lr,
                             warmup_steps=warmup_steps,
                             total_steps=total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=weight_decay)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": lr}
        return TrainState(params, opt), metrics

    return train_step
