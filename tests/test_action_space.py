"""Property tests for the combinatorial action mapping tau (paper Eq. 3-4)."""
import numpy as np
import pytest

pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.action_space import (codebook, k_nearest, nearest_in_codebook,
                                     threshold_map, wolpertinger_select)


@given(st.integers(2, 10),
       st.lists(st.floats(0.0, 1.0, allow_nan=False), min_size=2,
                max_size=10))
@settings(max_examples=200, deadline=None)
def test_threshold_map_is_exact_nearest_neighbour(n, vals):
    """threshold_map == brute-force argmin over the enumerated codebook."""
    vals = (vals + [0.5] * n)[:n]
    proto = jnp.asarray(vals, jnp.float32)
    fast = np.asarray(threshold_map(proto))
    cb = codebook(n)
    d = np.sum((cb - np.asarray(proto)[None]) ** 2, axis=1)
    best = d.min()
    # the fast answer must be valid, binary, nonzero, and distance-optimal
    assert fast.shape == (n,)
    assert set(np.unique(fast)).issubset({0.0, 1.0})
    assert fast.sum() >= 1
    fast_d = np.sum((fast - np.asarray(proto)) ** 2)
    assert fast_d <= best + 1e-6


@given(st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_codebook_enumerates_all_nonzero_vectors(n):
    cb = codebook(n)
    assert cb.shape == (2 ** n - 1, n)
    assert not np.any(np.all(cb == 0, axis=1))
    assert len(np.unique(cb, axis=0)) == 2 ** n - 1


def test_threshold_map_batched():
    protos = jnp.asarray([[0.9, 0.1, 0.6], [0.1, 0.2, 0.3]])
    out = np.asarray(threshold_map(protos))
    assert out.tolist() == [[1.0, 0.0, 1.0], [0.0, 0.0, 1.0]]


def test_nearest_in_codebook_matches_threshold():
    rng = np.random.default_rng(0)
    protos = rng.random((50, 6)).astype(np.float32)
    for p in protos:
        a = np.asarray(threshold_map(jnp.asarray(p)))
        b = np.asarray(nearest_in_codebook(jnp.asarray(p), 6))
        da = np.sum((a - p) ** 2)
        db = np.sum((b - p) ** 2)
        assert abs(da - db) < 1e-6


def test_wolpertinger_prefers_higher_q():
    # Q prefers exactly the vector [0,1,0]; with k covering the space the
    # re-ranked pick must be it even though the proto is near [1,0,0]
    target = jnp.asarray([0.0, 1.0, 0.0])

    def q_fn(_s, actions):
        return -jnp.sum((actions - target) ** 2, axis=-1)
    proto = jnp.asarray([0.9, 0.2, 0.1])
    a = wolpertinger_select(proto, jnp.zeros(4), q_fn, k=7)
    assert np.asarray(a).tolist() == [0.0, 1.0, 0.0]


def test_k_nearest_sorted_by_distance():
    proto = jnp.asarray([0.8, 0.2, 0.55])
    cand = np.asarray(k_nearest(proto, 3, 4))
    d = np.sum((cand - np.asarray(proto)[None]) ** 2, axis=1)
    assert np.all(np.diff(d) >= -1e-6)
