"""Per-architecture smoke tests: reduced variant, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ARCH_IDS, ShapeConfig, get_arch
from repro.data.pipeline import batch_for
from repro.models.model import build_model
from repro.training.train_step import init_train_state, make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _reduced(aid):
    cfg = get_arch(aid).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    return cfg


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_forward_shapes_no_nan(aid):
    cfg = _reduced(aid)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in batch_for(cfg, SMOKE_SHAPE, seed=1).items()}
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_one_train_step(aid):
    cfg = _reduced(aid)
    model = build_model(cfg, dtype=jnp.float32)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model))
    batch = {k: jnp.asarray(v)
             for k, v in batch_for(cfg, SMOKE_SHAPE, seed=2).items()}
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # at least one parameter leaf actually changed
    changed = any(
        not np.array_equal(np.asarray(p0), np.asarray(p1))
        for p0, p1 in zip(jax.tree.leaves(state.params),
                          jax.tree.leaves(state2.params)))
    assert changed


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_param_count_analytic_close(aid):
    """Analytic param_count tracks the real reduced-model param count."""
    cfg = _reduced(aid)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    real = sum(p.size for p in jax.tree.leaves(params))
    approx = cfg.param_count()
    assert 0.5 < approx / real < 2.0, (approx, real)


def test_full_configs_match_assignment():
    spec = {
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for aid, (L, d, H, K, ff, V) in spec.items():
        cfg = get_arch(aid)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == \
            (L, d, H, K, ff, V), aid
    # MoE / SSM / structural details
    assert get_arch("olmoe-1b-7b").moe.num_experts == 64
    assert get_arch("olmoe-1b-7b").moe.top_k == 8
    ds = get_arch("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    assert get_arch("mamba2-370m").ssm.d_state == 128
    assert get_arch("zamba2-2.7b").ssm.d_state == 64
    assert get_arch("zamba2-2.7b").shared_attn_every == 6
    assert get_arch("llama-3.2-vision-11b").cross_attn_every == 5
    assert get_arch("seamless-m4t-medium").encoder_layers == 12
    assert get_arch("qwen1.5-110b").qkv_bias
    assert not get_arch("command-r-plus-104b").qkv_bias
