"""AsyncFederationService: parity with the synchronous service, cache
shard integrity under concurrent clients, and exact cost accounting.

Parity: with ``max_batch=1, workers=1`` every request is its own flush
through the same single-state act path as ``FederationService.handle``,
so results must be identical — detections, action, cost, latency.

Concurrency: N client threads submit interleaved request streams; the
sharded subset-evaluation caches must stay partitioned (every image in
shard s satisfies ``img % W == s``, no duplicates across shards) and the
summed cost must equal the synchronous reference total exactly.
"""
import threading

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.sac import SAC, SACConfig
from repro.ensemble.boxes import Detections
from repro.federation.env import ArmolEnv
from repro.federation.evaluation import ShardedSubsetEvaluationCore
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving.async_service import AsyncFederationService
from repro.serving.federation_service import FederationService

TR = generate_traces(default_providers(), 40, seed=5)
ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)


class FixedAgent:
    """Always selects the same subset (batched-aware, like the real ones)."""

    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _sac():
    return SAC(SACConfig(state_dim=ENV.state_dim,
                         n_providers=ENV.n_providers, hidden=(16, 16)))


def _assert_results_equal(got, ref):
    np.testing.assert_array_equal(got.action, ref.action)
    assert got.cost_milli_usd == ref.cost_milli_usd
    assert got.latency_ms == ref.latency_ms
    np.testing.assert_array_equal(got.detections.boxes, ref.detections.boxes)
    np.testing.assert_array_equal(got.detections.scores,
                                  ref.detections.scores)
    np.testing.assert_array_equal(got.detections.labels,
                                  ref.detections.labels)


def test_parity_with_handle_max_batch_1():
    """max_batch=1, workers=1 is result-identical to the sync service on a
    fixed trace, with a real (deterministic) agent."""
    agent = _sac()
    svc = FederationService(ENV, agent)
    imgs = [int(i) for i in
            np.random.default_rng(3).integers(0, len(TR), 30)]
    refs = [svc.handle(i) for i in imgs]
    with AsyncFederationService(ENV, agent, max_batch=1,
                                workers=1) as asvc:
        for img, ref in zip(imgs, refs):
            _assert_results_equal(asvc.handle(img), ref)


def test_batched_flush_matches_sync_service():
    """Full flushes (padded batched forward + shard fan-out) agree with
    the synchronous reference for a fixed-action agent."""
    agent = FixedAgent([1, 0, 1])
    svc = FederationService(ENV, agent)
    imgs = list(range(len(TR))) * 2
    with AsyncFederationService(ENV, agent, max_batch=8, workers=3,
                                max_wait_ms=50.0) as asvc:
        got = asvc.handle_many(imgs)
    for img, res in zip(imgs, got):
        _assert_results_equal(res, svc.handle(img))


def test_empty_selection_is_zero_cost_zero_latency():
    with AsyncFederationService(ENV, FixedAgent([0, 0, 0]), max_batch=4,
                                workers=2) as asvc:
        res = asvc.handle(5)
    assert len(res.detections) == 0
    np.testing.assert_array_equal(res.detections.boxes,
                                  Detections.empty().boxes)
    assert res.cost_milli_usd == 0.0
    assert res.latency_ms == 0.0


def test_concurrent_clients_shard_integrity_and_accounting():
    workers = 3
    agent = FixedAgent([0, 1, 1])
    svc = FederationService(ENV, agent)
    rng = np.random.default_rng(11)
    streams = [[int(i) for i in rng.integers(0, len(TR), 60)]
               for _ in range(4)]
    collected = [None] * len(streams)

    with AsyncFederationService(ENV, agent, max_batch=8, workers=workers,
                                max_wait_ms=1.0) as asvc:
        def client(k):
            futs = [asvc.submit(i) for i in streams[k]]
            collected[k] = [f.result() for f in futs]

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(len(streams))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        shard_images = asvc.core.shard_images()
        cache_total = asvc.core.cache_sizes()

    # no cache corruption: every cached image sits in its home shard only
    for sid, imgs in enumerate(shard_images):
        assert all(i % workers == sid for i in imgs), (sid, imgs)
    all_cached = [i for imgs in shard_images for i in imgs]
    assert len(all_cached) == len(set(all_cached))       # no duplicates
    assert set(all_cached) == {i for s in streams for i in s}
    assert cache_total["tables"] == len(set(all_cached))

    # exact accounting: per-request results and totals match the sync path
    for k, stream in enumerate(streams):
        for img, res in zip(stream, collected[k]):
            _assert_results_equal(res, svc.handle(img))
    got_total = sum(r.cost_milli_usd for res in collected for r in res)
    want_total = sum(svc.handle(i).cost_milli_usd
                     for s in streams for i in s)
    assert got_total == want_total


def test_sharded_core_partition_and_delegation():
    core = ShardedSubsetEvaluationCore.like(ENV.core, 4)
    groups = core.partition([0, 1, 2, 3, 4, 5, 8, 9])
    assert groups == {0: [0, 4, 8], 1: [1, 5, 9], 2: [2], 3: [3]}
    mask = core.mask_of(np.asarray([1, 1, 0], np.float32))
    ref = ENV.core.ensemble(6, mask)
    got = core.ensemble(6, mask)
    np.testing.assert_array_equal(got.boxes, ref.boxes)
    assert core.cost(mask) == ENV.core.cost(mask)
    assert core.ap50(6, mask) == ENV.core.ap50(6, mask)
    sizes = core.cache_sizes()
    assert sizes["tables"] == 1 and sizes["ensembles"] >= 1
    assert core.shard_images()[6 % 4] == [6]


def test_submit_after_close_raises():
    asvc = AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=2,
                                  workers=1)
    assert asvc.handle(0).cost_milli_usd == ENV.costs[0]
    asvc.close()
    with pytest.raises(RuntimeError):
        asvc.submit(1)
    asvc.close()        # idempotent


def test_adaptive_default_off_keeps_fixed_deadline():
    """Parity guard for the default: with ``adaptive=False`` (the
    default) the flush deadline is EXACTLY the seed behavior — enqueue
    time + max_wait, independent of queue depth."""
    asvc = AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=8,
                                  max_wait_ms=10.0, workers=1)
    try:
        assert asvc.adaptive is False
        t0 = 100.0
        want = t0 + asvc.max_wait_s
        for depth in (0, 1, 4, 7, 8, 100):
            assert asvc._flush_deadline(t0, depth) == want
    finally:
        asvc.close()


def test_adaptive_deadline_shrinks_with_depth():
    asvc = AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=8,
                                  max_wait_ms=10.0, workers=1,
                                  adaptive=True)
    try:
        t0 = 100.0
        d = [asvc._flush_deadline(t0, k) for k in range(9)]
        assert all(a >= b for a, b in zip(d, d[1:]))     # monotone down
        assert d[0] == t0 + asvc.max_wait_s              # idle: full wait
        assert d[8] == t0                                # full: flush now
        assert asvc._flush_deadline(t0, 100) == t0       # clamps
    finally:
        asvc.close()


def test_adaptive_service_results_match_sync_reference():
    agent = FixedAgent([1, 1, 0])
    svc = FederationService(ENV, agent)
    imgs = [int(i) for i in
            np.random.default_rng(7).integers(0, len(TR), 50)]
    with AsyncFederationService(ENV, agent, max_batch=8, workers=2,
                                max_wait_ms=5.0, adaptive=True) as asvc:
        got = asvc.handle_many(imgs)
    for img, res in zip(imgs, got):
        _assert_results_equal(res, svc.handle(img))


def test_queued_requests_drain_on_close():
    """close() must flush requests already queued, not drop them."""
    asvc = AsyncFederationService(ENV, FixedAgent([1, 1, 0]),
                                  max_batch=64, max_wait_ms=10_000.0,
                                  workers=2)
    futs = [asvc.submit(i) for i in range(10)]
    asvc.close()        # deadline far away: close triggers the flush
    for f in futs:
        assert f.result(timeout=5).cost_milli_usd == pytest.approx(
            float(ENV.costs[0] + ENV.costs[1]))
    # the counter proves WHY the flush fired: the drain path, not the
    # 10-second timer racing the test
    assert asvc.stats["flush_drain"] >= 1
    assert asvc.stats["flush_timeout"] == 0
    assert asvc.stats["requests"] == 10


def test_flush_reason_counters_full_vs_timeout():
    """Flush-deadline behavior asserted via the flush-reason counters —
    no wall-clock sleeps, no dependence on how fast this machine runs.

    With a 10-second deadline, a burst of 3*max_batch requests can only
    leave the queue by filling it (``flush_full``); a lone request can
    only leave through its deadline (``flush_timeout``), however long the
    scheduler takes to get there."""
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=4,
                                max_wait_ms=10_000.0, workers=2) as asvc:
        asvc.handle_many(list(range(12)))       # 3 batch-filling flushes
        assert asvc.stats["flush_full"] == 3
        assert asvc.stats["flush_timeout"] == 0
        assert asvc.stats["flushes"] == 3
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=4,
                                max_wait_ms=1.0, workers=2) as asvc:
        asvc.handle(0)                          # can never fill the batch
        assert asvc.stats["flush_timeout"] == 1
        assert asvc.stats["flush_full"] == 0
        assert asvc.stats["flush_drain"] == 0


def test_reset_stats_zeroes_flush_reasons():
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=2,
                                workers=1) as asvc:
        asvc.handle_many([0, 1, 2, 3])
        asvc.reset_stats()
        assert all(v == 0 for v in asvc.stats.values())
