"""Device-resident replay buffer: parity with the numpy buffer, jax-mode
determinism, empty-buffer guards, and bit-identical device-path drivers.

The load-bearing property is BIT-parity: ``DeviceReplayBuffer`` in
``index_mode="host"`` consumes the same ``np.random.default_rng`` stream
as the numpy ``ReplayBuffer``, and device gathers are pure selection
(exact for float32), so every field, pointer, and sampled batch must
match the numpy buffer bitwise — which is what lets the device-path
driver tests pin against the frozen sequential references.
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.device_replay import DeviceReplayBuffer  # noqa: E402
from repro.core.replay_buffer import ReplayBuffer  # noqa: E402

CAP, D, A = 16, 5, 3


def _pair(seed=7, **kw):
    return (ReplayBuffer(CAP, D, A, seed=seed),
            DeviceReplayBuffer(CAP, D, A, seed=seed, index_mode="host",
                               **kw))


def _assert_same(h, d, ctx=""):
    for f in ("state", "action", "reward", "next_state", "done"):
        assert np.array_equal(getattr(h, f), getattr(d, f)), (ctx, f)
    assert h.ptr == d.ptr and h.size == d.size and len(h) == len(d), ctx


def _rows(rng, B):
    return (rng.normal(size=(B, D)), rng.normal(size=(B, A)),
            rng.normal(size=B), rng.normal(size=(B, D)),
            rng.integers(0, 2, size=B).astype(float))


# ---------------------------------------------------------------------------
# write parity
# ---------------------------------------------------------------------------

def test_interleaved_writes_bit_parity():
    """Scalar adds and batch writes interleaved, wraparound and
    B > capacity included, leave both buffers bitwise identical."""
    rng = np.random.default_rng(0)
    h, d = _pair()
    for B in (1, 4, 7, 1, 2 * CAP + 1, 5, CAP, 3, 1):
        if B == 1:
            s, a, r, s2, dn = (x[0] for x in _rows(rng, 1))
            h.add(s, a, r, s2, dn)
            d.add(s, a, r, s2, dn)
        else:
            s, a, r, s2, dn = _rows(rng, B)
            h.add_batch(s, a, r, s2, dn)
            d.add_batch(s, a, r, s2, dn)
        _assert_same(h, d, ctx=f"B={B}")


def test_batch_matches_scalar_loop():
    """One add_batch == the same rows added one by one (the numpy
    buffer's own contract, re-pinned on the device buffer)."""
    rng = np.random.default_rng(1)
    d1 = DeviceReplayBuffer(CAP, D, A, seed=0)
    d2 = DeviceReplayBuffer(CAP, D, A, seed=0)
    s, a, r, s2, dn = _rows(rng, CAP + 5)
    d1.add_batch(s, a, r, s2, dn)
    for i in range(CAP + 5):
        d2.add(s[i], a[i], r[i], s2[i], dn[i])
    _assert_same(d1, d2)


def test_indexed_writes_match_table_gather():
    """add_batch_indexed(s_idx, ...) == add_batch(table[s_idx], ...):
    on-device feature assembly is bitwise identical to host gathers."""
    rng = np.random.default_rng(2)
    table = np.asarray(rng.normal(size=(30, D)), np.float32)
    h = ReplayBuffer(CAP, D, A, seed=1)
    d = DeviceReplayBuffer(CAP, D, A, seed=1, index_mode="host",
                           feature_table=table)
    assert d.indexed
    for B in (5, 12, 9, 2 * CAP + 3):    # wraps + B > capacity
        si = rng.integers(0, 30, size=B)
        s2i = rng.integers(0, 30, size=B)
        a = np.asarray(rng.normal(size=(B, A)), np.float32)
        r = np.asarray(rng.normal(size=B), np.float32)
        dn = rng.integers(0, 2, size=B).astype(np.float32)
        h.add_batch(table[si], a, r, table[s2i], dn)
        d.add_batch_indexed(si, a, r, s2i, dn)
        _assert_same(h, d, ctx=f"B={B}")


def test_indexed_requires_table():
    d = DeviceReplayBuffer(CAP, D, A)
    assert not d.indexed
    with pytest.raises(ValueError, match="feature_table"):
        d.add_batch_indexed([0], np.zeros((1, A)), [0.0], [0], [0.0])


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _fill(*bufs, n=10):
    rng = np.random.default_rng(3)
    s, a, r, s2, dn = _rows(rng, n)
    for b in bufs:
        b.add_batch(s, a, r, s2, dn)


def test_host_mode_sample_stream_parity():
    """Host index mode consumes the numpy buffer's exact rng stream:
    sample() and sample_block() return bitwise-equal batches."""
    h, d = _pair(seed=11)
    _fill(h, d)
    for _ in range(4):
        bh, bd = h.sample(6), d.sample(6)
        assert set(bh) == set(bd)
        for k in bh:
            assert np.array_equal(np.asarray(bh[k]), np.asarray(bd[k])), k
    bh, bd = h.sample_block(3, 5), d.sample_block(3, 5)
    for k in bh:
        assert np.array_equal(np.asarray(bh[k]), np.asarray(bd[k])), k


def test_jax_mode_deterministic_and_in_range():
    """Same seed + same call sequence -> identical blocks; drawn rows
    all come from stored (not zero-padded) slots."""
    d1 = DeviceReplayBuffer(CAP, D, A, seed=3, index_mode="jax")
    d2 = DeviceReplayBuffer(CAP, D, A, seed=3, index_mode="jax")
    rng = np.random.default_rng(4)
    s = rng.normal(size=(10, D))
    rows = (s, rng.normal(size=(10, A)), np.arange(1.0, 11.0),
            rng.normal(size=(10, D)), np.zeros(10))
    for b in (d1, d2):
        b.add_batch(*rows)
    b1, b2 = d1.sample_block(4, 8), d2.sample_block(4, 8)
    for k in b1:
        assert np.array_equal(np.asarray(b1[k]), np.asarray(b2[k])), k
    # rewards were 1..10 over the filled slots: a draw outside the valid
    # prefix would surface a 0.0 from the zero-initialized storage
    assert np.asarray(b1["r"]).min() >= 1.0
    s1, s2_ = d1.sample(8), d2.sample(8)
    for k in s1:
        assert np.array_equal(np.asarray(s1[k]), np.asarray(s2_[k])), k


def test_bad_index_mode_rejected():
    with pytest.raises(ValueError, match="index_mode"):
        DeviceReplayBuffer(CAP, D, A, index_mode="device")


# ---------------------------------------------------------------------------
# empty-buffer guard (regression: both buffers used to return garbage
# batches gathered from the zero-initialized storage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [
    lambda: ReplayBuffer(CAP, D, A),
    lambda: DeviceReplayBuffer(CAP, D, A, index_mode="jax"),
    lambda: DeviceReplayBuffer(CAP, D, A, index_mode="host"),
], ids=["numpy", "device-jax", "device-host"])
def test_empty_sample_raises(mk):
    buf = mk()
    with pytest.raises(ValueError, match="empty replay buffer"):
        buf.sample(4)
    with pytest.raises(ValueError, match="empty replay buffer"):
        buf.sample_block(2, 4)


@pytest.mark.slow
def test_driver_warmup_guard_names_empty_buffer():
    """A buffer that silently drops writes makes the first scheduled
    update hit an empty buffer: the multi-lane driver must fail with the
    clear empty-buffer message, not sample garbage."""
    from repro.core.loops import run_off_policy
    from repro.core.sac import SAC, SACConfig
    from repro.federation.env import ArmolEnv
    from repro.federation.providers import default_providers
    from repro.federation.traces import generate_traces

    class DroppingBuffer(ReplayBuffer):
        def add_batch(self, *a, **kw):
            pass

    tr = generate_traces(default_providers(), 20, seed=0)
    env = ArmolEnv(tr, mode="gt", beta=-0.03, seed=3)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, seed=0))
    buf = DroppingBuffer(100, env.state_dim, env.n_providers, seed=5)
    with pytest.raises(ValueError, match="empty replay buffer"):
        run_off_policy(agent, env, lanes=4, buffer=buf, epochs=1,
                       steps_per_epoch=20, batch_size=8, start_steps=4,
                       update_after=4, update_every=8, update_iters=2,
                       log=None, seed=5)


# ---------------------------------------------------------------------------
# property-based parity (skipped where hypothesis is not installed)
# ---------------------------------------------------------------------------

def test_hypothesis_interleaved_parity():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=2 * CAP + 5),
                    min_size=1, max_size=6),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    def run(batch_sizes, seed):
        rng = np.random.default_rng(seed)
        h, d = _pair(seed=seed % 1000)
        for B in batch_sizes:
            s, a, r, s2, dn = _rows(rng, B)
            if B == 1 and rng.integers(2):
                h.add(s[0], a[0], r[0], s2[0], dn[0])
                d.add(s[0], a[0], r[0], s2[0], dn[0])
            else:
                h.add_batch(s, a, r, s2, dn)
                d.add_batch(s, a, r, s2, dn)
            _assert_same(h, d, ctx=f"B={B}")
        bh, bd = h.sample_block(2, 4), d.sample_block(2, 4)
        for k in bh:
            assert np.array_equal(np.asarray(bh[k]), np.asarray(bd[k]))

    run()
