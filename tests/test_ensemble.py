"""Ensemble stage: voting, NMS/Soft-NMS/WBF, pipeline invariants."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.ensemble.ablation import nms, soft_nms, wbf
from repro.ensemble.boxes import Detections, iou_matrix
from repro.ensemble.pipeline import PATHWAYS, ensemble_detections
from repro.ensemble.voting import group_detections, vote_filter


def _dets(boxes, scores=None, labels=None, providers=None):
    n = len(boxes)
    return Detections(np.asarray(boxes, np.float32),
                      np.ones(n, np.float32) if scores is None else scores,
                      np.zeros(n, np.int32) if labels is None else labels,
                      providers)


BOX = [0.2, 0.2, 0.6, 0.6]
NEAR = [0.22, 0.21, 0.61, 0.59]          # IoU with BOX > 0.5
FAR = [0.7, 0.7, 0.95, 0.95]


def test_iou_matrix_basics():
    m = iou_matrix(np.asarray([BOX]), np.asarray([BOX, FAR]))
    assert m[0, 0] == pytest.approx(1.0)
    assert m[0, 1] == pytest.approx(0.0)


def test_grouping_same_label_high_iou():
    d = _dets([BOX, NEAR, FAR], labels=np.asarray([1, 1, 1], np.int32))
    groups = group_detections(d)
    sizes = sorted(len(g) for g in groups)
    assert sizes == [1, 2]


def test_grouping_label_mismatch_blocks_merge():
    d = _dets([BOX, NEAR], labels=np.asarray([1, 2], np.int32))
    groups = group_detections(d)
    assert len(groups) == 2


def test_vote_filters():
    d = _dets([BOX, NEAR, FAR],
              labels=np.asarray([1, 1, 1], np.int32),
              providers=np.asarray([0, 1, 0], np.int32))
    groups = group_detections(d)
    aff = vote_filter(d, groups, method="affirmative", n_selected=2)
    con = vote_filter(d, groups, method="consensus", n_selected=2)
    una = vote_filter(d, groups, method="unanimous", n_selected=2)
    assert len(aff) == 2
    # the 2-member group has 2 distinct providers -> consensus+unanimous keep
    assert len(con) == 1 and len(una) == 1
    assert len(con[0]) == 2


def test_nms_keeps_top_score():
    sc = np.asarray([0.9, 0.8, 0.7], np.float32)
    d = _dets([BOX, NEAR, FAR], scores=sc, labels=np.zeros(3, np.int32))
    out = nms(d, iou_thr=0.5)
    assert len(out) == 2
    assert 0.9 in out.scores and 0.7 in out.scores and 0.8 not in out.scores


def test_soft_nms_decays_not_deletes():
    sc = np.asarray([0.9, 0.8], np.float32)
    d = _dets([BOX, NEAR], scores=sc, labels=np.zeros(2, np.int32))
    out = soft_nms(d)
    assert len(out) == 2
    assert out.scores.min() < 0.8          # decayed


def test_wbf_fuses_group_weighted():
    sc = np.asarray([0.9, 0.1], np.float32)
    d = _dets([BOX, NEAR], scores=sc, labels=np.zeros(2, np.int32),
              providers=np.asarray([0, 1], np.int32))
    groups = group_detections(d)
    assert len(groups) == 1
    out = wbf(d, groups)
    assert len(out) == 1
    # fused box closer to the high-confidence member
    assert np.sum(np.abs(out.boxes[0] - np.asarray(BOX))) < \
        np.sum(np.abs(out.boxes[0] - np.asarray(NEAR)))
    assert out.scores[0] == pytest.approx(0.5, abs=1e-6)


def test_wbf_rescale_downweights_single_provider():
    sc = np.asarray([0.9, 0.9, 0.9], np.float32)
    d = _dets([BOX, NEAR, FAR], scores=sc, labels=np.zeros(3, np.int32),
              providers=np.asarray([0, 1, 0], np.int32))
    groups = group_detections(d)
    out = wbf(d, groups, n_models=2)
    by_score = sorted(out.scores)
    assert by_score[0] == pytest.approx(0.45)    # lone FAR box: 0.9 * 1/2
    assert by_score[1] == pytest.approx(0.9)     # 2-provider consensus


def test_all_12_pathways_run():
    per_provider = [
        _dets([BOX, FAR], scores=np.asarray([0.8, 0.6], np.float32),
              labels=np.asarray([1, 2], np.int32)),
        _dets([NEAR], scores=np.asarray([0.7], np.float32),
              labels=np.asarray([1], np.int32)),
    ]
    assert len(PATHWAYS) == 12
    for voting, ablation in PATHWAYS:
        out = ensemble_detections(per_provider, voting=voting,
                                  ablation=ablation)
        assert len(out) <= 3


def test_pipeline_kernel_path_matches_numpy_path():
    rng = np.random.default_rng(3)
    boxes = rng.random((12, 4)).astype(np.float32)
    boxes[:, 2:] = boxes[:, :2] + 0.2
    per_provider = [
        _dets(boxes[:6], scores=rng.random(6).astype(np.float32),
              labels=(rng.integers(0, 3, 6)).astype(np.int32)),
        _dets(boxes[6:], scores=rng.random(6).astype(np.float32),
              labels=(rng.integers(0, 3, 6)).astype(np.int32)),
    ]
    a = ensemble_detections(per_provider, use_kernel=False)
    b = ensemble_detections(per_provider, use_kernel=True)
    assert len(a) == len(b)
    np.testing.assert_allclose(a.boxes, b.boxes, atol=1e-6)
    np.testing.assert_allclose(a.scores, b.scores, atol=1e-6)


@given(st.integers(1, 5), st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_ensemble_count_invariant(n_prov, n_det):
    """Output detections never exceed total input detections."""
    rng = np.random.default_rng(n_prov * 100 + n_det)
    per_provider = []
    for _ in range(n_prov):
        b = rng.random((n_det, 4)).astype(np.float32)
        b[:, 2:] = b[:, :2] + rng.random((n_det, 2)).astype(np.float32) * 0.3
        per_provider.append(_dets(
            b, scores=rng.random(n_det).astype(np.float32),
            labels=rng.integers(0, 4, n_det).astype(np.int32)))
    out = ensemble_detections(per_provider)
    assert len(out) <= n_prov * n_det
