"""Trace substrate + environment tests (reward semantics per Eq. 5)."""
import numpy as np
import pytest

from repro.core.loops import (ensembleN_policy, evaluate_policy,
                              random1_policy, upper_bound)
from repro.ensemble.metrics import ap50
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers, \
    scalability_providers
from repro.federation.traces import generate_traces

TR = generate_traces(default_providers(), 120, seed=0)


def test_traces_deterministic():
    t2 = generate_traces(default_providers(), 120, seed=0)
    np.testing.assert_array_equal(TR.images, t2.images)
    for a, b in zip(TR.dets[5], t2.dets[5]):
        np.testing.assert_array_equal(a.boxes, b.boxes)


def test_trace_shapes():
    assert TR.images.shape == (120, 48, 48, 3)
    assert TR.n_providers == 3
    assert len(TR.dets[0]) == 3


def test_word_grouping_applied():
    """Raw words include dialect synonyms; canonical dets only template ids."""
    saw_synonym = False
    for img in range(30):
        for p, raw in enumerate(TR.raw[img]):
            for w in raw.words:
                if w in ("automobile", "mug", "sofa", "human", "table"):
                    saw_synonym = True
        for d in TR.dets[img]:
            assert np.all(d.labels >= 0) and np.all(d.labels < 80)
    assert saw_synonym


def test_aws_blind_spots():
    """AWS never reports bottle/cup/dining-table (paper Fig. 1)."""
    blind_ids = {39, 41, 60}   # bottle, cup, dining table template indices
    from repro.federation.vocab import COCO_TEMPLATE
    blind_ids = {COCO_TEMPLATE.index(c)
                 for c in ("bottle", "cup", "dining table")}
    for img in range(len(TR)):
        aws = TR.dets[img][0]
        gt_present = set(TR.gts[img].labels.tolist())
        # AWS may emit a blind category only as a mislabelled FP; TPs are
        # impossible. Check: no high-IoU match between an AWS blind-label box
        # and a GT box of that category.
        for bid in blind_ids & gt_present:
            from repro.ensemble.boxes import iou_matrix
            gt_boxes = TR.gts[img].boxes[TR.gts[img].labels == bid]
            aws_boxes = aws.boxes[aws.labels == bid]
            if len(aws_boxes) and len(gt_boxes):
                assert iou_matrix(aws_boxes, gt_boxes).max() < 0.5


ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=3)


def test_reward_empty_selection_is_minus_one():
    # provider with no detections on some image: force via azure-only on an
    # image where azure returned nothing
    for img in range(len(TR)):
        if len(TR.dets[img][1]) == 0:
            r, v, c = ENV.evaluate_action(img, np.asarray([0, 1, 0.],
                                                          np.float32))
            assert r == -1.0 and v == 0.0
            return
    pytest.skip("azure returned detections on every trace image")


def test_reward_beta_cost_tradeoff():
    env_b = ArmolEnv(TR, mode="gt", beta=-0.1, seed=3)
    img = int(env_b.train_idx[0])
    r0, v0, c0 = ENV.evaluate_action(img, np.ones(3, np.float32))
    r1, v1, c1 = env_b.evaluate_action(img, np.ones(3, np.float32))
    assert c0 == c1 == 3.0
    if r0 != -1.0:
        assert r1 == pytest.approx(r0 - 0.3)


def test_env_episode_mechanics():
    s = ENV.reset(split="train")
    assert s.shape == (ENV.state_dim,)
    n = len(ENV.train_idx)
    done = False
    steps = 0
    while not done and steps < n + 1:
        _, _, done, info = ENV.step(np.ones(3, np.float32))
        steps += 1
    assert steps == n and done


def test_nogt_uses_pseudo_ground_truth():
    env = ArmolEnv(TR, mode="nogt", beta=0.0, seed=3)
    img = int(env.train_idx[0])
    pseudo = env.pseudo_gt(img)
    r, v, c = env.evaluate_action(img, np.ones(3, np.float32))
    # evaluating the all-provider ensemble against itself -> near-perfect AP
    if len(pseudo) > 0:
        assert v > 0.9


def test_evaluate_policy_and_upper_bound_ordering():
    res_r1 = evaluate_policy(random1_policy(ENV, seed=0), ENV)
    res_all = evaluate_policy(ensembleN_policy(ENV), ENV)
    ub = upper_bound(ENV)
    assert res_all["cost"] == pytest.approx(3.0)
    assert res_r1["cost"] == pytest.approx(1.0)
    # paper ordering: UB >= EnsembleN > Random-1 (corpus AP50)
    assert ub["ap50"] >= res_all["ap50"] - 3.0
    assert res_all["ap50"] > res_r1["ap50"]
    assert ub["cost"] < 2.0


def test_scalability_providers_profile():
    provs = scalability_providers()
    assert len(provs) == 10
    recs = [p.base_recall for p in provs]
    assert max(recs) == recs[5]              # MLaaS 5 dominates (Tab. III)
