"""FederationService accounting: cost = sum of selected provider fees,
latency = transmission_ms * |sel| + max(selected provider latencies)
(sequential transmission, parallel inference — paper Sec. II-B), and the
empty-selection path returns Detections.empty().  handle_many must agree
with per-request handle."""
import numpy as np
import pytest

from repro.ensemble.boxes import Detections
from repro.ensemble.pipeline import ensemble_detections
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving.federation_service import FederationService

TR = generate_traces(default_providers(), 40, seed=5)
N = TR.n_providers
ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)


class FixedAgent:
    """Always selects the same subset (batched-aware, like the real ones)."""

    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


@pytest.mark.parametrize("action", [[1, 0, 0], [0, 1, 1], [1, 1, 1]])
def test_cost_and_latency_accounting(action):
    svc = FederationService(ENV, FixedAgent(action), transmission_ms=20.0)
    res = svc.handle(3)
    sel = np.where(np.asarray(action) > 0.5)[0]
    fees = sum(TR.providers[i].cost_milli_usd for i in sel)
    lat = 20.0 * len(sel) + max(TR.providers[i].latency_ms for i in sel)
    assert res.cost_milli_usd == pytest.approx(fees)
    assert res.latency_ms == pytest.approx(lat)
    np.testing.assert_array_equal(res.action, np.asarray(action, np.float32))


def test_empty_selection_returns_empty_detections():
    svc = FederationService(ENV, FixedAgent([0, 0, 0]))
    res = svc.handle(0)
    assert len(res.detections) == 0
    np.testing.assert_array_equal(res.detections.boxes,
                                  Detections.empty().boxes)
    assert res.cost_milli_usd == 0.0
    assert res.latency_ms == 0.0


def test_detections_match_direct_ensemble():
    svc = FederationService(ENV, FixedAgent([1, 0, 1]))
    res = svc.handle(7)
    ref = ensemble_detections([TR.dets[7][0], TR.dets[7][2]],
                              voting=ENV.voting, ablation=ENV.ablation)
    np.testing.assert_array_equal(res.detections.boxes, ref.boxes)
    np.testing.assert_array_equal(res.detections.scores, ref.scores)
    np.testing.assert_array_equal(res.detections.labels, ref.labels)


def test_handle_many_matches_handle():
    svc = FederationService(ENV, FixedAgent([0, 1, 1]))
    imgs = list(ENV.test_idx[:6])
    many = svc.handle_many(imgs)
    assert len(many) == 6
    for img, got in zip(imgs, many):
        ref = svc.handle(int(img))
        np.testing.assert_array_equal(got.action, ref.action)
        assert got.cost_milli_usd == ref.cost_milli_usd
        assert got.latency_ms == ref.latency_ms
        np.testing.assert_array_equal(got.detections.boxes,
                                      ref.detections.boxes)
        np.testing.assert_array_equal(got.detections.scores,
                                      ref.detections.scores)


def test_handle_many_empty_input():
    svc = FederationService(ENV, FixedAgent([1, 1, 1]))
    assert svc.handle_many([]) == []
