"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.iou_matrix.kernel import iou_matrix_pallas
from repro.kernels.iou_matrix.ref import iou_matrix_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_naive
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# IoU matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (7, 5), (33, 129), (128, 512),
                                 (130, 515)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_iou_kernel_shapes(m, n, dtype):
    a = RNG.random((m, 4)).astype(dtype)
    a[:, 2:] = a[:, :2] + RNG.random((m, 2)).astype(dtype)
    b = RNG.random((n, 4)).astype(dtype)
    b[:, 2:] = b[:, :2] + RNG.random((n, 2)).astype(dtype)
    got = iou_matrix_pallas(jnp.asarray(a, jnp.float32),
                            jnp.asarray(b, jnp.float32),
                            block_m=32, block_n=64, interpret=True)
    ref = iou_matrix_ref(jnp.asarray(a, jnp.float32),
                         jnp.asarray(b, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_iou_degenerate_boxes():
    a = np.asarray([[0.5, 0.5, 0.5, 0.5]], np.float32)   # zero area
    b = np.asarray([[0.0, 0.0, 1.0, 1.0]], np.float32)
    got = iou_matrix_pallas(jnp.asarray(a), jnp.asarray(b), interpret=True)
    assert float(got[0, 0]) == 0.0


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,hd,bq,bk", [(32, 16, 8, 8), (64, 32, 16, 32),
                                        (128, 64, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, hd, bq, bk, dtype, causal):
    B, H = 2, 3
    q = jnp.asarray(RNG.standard_normal((B, H, S, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, H, S, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, H, S, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("window", [8, 24])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 64, 16
    q = jnp.asarray(RNG.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, H, S, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, H, S, hd)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=16, block_k=16, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_gqa_wrapper():
    B, S, H, K, hd = 2, 32, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, K, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, K, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    kr = jnp.repeat(k, H // K, 2)
    vr = jnp.repeat(v, H // K, 2)
    ref = jnp.moveaxis(attention_ref(jnp.moveaxis(q, 2, 1),
                                     jnp.moveaxis(kr, 2, 1),
                                     jnp.moveaxis(vr, 2, 1), causal=True),
                       1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (128, 128)])
@pytest.mark.parametrize("nh,hd,N", [(2, 8, 4), (4, 16, 8)])
def test_ssd_kernel_sweep(S, chunk, nh, hd, N):
    B = 2
    xh = jnp.asarray(RNG.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, nh)) * 0.5 + 0.05, jnp.float32)
    A = -jnp.asarray(RNG.random((nh,)) * 0.9 + 0.3, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    naive = ssd_naive(xh, dt, A, Bm, Cm)
    kern = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(naive),
                               atol=5e-4)


def test_chunked_jnp_matches_naive():
    B, S, nh, hd, N = 1, 48, 2, 8, 4
    xh = jnp.asarray(RNG.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, nh)) * 0.4 + 0.05, jnp.float32)
    A = -jnp.ones((nh,), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, 12)
    naive = ssd_naive(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(naive), atol=5e-4)


def test_ssd_initial_state_continuation():
    """Running two halves with carried state == one full run."""
    B, S, nh, hd, N = 1, 64, 2, 8, 4
    xh = jnp.asarray(RNG.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, nh)) * 0.4 + 0.05, jnp.float32)
    A = -jnp.ones((nh,), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    y_full, st_full = ssd_chunked(xh, dt, A, Bm, Cm, 16)
    y1, st1 = ssd_chunked(xh[:, :32], dt[:, :32], A, Bm[:, :32],
                          Cm[:, :32], 16)
    y2, st2 = ssd_chunked(xh[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                          Cm[:, 32:], 16, initial_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               atol=5e-4)


# ---------------------------------------------------------------------------
# kernel dispatch: resolve_use_kernel + iou_matrix_op fallback
# ---------------------------------------------------------------------------

def test_resolve_use_kernel_rejects_bad_strings():
    """Regression: a typo like "atuo" used to silently resolve as truthy
    instead of failing loudly."""
    from repro.ensemble.pipeline import resolve_use_kernel
    for bad in ("atuo", "Auto", "yes", ""):
        with pytest.raises(ValueError, match="use_kernel"):
            resolve_use_kernel(bad)
    assert resolve_use_kernel("auto") == (jax.default_backend() != "cpu")
    assert resolve_use_kernel(True) is True
    assert resolve_use_kernel(False) is False


def test_iou_matrix_op_clamps_blocks_to_tiny_inputs():
    """Regression: default 128x128 blocks on a 3x2 problem used to reach
    the kernel with out-of-range tiles."""
    from repro.kernels.iou_matrix.ops import iou_matrix_op
    a = RNG.random((3, 4)).astype(np.float32)
    b = RNG.random((2, 4)).astype(np.float32)
    a[:, 2:] = a[:, :2] + 0.5
    b[:, 2:] = b[:, :2] + 0.5
    got = np.asarray(iou_matrix_op(a, b))           # default block sizes
    want = np.asarray(iou_matrix_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_iou_matrix_op_falls_back_on_lowering_failure():
    """When the Pallas kernel raises, the op must warn ONCE and return
    the numpy twin's result instead of propagating the error."""
    from repro.ensemble.boxes import iou_matrix
    from repro.kernels.iou_matrix import ops

    def boom(*a, **kw):
        raise RuntimeError("no pallas lowering for this backend")

    a = RNG.random((5, 4)).astype(np.float32)
    b = RNG.random((7, 4)).astype(np.float32)
    a[:, 2:] = a[:, :2] + 0.5
    b[:, 2:] = b[:, :2] + 0.5
    orig = ops.iou_matrix_pallas
    orig_flag = ops._FALLBACK_WARNED
    ops.iou_matrix_pallas, ops._FALLBACK_WARNED = boom, False
    try:
        with pytest.warns(RuntimeWarning, match="falling back"):
            got = np.asarray(ops.iou_matrix_op(a, b))
        # second call: same fallback result, but no repeat warning
        import warnings as _warnings
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            again = np.asarray(ops.iou_matrix_op(a, b))
    finally:
        ops.iou_matrix_pallas, ops._FALLBACK_WARNED = orig, orig_flag
    want = iou_matrix(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(again, want, rtol=1e-6, atol=1e-6)
