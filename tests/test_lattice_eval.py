"""Parity tests: the full-lattice pass (``evaluate_lattice``) must
reproduce the memoized per-bitmask path bit for bit — every subset's
fused detection arrays, AP50, and cost — across voting variants, both
references, empty ensembles, invalidation, and the sharded backends.

The lattice and loop answers are compared on SEPARATE cores so no memo
sharing can mask a divergence; the back-fill tests then check the
sharing on purpose.  A hypothesis-driven twin of this suite lives in
``test_lattice_eval_fuzz.py`` (random rosters and op orders).
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.env import ArmolEnv  # noqa: E402
from repro.federation.evaluation import (  # noqa: E402
    ShardedSubsetEvaluationCore, SubsetEvaluationCore, popcount_masks)
from repro.federation.providers import (  # noqa: E402
    ProviderProfile, default_providers, lattice_stress_providers)
from repro.federation.traces import generate_traces  # noqa: E402

TR3 = generate_traces(default_providers(), 12, seed=7)
TR6 = generate_traces(lattice_stress_providers(6), 6, seed=5)


def assert_lattice_matches_loop(lat_core, loop_core, img, *,
                                against="gt"):
    """Every row of the lattice == the per-bitmask path, bit for bit."""
    lat = lat_core.evaluate_lattice(img, against=against)
    masks = popcount_masks(loop_core.n_providers)
    assert lat.masks.tolist() == masks
    for m in masks:
        a = lat.detections(m)
        b = loop_core.ensemble(img, m)
        assert a.boxes.dtype == b.boxes.dtype
        assert a.scores.dtype == b.scores.dtype
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.providers, b.providers)
        assert lat.n_dets[lat.index_of(m)] == len(b)
        assert lat.ap_of(m) == loop_core.ap50(img, m, against=against)
        assert lat.cost[lat.index_of(m)] == loop_core.cost(m)


# ---------------------------------------------------------------------------
# row-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("against", ["gt", "pseudo"])
def test_all_rows_match_loop_n6(against):
    lat_core = SubsetEvaluationCore(TR6)
    loop_core = SubsetEvaluationCore(TR6)
    for img in range(len(TR6)):
        assert_lattice_matches_loop(lat_core, loop_core, img,
                                    against=against)


@pytest.mark.parametrize("voting", ["consensus", "unanimous"])
def test_voting_variants_match_loop(voting):
    lat_core = SubsetEvaluationCore(TR3, voting=voting)
    loop_core = SubsetEvaluationCore(TR3, voting=voting)
    for img in range(len(TR3)):
        assert_lattice_matches_loop(lat_core, loop_core, img)


def test_non_wbf_ablation_falls_back_and_matches():
    """Only the wbf fusion recipe is vectorized; other ablations must
    still answer — through the per-mask fallback — identically."""
    lat_core = SubsetEvaluationCore(TR3, ablation="nms")
    loop_core = SubsetEvaluationCore(TR3, ablation="nms")
    for img in range(4):
        assert_lattice_matches_loop(lat_core, loop_core, img)


def test_empty_and_silent_provider_rows():
    """Subsets of providers that detected nothing yield empty rows with
    AP 0 — same as the loop path — and a fully silent roster yields an
    all-empty lattice without tripping the vectorized pass."""
    mute = ProviderProfile(name="mute", base_recall=0.0, fp_rate=0.0)
    tr = generate_traces(default_providers() + [mute], 6, seed=3)
    lat_core = SubsetEvaluationCore(tr)
    loop_core = SubsetEvaluationCore(tr)
    mute_mask = 1 << 3
    for img in range(len(tr)):
        assert_lattice_matches_loop(lat_core, loop_core, img)
        lat = lat_core.evaluate_lattice(img)
        assert lat.n_dets[lat.index_of(mute_mask)] == 0
        assert len(lat.detections(mute_mask)) == 0
        assert lat.ap_of(mute_mask) == 0.0

    tr_silent = generate_traces([mute, mute.replace(name="mute2")], 3,
                                seed=3)
    lat = SubsetEvaluationCore(tr_silent).evaluate_lattice(0)
    assert lat.n_dets.sum() == 0
    assert np.all(lat.ap == 0.0)


# ---------------------------------------------------------------------------
# memo sharing: back-fill and invalidation
# ---------------------------------------------------------------------------

def test_lattice_backfills_per_mask_memo_as_hits():
    core = SubsetEvaluationCore(TR3)
    core.evaluate_lattice(0)
    misses = (core.stats["ens_misses"], core.stats["ap_misses"])
    ref = SubsetEvaluationCore(TR3)
    for m in popcount_masks(TR3.n_providers):
        assert core.ap50(0, m) == ref.ap50(0, m)
        a, b = core.ensemble(0, m), ref.ensemble(0, m)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
    # every per-mask answer came from the lattice: hits, not recomputes
    n_masks = len(popcount_masks(TR3.n_providers))
    assert (core.stats["ens_misses"], core.stats["ap_misses"]) == misses
    assert core.stats["ens_hits"] >= n_masks
    assert core.stats["ap_hits"] >= n_masks


def test_invalidate_drops_lattice_and_recomputes_identically():
    core = SubsetEvaluationCore(TR3)
    lat = core.evaluate_lattice(2)
    before = (lat.ap.copy(), lat.boxes.copy(), lat.offsets.copy())
    assert core.cache_sizes()["lattices"] == 1
    core.invalidate_images([2])
    # the lattice row AND its back-fill source are gone: a stale lattice
    # surviving here would resurrect dropped per-mask entries
    assert core.cache_sizes()["lattices"] == 0
    lat2 = core.evaluate_lattice(2)
    np.testing.assert_array_equal(lat2.ap, before[0])
    np.testing.assert_array_equal(lat2.boxes, before[1])
    np.testing.assert_array_equal(lat2.offsets, before[2])


def test_lattice_is_memoized_per_against():
    core = SubsetEvaluationCore(TR3)
    a = core.evaluate_lattice(1, against="gt")
    assert core.evaluate_lattice(1, against="gt") is a
    b = core.evaluate_lattice(1, against="pseudo")
    assert b is not a
    # the fused arrays are reference-independent and shared across the
    # two lattices; only the AP column differs
    assert b.boxes is a.boxes
    assert core.cache_sizes()["lattices"] == 2


def test_wire_roundtrip():
    lat = SubsetEvaluationCore(TR3).evaluate_lattice(0)
    from repro.federation.evaluation import LatticeResult
    back = LatticeResult.from_wire(lat.to_wire(), lat.against)
    np.testing.assert_array_equal(back.ap, lat.ap)
    np.testing.assert_array_equal(back.boxes, lat.boxes)
    assert back.detections(3).scores.tolist() == \
        lat.detections(3).scores.tolist()


# ---------------------------------------------------------------------------
# consumers: upper bound / oracle argmax over lattice rows
# ---------------------------------------------------------------------------

def test_argmax_row_equals_best_subset_scan():
    """popcount-order rows + first-occurrence argmax == the Algo.-2
    first-strict-improvement scan, including its cheapest-wins ties."""
    lat_core = SubsetEvaluationCore(TR6)
    loop_core = SubsetEvaluationCore(TR6)
    masks = popcount_masks(TR6.n_providers)
    for img in range(len(TR6)):
        lat = lat_core.evaluate_lattice(img)
        i = int(np.argmax(lat.ap))
        m, v = loop_core.best_subset(img, masks)
        assert (int(lat.masks[i]), float(lat.ap[i])) == (m, v)


def test_upper_bound_runs_at_n12():
    """The exact oracle at 4095 subsets/image — the regime the lattice
    unlocks — completes and its AP dominates the full ensemble."""
    from repro.core.loops import (ensembleN_policy, evaluate_policy,
                                  upper_bound)
    tr = generate_traces(lattice_stress_providers(12), 8, seed=1)
    env = ArmolEnv(tr, mode="gt", beta=0.0, seed=1)
    ub = upper_bound(env)
    full = evaluate_policy(ensembleN_policy(env), env)
    assert ub["ap50"] >= full["ap50"]
    assert ub["cost"] <= full["cost"]


# ---------------------------------------------------------------------------
# sharded backends
# ---------------------------------------------------------------------------

def test_thread_sharded_delegates_to_home_shard():
    ref = SubsetEvaluationCore(TR3)
    cut = ShardedSubsetEvaluationCore(TR3, n_shards=3)
    for img in (0, 4, 11):
        a = cut.evaluate_lattice(img)
        b = ref.evaluate_lattice(img)
        np.testing.assert_array_equal(a.ap, b.ap)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.offsets, b.offsets)
    assert cut.cache_sizes()["lattices"] == 3


@pytest.mark.slow
def test_process_shard_lattice_rpc_parity():
    """One lattice RPC per image over the worker pipe must equal the
    in-process answer — masks, AP, cost, and the fused arrays."""
    from repro.serving.mp_shards import ProcessShardedSubsetEvaluationCore
    ref = SubsetEvaluationCore(TR3)
    with ProcessShardedSubsetEvaluationCore(TR3, n_shards=2) as cut:
        for img in (0, 1, 7):
            for against in ("gt", "pseudo"):
                a = cut.evaluate_lattice(img, against=against)
                b = ref.evaluate_lattice(img, against=against)
                np.testing.assert_array_equal(a.masks, b.masks)
                np.testing.assert_array_equal(a.ap, b.ap)
                np.testing.assert_array_equal(a.cost, b.cost)
                np.testing.assert_array_equal(a.offsets, b.offsets)
                np.testing.assert_array_equal(a.boxes, b.boxes)
                np.testing.assert_array_equal(a.scores, b.scores)
                np.testing.assert_array_equal(a.labels, b.labels)
                np.testing.assert_array_equal(a.providers, b.providers)
        # invalidation must reach the workers' lattice rows too
        cut.invalidate_images([0])
        assert cut.cache_sizes()["lattices"] == \
            ref.cache_sizes()["lattices"] - 2
