"""Property tests: lattice-vs-loop parity under random rosters (N <= 8),
random mask samples, silent providers, and interleaved invalidations —
the hypothesis-driven twin of ``test_lattice_eval.py``."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
pytest.importorskip("jax")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.federation.evaluation import (  # noqa: E402
    SubsetEvaluationCore, popcount_masks)
from repro.federation.providers import (  # noqa: E402
    ProviderProfile, lattice_stress_providers)
from repro.federation.traces import generate_traces  # noqa: E402

N_IMAGES = 5
_MUTE = ProviderProfile(name="mute", base_recall=0.0, fp_rate=0.0)

# pregenerated rosters (per-example trace generation would dominate the
# run): plain stress rosters at N in {2, 4, 6, 8} plus one with a silent
# provider, so empty-ensemble rows are always in the sampled population
TRS = {n: generate_traces(lattice_stress_providers(n), N_IMAGES, seed=n)
       for n in (2, 4, 6, 8)}
TRS["mute4"] = generate_traces(
    lattice_stress_providers(3) + [_MUTE], N_IMAGES, seed=13)


@settings(max_examples=30, deadline=None)
@given(roster=st.sampled_from(sorted(TRS, key=str)),
       img=st.integers(0, N_IMAGES - 1),
       against=st.sampled_from(["gt", "pseudo"]),
       picks=st.lists(st.integers(0, 10 ** 9), min_size=1, max_size=10),
       inv_first=st.booleans())
def test_lattice_rows_bit_identical(roster, img, against, picks,
                                    inv_first):
    tr = TRS[roster]
    lat_core = SubsetEvaluationCore(tr)
    loop_core = SubsetEvaluationCore(tr)
    if inv_first:
        # a dropped-and-rebuilt lattice must answer like a fresh one
        lat_core.evaluate_lattice(img, against=against)
        lat_core.invalidate_images([img])
    lat = lat_core.evaluate_lattice(img, against=against)
    full = (1 << tr.n_providers) - 1
    for p in picks:
        m = 1 + (p % full)
        a = lat.detections(m)
        b = loop_core.ensemble(img, m)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.providers, b.providers)
        assert lat.ap_of(m) == loop_core.ap50(img, m, against=against)
        assert lat.cost[lat.index_of(m)] == loop_core.cost(m)


@settings(max_examples=20, deadline=None)
@given(img=st.integers(0, N_IMAGES - 1),
       drop=st.lists(st.integers(0, N_IMAGES - 1), min_size=1,
                     max_size=4),
       picks=st.lists(st.integers(0, 10 ** 9), min_size=1, max_size=6))
def test_backfilled_memo_survives_invalidation_correctly(img, drop,
                                                         picks):
    """Back-filled per-mask entries and the lattice they came from drop
    TOGETHER; recomputation after the drop is loss-free."""
    tr = TRS[4]
    core = SubsetEvaluationCore(tr)
    core.evaluate_lattice(img)
    full = (1 << tr.n_providers) - 1
    masks = [1 + (p % full) for p in picks]
    before = {m: core.ap50(img, m) for m in masks}
    core.invalidate_images(drop)
    if img in drop:
        assert all(k[0] != img for k in core._lattice)
    for m in masks:
        assert core.ap50(img, m) == before[m]


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(img=st.integers(0, N_IMAGES - 1),
       voting=st.sampled_from(["affirmative", "consensus", "unanimous"]),
       against=st.sampled_from(["gt", "pseudo"]))
def test_every_row_matches_at_n8(img, voting, against):
    """All 255 rows, every array, exact floats — the exhaustive check at
    the largest fuzzed N."""
    tr = TRS[8]
    lat_core = SubsetEvaluationCore(tr, voting=voting)
    loop_core = SubsetEvaluationCore(tr, voting=voting)
    lat = lat_core.evaluate_lattice(img, against=against)
    for m in popcount_masks(tr.n_providers):
        a = lat.detections(m)
        b = loop_core.ensemble(img, m)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.providers, b.providers)
        assert lat.ap_of(m) == loop_core.ap50(img, m, against=against)
