"""COCO-style AP metric tests."""
import numpy as np
import pytest

from repro.ensemble.boxes import Detections
from repro.ensemble.metrics import average_precision, coco_map, image_ap50


def _d(boxes, scores, labels):
    return Detections(np.asarray(boxes, np.float32),
                      np.asarray(scores, np.float32),
                      np.asarray(labels, np.int32))


GT = _d([[0.1, 0.1, 0.4, 0.4], [0.6, 0.6, 0.9, 0.9]], [1, 1], [0, 0])


def test_perfect_predictions_ap_1():
    dt = _d(GT.boxes, [0.9, 0.8], [0, 0])
    assert average_precision({0: dt}, {0: GT}) == pytest.approx(1.0)


def test_no_predictions_ap_0():
    assert average_precision({0: Detections.empty()}, {0: GT}) == 0.0


def test_half_recall():
    dt = _d([[0.1, 0.1, 0.4, 0.4]], [0.9], [0])
    ap = average_precision({0: dt}, {0: GT})
    # one of two GTs found at precision 1 -> AP slightly above 0.5 due to
    # 101-pt interpolation boundary
    assert 0.45 < ap < 0.55


def test_fp_above_tp_hurts():
    clean = _d(GT.boxes, [0.9, 0.8], [0, 0])
    noisy = _d(np.vstack([GT.boxes, [[0.3, 0.5, 0.5, 0.7]]]),
               [0.9, 0.8, 0.95], [0, 0, 0])
    assert average_precision({0: noisy}, {0: GT}) < \
        average_precision({0: clean}, {0: GT})


def test_fp_below_all_tps_harmless_at_ap50():
    clean = _d(GT.boxes, [0.9, 0.8], [0, 0])
    noisy = _d(np.vstack([GT.boxes, [[0.3, 0.5, 0.5, 0.7]]]),
               [0.9, 0.8, 0.1], [0, 0, 0])
    assert average_precision({0: noisy}, {0: GT}) == pytest.approx(
        average_precision({0: clean}, {0: GT}))


def test_wrong_label_is_fp():
    dt = _d(GT.boxes, [0.9, 0.8], [1, 1])
    assert average_precision({0: dt}, {0: GT}) == 0.0


def test_iou_threshold_matters():
    shifted = GT.boxes + 0.04        # IoU ~0.6: inside [0.5, 0.75)
    dt = _d(shifted, [0.9, 0.8], [0, 0])
    ap50 = average_precision({0: dt}, {0: GT}, iou_thr=0.5)
    ap75 = average_precision({0: dt}, {0: GT}, iou_thr=0.75)
    assert ap50 > ap75


def test_coco_map_leq_ap50():
    dt = _d(GT.boxes + 0.02, [0.9, 0.8], [0, 0])
    assert coco_map({0: dt}, {0: GT}) <= \
        average_precision({0: dt}, {0: GT}, iou_thr=0.5) + 1e-9


def test_image_ap50_is_reward_signal():
    dt = _d(GT.boxes, [0.9, 0.8], [0, 0])
    assert image_ap50(dt, GT) == pytest.approx(1.0)
    assert image_ap50(Detections.empty(), GT) == 0.0


def test_corpus_pools_across_images():
    # image 0 perfect, image 1 empty -> corpus AP ~ 0.5 (same class)
    dt0 = _d(GT.boxes, [0.9, 0.8], [0, 0])
    ap = average_precision({0: dt0, 1: Detections.empty()},
                           {0: GT, 1: GT})
    assert 0.4 < ap < 0.6
