"""Observability unit suite: metrics registry, tracer, serving log,
report summarizers, and the sync/async serving integration.

Fast lane — everything here runs on in-process thread shards with tiny
rosters.  The heavier bit-parity matrix (process shards, scenario
drivers) lives in ``tests/test_obs_parity.py``.
"""
import json
import os

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.sac import SAC, SACConfig
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.launch.obs_report import (load_run, render, serving_summary,
                                     span_summary)
from repro.obs import (NULL_SPAN, MetricsRegistry, Obs, Tracer,
                       counters_snapshot, hist_quantile, merge_snapshots,
                       read_serving_log)
from repro.obs.serving_log import ServingLog
from repro.serving.async_service import AsyncFederationService
from repro.serving.federation_service import FederationService

TR = generate_traces(default_providers(), 24, seed=5)
ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
NAMES = [p.name for p in TR.providers]


class FixedAgent:
    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _sac():
    return SAC(SACConfig(state_dim=ENV.state_dim,
                         n_providers=ENV.n_providers, hidden=(16, 16)))


# -- metrics registry -----------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("g")
    g.set(4.0)
    g.add(1.0)
    g.set_max(2.0)          # below current -> unchanged
    assert g.value == 5.0
    g.set_max(9.0)
    assert g.value == 9.0
    h = reg.histogram("h", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.counts == [1, 1, 1]
    assert h.count == 3 and h.sum == 55.5
    assert (h.vmin, h.vmax) == (0.5, 50.0)


def test_registry_returns_same_object_and_rejects_rebound_hist():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.histogram("h", bounds=(1.0,)) is reg.histogram(
        "h", bounds=(1.0,))
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(2.0,))
    with pytest.raises(ValueError):
        reg.histogram("unsorted", bounds=(3.0, 1.0))


def test_observe_batch_matches_repeated_observe():
    reg = MetricsRegistry()
    a = reg.histogram("a", bounds=(1.0, 2.0, 5.0))
    b = reg.histogram("b", bounds=(1.0, 2.0, 5.0))
    vals = [0.1, 1.5, 2.0, 4.9, 8.0, 1.0]
    for v in vals:
        a.observe(v)
    b.observe_batch(vals)
    assert a.counts == b.counts
    assert a.sum == b.sum and a.count == b.count
    assert (a.vmin, a.vmax) == (b.vmin, b.vmax)


def test_snapshot_is_plain_and_reset_prefix_scopes():
    reg = MetricsRegistry()
    reg.counter("serving.requests").inc(7)
    reg.counter("train.steps").inc(3)
    reg.gauge("serving.occupancy").set(2.0)
    reg.histogram("serving.ms", bounds=(1.0,)).observe(0.5)
    snap = reg.snapshot()
    assert json.loads(json.dumps(snap)) == snap      # JSON-safe
    assert snap["counters"]["serving.requests"] == 7.0
    assert snap["histograms"]["serving.ms"]["count"] == 1
    reg.reset(prefix="serving.")
    snap2 = reg.snapshot()
    assert snap2["counters"]["serving.requests"] == 0.0
    assert snap2["counters"]["train.steps"] == 3.0   # untouched
    assert snap2["histograms"]["serving.ms"]["count"] == 0


def test_disabled_registry_is_free_and_empty():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c")
    c.inc(5)
    reg.gauge("g").set(1.0)
    h = reg.histogram("h")
    h.observe(1.0)
    h.observe_batch([1.0, 2.0])
    assert c.value == 0.0
    snap = reg.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


def test_merge_snapshots_sums_and_rejects_mismatched_buckets():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    for r, k in ((r1, 2), (r2, 5)):
        r.counter("n").inc(k)
        r.gauge("occ").set(k)
        r.histogram("ms", bounds=(1.0, 10.0)).observe(k)
    merged = merge_snapshots(r1.snapshot(), r2.snapshot())
    assert merged["counters"]["n"] == 7.0
    assert merged["gauges"]["occ"] == 7.0           # gauges sum (partitioned)
    h = merged["histograms"]["ms"]
    assert h["count"] == 2 and h["sum"] == 7.0
    assert (h["min"], h["max"]) == (2.0, 5.0)
    bad = MetricsRegistry()
    bad.histogram("ms", bounds=(3.0,)).observe(1.0)
    with pytest.raises(ValueError):
        merge_snapshots(r1.snapshot(), bad.snapshot())


def test_counters_snapshot_lifts_plain_dict():
    snap = counters_snapshot({"hits": 3, "misses": 1}, "core.")
    assert snap["counters"] == {"core.hits": 3.0, "core.misses": 1.0}
    merged = merge_snapshots(snap, snap)
    assert merged["counters"]["core.hits"] == 6.0


def test_hist_quantile_interpolates_and_handles_empty():
    reg = MetricsRegistry()
    h = reg.histogram("h", bounds=(10.0, 20.0, 30.0))
    h.observe_batch([5.0, 15.0, 25.0, 29.0])
    snap = reg.snapshot()["histograms"]["h"]
    assert hist_quantile(snap, 0.0) <= hist_quantile(snap, 0.5) \
        <= hist_quantile(snap, 1.0)
    assert hist_quantile(snap, 1.0) == pytest.approx(29.0)
    empty = MetricsRegistry().histogram("e")
    assert hist_quantile(
        {"buckets": list(empty.bounds), "counts": list(empty.counts),
         "sum": 0.0, "count": 0, "min": None, "max": None}, 0.5) is None


# -- tracer ---------------------------------------------------------------
def test_tracer_off_is_null():
    tr = Tracer(sample=0.0)
    assert tr.sample_request() is None
    assert tr.span("x", None) is NULL_SPAN
    with tr.span("x", tr.sample_request()) as sp:
        sp.set(a=1)
    assert tr.spans() == []


def test_tracer_records_spans_with_parent_and_writer():
    out = []
    tr = Tracer(sample=1.0, writer=out.append)
    tid = tr.sample_request()
    assert tid is not None
    with tr.span("request", tid, img=3) as root:
        with tr.span("shard_assemble", tid, parent=root.span_id) as sub:
            sub.set(n=2)
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["shard_assemble", "request"]
    child, root_rec = spans
    assert child["parent"] == root_rec["span"]
    assert child["trace"] == root_rec["trace"] == tid
    assert child["attrs"]["n"] == 2 and root_rec["attrs"]["img"] == 3
    assert all(s["dur_ms"] >= 0.0 for s in spans)
    assert out == spans                              # writer saw both


def test_tracer_sampling_is_seed_deterministic_and_partial():
    a = Tracer(sample=0.3, seed=7)
    b = Tracer(sample=0.3, seed=7)
    da = [a.sample_request() for _ in range(200)]
    db = [b.sample_request() for _ in range(200)]
    assert da == db
    hits = sum(1 for t in da if t is not None)
    assert 0 < hits < 200
    assert len({t for t in da if t is not None}) == hits   # unique ids


def test_span_records_error_attr():
    tr = Tracer(sample=1.0)
    tid = tr.sample_request()
    with pytest.raises(RuntimeError):
        with tr.span("boom", tid):
            raise RuntimeError("x")
    (sp,) = tr.spans()
    assert sp["attrs"]["error"] == "RuntimeError"


# -- serving log ----------------------------------------------------------
class _Res:
    def __init__(self, cost, lat, dets):
        self.cost_milli_usd = cost
        self.latency_ms = lat
        self.detections = dets


def _flush_args(n=4, seed=0):
    rng = np.random.default_rng(seed)
    imgs = [int(i) for i in rng.integers(0, 24, n)]
    masks = [int(m) for m in rng.integers(1, 8, n)]
    results = [_Res(float(m), 10.0 + m, ENV.core.ensemble(i, m))
               for i, m in zip(imgs, masks)]
    return imgs, masks, results


def test_serving_log_record_schema_and_roundtrip(tmp_path):
    path = str(tmp_path / "s.jsonl")
    log = ServingLog(path, provider_names=NAMES, gts=TR.gts, retain=8)
    imgs, masks, results = _flush_args()
    log.log_flush(imgs, masks, ENV.costs, results, seg=1, clock=42,
                  reason="flush_full", backend="thread")
    log.flush()                       # write barrier (async writer)
    recs = read_serving_log(path)
    assert len(recs) == len(imgs) == log.n_records
    assert recs == log.tail()
    for rec, img, mask, res in zip(recs, imgs, masks, results):
        assert rec["img"] == img and rec["mask"] == mask
        assert rec["seg"] == 1 and rec["clock"] == 42
        assert rec["providers"] == [NAMES[i] for i in range(8)
                                    if (mask >> i) & 1]
        assert set(rec["fees"]) == set(rec["providers"])
        for name, fee in rec["fees"].items():
            assert fee == pytest.approx(
                float(ENV.costs[NAMES.index(name)]))
        assert rec["cost_milli_usd"] == res.cost_milli_usd
        assert rec["latency_ms"] == res.latency_ms
        assert 0.0 <= rec["ap50"] <= 1.0
        assert rec["flush_reason"] == "flush_full"
        assert rec["backend"] == "thread"
        assert rec["ts"] > 0


def test_serving_log_null_fields_and_explicit_aps(tmp_path):
    path = str(tmp_path / "s.jsonl")
    log = ServingLog(path, provider_names=NAMES, gts=None, retain=4)
    imgs, masks, results = _flush_args(n=3)
    log.log_flush(imgs, masks, ENV.costs, results)
    log.log_flush(imgs, masks, ENV.costs, results, aps=[0.25, None, 1.0])
    log.flush()
    recs = read_serving_log(path)
    assert [r["ap50"] for r in recs[:3]] == [None] * 3   # no gts
    assert [r["ap50"] for r in recs[3:]] == [0.25, None, 1.0]
    assert all(r["seg"] is None and r["clock"] is None
               and r["flush_reason"] is None for r in recs)
    assert len(log.tail()) == 4                           # retain trims
    log.close()
    with pytest.raises(RuntimeError):
        log.log_flush(imgs, masks, ENV.costs, results)


def test_serving_log_ap_memo_and_fragment_reuse(tmp_path):
    log = ServingLog(str(tmp_path / "s.jsonl"), provider_names=NAMES,
                     gts=TR.gts)
    imgs, masks, results = _flush_args(n=2)
    for _ in range(3):
        log.log_flush(imgs, masks, ENV.costs, results, seg=0)
    log.flush()
    recs = read_serving_log(log.path)
    assert len(recs) == 6
    # identical (seg, img, mask) must produce identical ap / fees
    assert recs[0]["ap50"] == recs[2]["ap50"] == recs[4]["ap50"]
    assert recs[1]["fees"] == recs[3]["fees"] == recs[5]["fees"]


# -- report summarizers ---------------------------------------------------
def test_serving_summary_groups_by_segment():
    recs = [
        {"img": 0, "seg": 0, "mask": 3, "providers": ["a", "b"],
         "fees": {"a": 1.0, "b": 2.0}, "cost_milli_usd": 3.0,
         "latency_ms": 30.0, "ap50": 0.5, "flush_reason": "flush_full"},
        {"img": 1, "seg": 0, "mask": 1, "providers": ["a"],
         "fees": {"a": 1.0}, "cost_milli_usd": 1.0, "latency_ms": 10.0,
         "ap50": None, "flush_reason": "flush_timeout"},
        {"img": 2, "seg": None, "mask": 0, "providers": [], "fees": {},
         "cost_milli_usd": 0.0, "latency_ms": 0.0, "ap50": 0.0,
         "flush_reason": None},
    ]
    s = serving_summary(recs)
    assert set(s) == {"seg0", "all"}
    seg0 = s["seg0"]
    assert seg0["requests"] == 2
    assert seg0["cost_total"] == pytest.approx(4.0)
    assert seg0["cost_per_request"] == pytest.approx(2.0)
    assert seg0["mean_ap50"] == pytest.approx(0.5)   # only scored recs
    assert seg0["flush_reasons"] == {"flush_full": 1, "flush_timeout": 1}
    assert seg0["fees_by_provider"] == {"a": 2.0, "b": 2.0}
    assert s["all"]["empty"] == 1


def test_span_summary_percentiles():
    spans = [{"name": "flush", "dur_ms": float(d)} for d in range(10)]
    spans += [{"name": "request", "dur_ms": 5.0}]
    s = span_summary(spans)
    assert s["flush"]["count"] == 10
    assert s["flush"]["max_ms"] == 9.0
    assert s["request"] == {"count": 1, "p50_ms": 5.0, "p99_ms": 5.0,
                            "max_ms": 5.0}


def test_obs_umbrella_and_report_render(tmp_path):
    d = str(tmp_path / "run")
    obs = Obs(d, trace_sample=1.0)
    obs.open_serving_log(NAMES, TR.gts, retain=4)
    tid = obs.tracer.sample_request()
    with obs.tracer.span("request", tid, img=0):
        pass
    imgs, masks, results = _flush_args(n=2)
    obs.serving_log.log_flush(imgs, masks, ENV.costs, results, seg=0,
                              reason="flush_full", backend="thread")
    obs.event("regime_switch", from_seg=0, to_seg=1, clock=10)
    obs.metrics.counter("serving.requests").inc(2)
    obs.write_metrics([counters_snapshot({"hits": 5}, "core.")])
    obs.close()                                      # drains the log
    run = load_run(d)
    assert run["metrics"]["counters"] == {"serving.requests": 2.0,
                                          "core.hits": 5.0}
    assert len(run["serving"]) == 2
    assert [s["name"] for s in run["spans"]] == ["request"]
    assert run["events"][0]["event"] == "regime_switch"
    text = render(run)
    assert "seg0" in text and "regime_switch" in text \
        and "serving.requests" in text


def test_disabled_obs_is_inert(tmp_path):
    d = str(tmp_path / "off")
    obs = Obs(d, trace_sample=1.0, enabled=False)
    assert obs.open_serving_log(NAMES) is None
    assert obs.tracer.sample_request() is None
    obs.event("x", a=1)
    assert obs.events == []
    obs.write_metrics()
    obs.close()
    assert not os.path.exists(os.path.join(d, "metrics.json"))


# -- serving integration (sync + async thread plane) ----------------------
def test_sync_service_logs_requests_and_is_bit_identical(tmp_path):
    agent = FixedAgent([1, 0, 1])
    bare = FederationService(ENV, agent)
    d = str(tmp_path / "run")
    obs = Obs(d)
    obs.open_serving_log(NAMES, TR.gts)
    inst = FederationService(ENV, agent, obs=obs)
    reqs = [0, 3, 7, 3, 11]
    ref = [bare.handle(i) for i in reqs]
    got = [inst.handle(i) for i in reqs]
    for a, b in zip(ref, got):
        assert a.cost_milli_usd == b.cost_milli_usd
        assert a.latency_ms == b.latency_ms
        np.testing.assert_array_equal(a.detections.boxes,
                                      b.detections.boxes)
    obs.close()
    recs = read_serving_log(os.path.join(d, "serving_log.jsonl"))
    assert [r["img"] for r in recs] == reqs
    assert all(r["backend"] == "sync" for r in recs)
    # AP came off the evaluation core's memo — must match a rescoring
    from repro.ensemble.metrics import image_ap50
    for r in recs:
        ens = ENV.core.ensemble(r["img"], r["mask"])
        assert r["ap50"] == pytest.approx(
            float(image_ap50(ens, TR.gts[r["img"]])))


def test_async_service_stats_port_and_reset():
    obs = Obs(None)
    with AsyncFederationService(ENV, _sac(), max_batch=4, workers=2,
                                obs=obs) as svc:
        for f in [svc.submit(i % 24) for i in range(20)]:
            f.result()
        st = svc.stats
        assert st["requests"] == 20
        assert st["batched_requests"] == 20
        assert st["flushes"] >= 5
        assert st["max_flush"] <= 4
        assert st["flush_full"] + st["flush_timeout"] \
            + st["flush_drain"] == st["flushes"]
        assert svc.mean_flush_size() == pytest.approx(
            st["batched_requests"] / st["flushes"])
        # the same numbers must appear in the obs registry snapshot
        snap = obs.metrics.snapshot()
        assert snap["counters"]["serving.requests"] == 20.0
        svc.reset_stats()
        st0 = svc.stats
        assert all(v == 0 for v in st0.values())


def test_async_service_obs_parity_and_merged_snapshot(tmp_path):
    agent = FixedAgent([1, 1, 0])
    reqs = [int(i) for i in
            np.random.default_rng(3).integers(0, 24, 40)]
    with AsyncFederationService(ENV, agent, max_batch=8,
                                workers=2) as bare:
        ref = bare.handle_many(reqs)
    d = str(tmp_path / "run")
    obs = Obs(d, trace_sample=1.0)
    obs.open_serving_log(NAMES, TR.gts)
    with AsyncFederationService(ENV, agent, max_batch=8, workers=2,
                                obs=obs) as inst:
        got = inst.handle_many(reqs)
        snap = inst.metrics_snapshot()
    obs.write_metrics(inst.extra_metric_snapshots())
    obs.close()
    for a, b in zip(ref, got):
        assert a.cost_milli_usd == b.cost_milli_usd
        assert a.latency_ms == b.latency_ms
        np.testing.assert_array_equal(a.detections.boxes,
                                      b.detections.boxes)
    # merged view: parent serving counters + per-shard core cache stats
    assert snap["counters"]["serving.requests"] == float(len(reqs))
    assert any(k.startswith("core.") for k in snap["counters"])
    assert snap["histograms"]["serving.flush_size"]["count"] >= 1
    assert snap["histograms"]["serving.queue_wait_ms"]["count"] \
        == len(reqs)
    recs = read_serving_log(os.path.join(d, "serving_log.jsonl"))
    assert len(recs) == len(reqs)
    assert sorted(r["img"] for r in recs) == sorted(reqs)
    assert {r["backend"] for r in recs} == {"thread"}
    spans = load_run(d)["spans"]
    names = {s["name"] for s in spans}
    assert {"request", "flush", "shard_assemble"} <= names
    by_trace = {}
    for sp in spans:
        by_trace.setdefault(sp["trace"], []).append(sp)
    # every traced request produced its root span; flush/assembly spans
    # hang off the first traced request of their flush
    assert len(by_trace) == len(reqs)
    assert all(any(s["name"] == "request" for s in chain)
               for chain in by_trace.values())
    full = [c for c in by_trace.values()
            if {"flush", "shard_assemble"} <= {s["name"] for s in c}]
    assert full, "no flush carried its span chain"
    for chain in full:
        flush_sp = next(s for s in chain if s["name"] == "flush")
        asm = [s for s in chain if s["name"] == "shard_assemble"]
        assert all(s["parent"] == flush_sp["span"] for s in asm)
        assert flush_sp["attrs"]["reason"].startswith("flush_")
