"""Property tests for the metrics snapshot algebra.

``merge_snapshots`` is documented associative and commutative — the
process-shard parent folds worker replies in whatever order the pipes
answer, and the report CLI folds run artifacts in directory order, so
any grouping must produce the same merged view.  Values are drawn as
integer-valued floats: integer addition is exact in binary floating
point, which keeps the algebraic properties testable with ``==``.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
pytest.importorskip("jax")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import empty_snapshot, merge_snapshots

# one bucket layout per histogram name, as the registry enforces
BOUNDS = {"h.a": [1.0, 5.0, 25.0], "h.b": [0.5, 8.0], "h.c": [10.0]}

_int_val = st.integers(-1_000, 1_000).map(float)
_nonneg = st.integers(0, 1_000)


@st.composite
def _histogram(draw, name):
    bounds = BOUNDS[name]
    return {
        "buckets": list(bounds),
        "counts": draw(st.lists(_nonneg, min_size=len(bounds) + 1,
                                max_size=len(bounds) + 1)),
        "sum": float(draw(st.integers(0, 100_000))),
        "count": draw(_nonneg),
        "min": draw(st.none() | _int_val),
        "max": draw(st.none() | _int_val),
    }


@st.composite
def _snapshot(draw):
    snap = empty_snapshot()
    for k in draw(st.lists(st.sampled_from(["c.x", "c.y", "c.z"]),
                           unique=True)):
        snap["counters"][k] = draw(_int_val)
    for k in draw(st.lists(st.sampled_from(["g.x", "g.y"]), unique=True)):
        snap["gauges"][k] = draw(_int_val)
    for k in draw(st.lists(st.sampled_from(sorted(BOUNDS)), unique=True)):
        snap["histograms"][k] = draw(_histogram(k))
    return snap


@settings(max_examples=60, deadline=None)
@given(a=_snapshot(), b=_snapshot())
def test_merge_commutative(a, b):
    assert merge_snapshots(a, b) == merge_snapshots(b, a)


@settings(max_examples=60, deadline=None)
@given(a=_snapshot(), b=_snapshot(), c=_snapshot())
def test_merge_associative(a, b, c):
    left = merge_snapshots(merge_snapshots(a, b), c)
    right = merge_snapshots(a, merge_snapshots(b, c))
    flat = merge_snapshots(a, b, c)
    assert left == right == flat


@settings(max_examples=40, deadline=None)
@given(snaps=st.lists(_snapshot(), max_size=5), data=st.data())
def test_merge_permutation_invariant(snaps, data):
    ref = merge_snapshots(*snaps)
    perm = data.draw(st.permutations(snaps))
    assert merge_snapshots(*perm) == ref


@settings(max_examples=40, deadline=None)
@given(a=_snapshot())
def test_merge_identities(a):
    # empty snapshot and None are both units of the fold
    merged = merge_snapshots(a, empty_snapshot(), None)
    assert merged["counters"] == a["counters"]
    assert merged["gauges"] == a["gauges"]
    assert merged["histograms"] == a["histograms"]
    # and the fold never aliases its inputs' histogram dicts
    for k in merged["histograms"]:
        assert merged["histograms"][k] is not a["histograms"][k]


@settings(max_examples=30, deadline=None)
@given(h=_histogram("h.a"))
def test_merge_rejects_bucket_mismatch(h):
    a, b = empty_snapshot(), empty_snapshot()
    a["histograms"]["h"] = h
    other = dict(h, buckets=list(h["buckets"]) + [99.0],
                 counts=list(h["counts"]) + [0])
    b["histograms"]["h"] = other
    with pytest.raises(ValueError):
        merge_snapshots(a, b)
