"""Bit-parity matrix for the observability layer.

The design contract of ``repro.obs``: attaching metrics, tracing, and
the serving log changes NO served result and NO trained weight — obs
reads clocks and copies values, it never touches an rng, a cache key,
or an accounting quantity.  This suite runs the heavier halves of that
matrix: the process-shard backend under a scenario schedule, and the
full ``run_online`` driver.

Slow-marked wholesale: process shards spawn worker interpreters and the
driver parity case trains twice over a scenario horizon.
"""
import json
import os

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.sac import SAC, SACConfig
from repro.federation.providers import default_providers
from repro.launch.obs_report import load_run, render, serving_summary
from repro.obs import Obs, read_serving_log
from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                             build_scenario)
from repro.scenarios.online import run_online
from repro.serving.async_service import AsyncFederationService

pytestmark = pytest.mark.slow

PROVS = default_providers()


def _scenario_env(name="provider_outage", horizon=90, n_images=30):
    schedule = build_scenario(name, PROVS, horizon=horizon)
    pool = DynamicProviderPool(PROVS, schedule, n_images=n_images, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    return pool, env


class Greedy:
    """Select every provider — exercises the widest ensembles."""

    def __init__(self, n):
        self.n = n

    def select_for_images(self, imgs, step=None):
        return np.ones((len(imgs), self.n), np.float32)


def _assert_same_results(ref, got):
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.action, b.action)
        assert a.cost_milli_usd == b.cost_milli_usd
        assert a.latency_ms == b.latency_ms
        np.testing.assert_array_equal(a.detections.boxes,
                                      b.detections.boxes)
        np.testing.assert_array_equal(a.detections.scores,
                                      b.detections.scores)


def test_process_backend_obs_parity_and_artifacts(tmp_path):
    pool, env = _scenario_env()
    agent = Greedy(env.n_providers)
    reqs = [int(i) for i in
            np.random.default_rng(0).integers(0, 30, 60)]

    with AsyncFederationService(env, agent, max_batch=1, workers=2,
                                pool=pool, shard_backend="process") as s:
        bare = [s.handle(i) for i in reqs]

    d = str(tmp_path / "run")
    obs = Obs(d, trace_sample=1.0)
    obs.open_serving_log([p.name for p in PROVS], env.traces.gts)
    with AsyncFederationService(env, agent, max_batch=1, workers=2,
                                pool=pool, shard_backend="process",
                                obs=obs) as s:
        inst = [s.handle(i) for i in reqs]
        extras = s.extra_metric_snapshots()
    snap = obs.write_metrics(extras)
    obs.close()

    _assert_same_results(bare, inst)

    # merged metrics: parent-side serving stats + per-shard RPC latency
    # histograms + worker-process op timings in ONE view
    assert snap["counters"]["serving.requests"] == 60.0
    assert any(k.startswith("serving.shard_rpc_ms.s")
               for k in snap["histograms"])
    assert any(k.startswith("worker.op_ms.") for k in snap["histograms"])
    assert any(k.startswith("core.") for k in snap["counters"])

    # serving log covers every request, with the regime recorded; the
    # per-regime summary is the OPE acceptance surface
    recs = read_serving_log(os.path.join(d, "serving_log.jsonl"))
    assert len(recs) == 60
    assert {r["backend"] for r in recs} == {"process"}
    summ = serving_summary(recs)
    assert set(summ) == {f"seg{k}" for k in
                         sorted({r["seg"] for r in recs})}
    assert len(summ) >= 2                       # the outage switched regimes
    assert sum(s["requests"] for s in summ.values()) == 60
    for s in summ.values():
        assert s["mean_ap50"] is not None and 0.0 <= s["mean_ap50"] <= 1.0
        assert s["cost_per_request"] > 0.0
        assert sum(s["flush_reasons"].values()) == s["requests"]

    # trace context crossed the worker pipe: worker_eval spans exist and
    # parent correctly onto shard_assemble spans of the same trace
    spans = load_run(d)["spans"]
    names = {sp["name"] for sp in spans}
    assert {"request", "flush", "shard_assemble", "worker_eval"} <= names
    by_span = {sp["span"]: sp for sp in spans}
    workers = [sp for sp in spans if sp["name"] == "worker_eval"]
    assert workers
    for w in workers:
        parent = by_span[w["parent"]]
        assert parent["name"] == "shard_assemble"
        assert parent["trace"] == w["trace"]
        assert "pid" in w["attrs"]

    # the rendered report stands on its own
    text = render(load_run(d))
    assert "worker_eval" in text and "seg0" in text


def test_run_online_obs_parity_and_event_stream(tmp_path):
    def _run(obs):
        pool, env = _scenario_env(horizon=240, n_images=24)
        agent = SAC(SACConfig(state_dim=env.state_dim,
                              n_providers=env.n_providers, gamma=0.0,
                              hidden=(16, 16)))
        return run_online(agent, env, lanes=2, seed=0, log=None,
                          start_steps=40, explore_steps=20,
                          batch_size=32, update_iters=4, obs=obs)

    ref = _run(None)
    d = str(tmp_path / "run")
    obs = Obs(d)
    got = _run(obs)
    obs.write_metrics()
    obs.close()

    def _strip(x):
        if isinstance(x, dict):
            return {k: _strip(v) for k, v in x.items()
                    if "wall" not in k and "time" not in k}
        if isinstance(x, list):
            return [_strip(v) for v in x]
        return x

    assert _strip(ref["summary"]) == _strip(got["summary"])
    assert _strip(ref["segments"]) == _strip(got["segments"])

    # the event stream narrates the scenario: one close per segment,
    # switches in between, and a final summary
    events = [json.loads(ln) for ln in
              open(os.path.join(d, "events.jsonl")) if ln.strip()]
    kinds = [e["event"] for e in events]
    n_segs = len(got["segments"])
    assert kinds.count("segment_close") == n_segs
    assert kinds.count("regime_switch") == n_segs - 1
    assert kinds[-1] == "scenario_summary"
    for ev in events:
        if ev["event"] == "regime_switch":
            assert ev["buffer"] in ("flush", "fee_relabel", "fresh",
                                    "stash_restore")
            assert ev["to_seg"] == ev["from_seg"] + 1

    # training metrics landed in the registry
    snap = json.load(open(os.path.join(d, "metrics.json")))
    assert snap["counters"]["train.update_iters"] > 0
    assert snap["histograms"]["train.tick_ms"]["count"] > 0


def test_thread_backend_scenario_obs_parity(tmp_path):
    """Thread shards under the same scenario: results identical, and the
    serving log's segment column follows the pool clock."""
    pool, env = _scenario_env(horizon=60, n_images=20)
    agent = Greedy(env.n_providers)
    reqs = list(range(20)) * 2

    def _serve(obs):
        with AsyncFederationService(env, agent, max_batch=4, workers=2,
                                    pool=pool, obs=obs) as s:
            out = []
            for k, i in enumerate(reqs):
                s.set_clock(k)          # sweep the scenario clock
                out.append(s.handle(i))
            return out

    bare = _serve(None)
    d = str(tmp_path / "run")
    obs = Obs(d)
    obs.open_serving_log([p.name for p in PROVS], env.traces.gts)
    inst = _serve(obs)
    obs.close()
    _assert_same_results(bare, inst)
    recs = read_serving_log(os.path.join(d, "serving_log.jsonl"))
    assert len(recs) == len(reqs)
    assert {r["backend"] for r in recs} == {"thread"}
    # clock column is the flush clock; segment follows the schedule
    for r in recs:
        assert r["seg"] == pool.schedule.segment_index(r["clock"])


def test_stats_contract_unchanged_by_obs_registry():
    """The dict-shaped ``stats`` accessor and ``reset_stats`` behave
    identically whether backed by a private registry or an Obs one."""
    pool, env = _scenario_env(horizon=30, n_images=12)
    agent = Greedy(env.n_providers)

    def _stats(obs):
        with AsyncFederationService(env, agent, max_batch=4, workers=2,
                                    pool=pool, obs=obs) as s:
            for f in [s.submit(i % 12) for i in range(24)]:
                f.result()
            st = dict(s.stats)
            s.reset_stats()
            zeroed = dict(s.stats)
        return st, zeroed

    st_bare, z_bare = _stats(None)
    st_obs, z_obs = _stats(Obs(None))
    assert set(st_bare) == set(st_obs) == {
        "requests", "flushes", "batched_requests", "max_flush",
        "flush_full", "flush_timeout", "flush_drain"}
    assert st_bare["requests"] == st_obs["requests"] == 24
    assert st_bare["batched_requests"] == st_obs["batched_requests"]
    assert all(v == 0 for v in z_bare.values())
    assert all(v == 0 for v in z_obs.values())
