"""Correctness of the §Perf optimisations: the optimised paths must be
semantics-preserving vs the naive baselines."""
import os
import subprocess
import sys

import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ShapeConfig, get_arch
from repro.data.pipeline import batch_for
from repro.models.model import build_model
from repro.training.train_step import chunked_lm_loss, lm_loss


def test_chunked_loss_matches_naive():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", 32, 2, "train"), seed=3).items()}
    (l0, _), (l1, _) = lm_loss(model, params, batch), \
        chunked_lm_loss(model, params, batch, n_chunks=4)
    assert float(jnp.abs(l0 - l1)) < 1e-4


def test_chunked_loss_gradients_match():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", 16, 2, "train"), seed=4).items()}
    g0 = jax.grad(lambda p: lm_loss(model, p, batch)[0])(params)
    g1 = jax.grad(lambda p: chunked_lm_loss(model, p, batch,
                                            n_chunks=4)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_mla_constraint_numerically_neutral():
    """REPRO_MLA_CONSTRAINT only changes sharding; on one device the
    forward must be bit-identical."""
    cfg = get_arch("deepseek-v2-236b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", 16, 2, "train"), seed=5).items()}
    l0, _ = model.forward(params, batch)
    os.environ["REPRO_MLA_CONSTRAINT"] = "1"
    try:
        l1, _ = model.forward(params, batch)
    finally:
        del os.environ["REPRO_MLA_CONSTRAINT"]
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_moe_constraint_numerically_neutral():
    cfg = get_arch("olmoe-1b-7b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", 16, 2, "train"), seed=6).items()}
    l0, _ = model.forward(params, batch)
    os.environ["REPRO_MOE_CONSTRAINT"] = "1"
    try:
        l1, _ = model.forward(params, batch)
    finally:
        del os.environ["REPRO_MOE_CONSTRAINT"]
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_scan_unroll_numerically_neutral():
    cfg = get_arch("zamba2-2.7b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", 16, 2, "train"), seed=7).items()}
    l0, _ = model.forward(params, batch)
    os.environ["REPRO_SCAN_UNROLL"] = "8"
    try:
        l1, _ = model.forward(params, batch)
    finally:
        del os.environ["REPRO_SCAN_UNROLL"]
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


@pytest.mark.slow
def test_dryrun_subprocess_smoke():
    """One real 256-device lower+compile through the CLI (the deliverable-e
    path), in a subprocess so the 512-device flag never leaks."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1.5-0.5b", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=300,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "1/1 combos OK" in out.stdout, out.stdout + out.stderr
