"""Property-based tests for the vectorized replay-buffer batch APIs:
``add_batch`` must match a loop of scalar ``add`` calls for arbitrary
chunkings (wraparound and batch > capacity included), and ``sample`` /
``sample_block`` must be deterministic under a fixed rng seed."""
import numpy as np
import pytest

from repro.core.replay_buffer import ReplayBuffer

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(deadline=None, max_examples=60)
@given(cap=st.integers(1, 12),
       chunks=st.lists(st.integers(0, 30), min_size=1, max_size=5),
       data_seed=st.integers(0, 2 ** 16))
def test_add_batch_matches_scalar_loop(cap, chunks, data_seed):
    rng = np.random.default_rng(data_seed)
    scalar = ReplayBuffer(cap, 3, 2)
    batched = ReplayBuffer(cap, 3, 2)
    for B in chunks:
        s = rng.standard_normal((B, 3)).astype(np.float32)
        a = rng.standard_normal((B, 2)).astype(np.float32)
        r = rng.standard_normal(B).astype(np.float32)
        s2 = rng.standard_normal((B, 3)).astype(np.float32)
        d = (rng.random(B) > 0.5).astype(np.float32)
        for i in range(B):
            scalar.add(s[i], a[i], r[i], s2[i], d[i])
        batched.add_batch(s, a, r, s2, d)
        assert (scalar.ptr, scalar.size) == (batched.ptr, batched.size)
        for field in ("state", "action", "reward", "next_state", "done"):
            np.testing.assert_array_equal(getattr(scalar, field),
                                          getattr(batched, field))


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2 ** 16), n_fill=st.integers(1, 40),
       batch=st.integers(1, 16), iters=st.integers(1, 6))
def test_sample_determinism_and_block_equivalence(seed, n_fill, batch,
                                                 iters):
    def filled(rb_seed):
        buf = ReplayBuffer(32, 3, 2, seed=rb_seed)
        rng = np.random.default_rng(0)
        for _ in range(n_fill):
            buf.add(rng.standard_normal(3), rng.standard_normal(2),
                    rng.standard_normal(), rng.standard_normal(3), 0.0)
        return buf
    b1, b2 = filled(seed), filled(seed)
    mb1, mb2 = b1.sample(batch), b2.sample(batch)
    for k, v in mb1.items():
        np.testing.assert_array_equal(v, mb2[k], err_msg=k)
    # one sample_block draw consumes the rng exactly like `iters` samples
    b3, b4 = filled(seed), filled(seed)
    block = b3.sample_block(iters, batch)
    singles = [b4.sample(batch) for _ in range(iters)]
    for k in block:
        np.testing.assert_array_equal(
            block[k], np.stack([s[k] for s in singles]), err_msg=k)
