"""RL agent unit tests: update mechanics + learning on a tiny bandit."""
import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import networks as nets
from repro.core.ppo import PPO, PPOConfig
from repro.core.replay_buffer import ReplayBuffer
from repro.core.sac import SAC, SACConfig
from repro.core.td3 import TD3, TD3Config

STATE_DIM, N = 6, 3


def _bandit_batch(rng, agent_like, n=256):
    """Contextual bandit: reward = 1 if action matches argmax(state[:N])."""
    s = rng.standard_normal((n, STATE_DIM)).astype(np.float32)
    best = np.argmax(s[:, :N], axis=1)
    a = np.zeros((n, N), np.float32)
    pick = rng.integers(0, N, n)
    a[np.arange(n), pick] = 1.0
    r = (pick == best).astype(np.float32)
    s2 = rng.standard_normal((n, STATE_DIM)).astype(np.float32)
    d = np.ones(n, np.float32)              # bandit: episode ends each step
    return {"s": s, "a": a, "r": r, "s2": s2, "d": d}


def test_replay_buffer_roundtrip():
    buf = ReplayBuffer(10, STATE_DIM, N)
    for i in range(15):                      # overfill to test wrap
        buf.add(np.full(STATE_DIM, i, np.float32), np.ones(N), float(i),
                np.zeros(STATE_DIM), 0.0)
    assert len(buf) == 10
    b = buf.sample(4)
    assert b["s"].shape == (4, STATE_DIM)
    assert np.all(b["r"] >= 5)               # oldest entries overwritten


def test_sample_action_logprob_finite():
    key = jax.random.PRNGKey(0)
    actor = nets.init_actor(key, STATE_DIM, N)
    s = jnp.zeros((4, STATE_DIM))
    proto, logp = nets.sample_action(actor, s, key)
    assert proto.shape == (4, N)
    assert bool(jnp.all((proto >= 0) & (proto <= 1)))
    assert bool(jnp.all(jnp.isfinite(logp)))


def test_sac_update_moves_q_toward_reward():
    rng = np.random.default_rng(0)
    agent = SAC(SACConfig(state_dim=STATE_DIM, n_providers=N, lr=3e-4))
    batch = _bandit_batch(rng, agent)
    m0 = agent.update(batch)
    for _ in range(60):
        m = agent.update(_bandit_batch(rng, agent))
    assert m["q1_loss"] < m0["q1_loss"]
    assert np.isfinite(m["pi_loss"])


def test_sac_learns_contextual_bandit():
    rng = np.random.default_rng(1)
    agent = SAC(SACConfig(state_dim=STATE_DIM, n_providers=N, lr=1e-3,
                          alpha=0.02, gamma=0.0))
    for _ in range(300):
        agent.update(_bandit_batch(rng, agent))
    s = rng.standard_normal((200, STATE_DIM)).astype(np.float32)
    correct = 0
    for i in range(200):
        a, _ = agent.select_action(s[i], deterministic=True)
        if a[np.argmax(s[i, :N])] == 1.0:
            correct += 1
    assert correct > 120, correct            # >> chance (~66 for random-1)


def test_td3_update_finite_and_delayed_policy():
    rng = np.random.default_rng(2)
    agent = TD3(TD3Config(state_dim=STATE_DIM, n_providers=N))
    for _ in range(10):
        m = agent.update(_bandit_batch(rng, agent))
    assert np.isfinite(m["q1_loss"]) and np.isfinite(m["pi_loss"])
    a, proto = agent.select_action(np.zeros(STATE_DIM, np.float32),
                                   deterministic=True)
    assert set(np.unique(a)).issubset({0.0, 1.0}) and a.sum() >= 1
    assert np.all((proto >= 0) & (proto <= 1))


def test_ppo_rollout_update():
    rng = np.random.default_rng(3)
    agent = PPO(PPOConfig(state_dim=STATE_DIM, n_providers=N, minibatch=64))
    T = 128
    S = rng.standard_normal((T, STATE_DIM)).astype(np.float32)
    protos, logps, vals, rews = [], [], [], []
    for t in range(T):
        a, proto, logp, v = agent.select_action(S[t])
        protos.append(proto)
        logps.append(logp)
        vals.append(v)
        rews.append(float(a[np.argmax(S[t, :N])]))
    adv, ret = agent.gae(np.asarray(rews, np.float32),
                         np.asarray(vals, np.float32),
                         np.ones(T, np.float32), 0.0)
    metrics = agent.update_from_rollout(
        {"s": S, "proto": np.asarray(protos, np.float32),
         "logp": np.asarray(logps, np.float32), "adv": adv, "ret": ret})
    assert np.isfinite(metrics["pi_loss"]) and np.isfinite(metrics["v_loss"])


def test_gae_simple_case():
    agent = PPO(PPOConfig(state_dim=2, n_providers=2))
    # single terminal step: adv = r - v
    adv, ret = agent.gae(np.asarray([1.0], np.float32),
                         np.asarray([0.25], np.float32),
                         np.asarray([1.0], np.float32), 99.0)
    assert adv[0] == pytest.approx(0.75)
    assert ret[0] == pytest.approx(1.0)


def test_sac_wolpertinger_variant():
    """Beyond-paper: critic re-ranked action selection returns valid,
    nonzero binary actions and learns the bandit at least as fast."""
    rng = np.random.default_rng(5)
    agent = SAC(SACConfig(state_dim=STATE_DIM, n_providers=N,
                          wolpertinger_k=4, gamma=0.0, lr=1e-3, alpha=0.02))
    for _ in range(100):
        agent.update(_bandit_batch(rng, agent))
    a, proto = agent.select_action(
        rng.standard_normal(STATE_DIM).astype(np.float32),
        deterministic=True)
    assert set(np.unique(a)).issubset({0.0, 1.0}) and a.sum() >= 1
