"""Measured-roofline utilities: HLO cost extraction, fenced timing, and
roofline placement (``repro.roofline.measure``)."""
import numpy as np
import pytest

pytest.importorskip("jax")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.roofline.analysis import HW  # noqa: E402
from repro.roofline.measure import (achieved_point, hlo_cost,  # noqa: E402
                                    measure, timed_best)


@jax.jit
def _matmul(a, b):
    return a @ b


def test_hlo_cost_counts_matmul_flops():
    n = 64
    a = jnp.ones((n, n), jnp.float32)
    cost = hlo_cost(_matmul, a, a)
    # XLA counts an n^3 matmul as 2n^3 flops; allow fusion slack
    assert cost["flops"] >= 2 * n ** 3
    assert cost["flops"] < 4 * n ** 3
    if cost["bytes"]:      # CPU backend sometimes omits bytes accessed
        assert cost["intensity"] == pytest.approx(
            cost["flops"] / cost["bytes"])
    else:
        assert cost["intensity"] == 0.0


def test_hlo_cost_scan_counts_body_once():
    """XLA's cost model excludes the trip count of a ``lax.scan`` — the
    property the fused-update flops_parity gate relies on."""
    @jax.jit
    def once(x):
        return x @ x

    @jax.jit
    def scanned(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.ones((32, 32), jnp.float32)
    f1 = hlo_cost(once, x)["flops"]
    f10 = hlo_cost(scanned, x)["flops"]
    assert f10 == pytest.approx(f1, rel=0.1)


def test_timed_best_returns_positive_time_and_result():
    a = jnp.ones((32, 32), jnp.float32)
    seconds, out = timed_best(_matmul, a, a, repeats=2)
    assert seconds > 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ a))


def test_achieved_point_bound_selection():
    hw = HW()
    knee = hw.peak_flops / hw.hbm_bw
    lo = achieved_point({"flops": 1e6, "bytes": 1e6,
                         "intensity": knee / 10}, seconds=1e-3, hw=hw)
    hi = achieved_point({"flops": 1e9, "bytes": 1e3,
                         "intensity": knee * 10}, seconds=1e-3, hw=hw)
    assert lo["bound"] == "memory" and hi["bound"] == "compute"
    assert lo["knee_intensity"] == pytest.approx(knee)
    assert lo["achieved_flops_s"] == pytest.approx(1e9)
    assert lo["frac_peak_bw"] == pytest.approx(1e9 / hw.hbm_bw)


def test_measure_composes():
    a = jnp.ones((48, 48), jnp.float32)
    pt = measure(_matmul, a, a, repeats=2)
    assert pt["flops"] > 0 and pt["seconds"] > 0
    assert pt["bound"] in ("memory", "compute")


@pytest.mark.slow
def test_measure_does_not_consume_donated_args():
    """``hlo_cost`` lowers without executing, so measuring a
    donate_argnums function must not invalidate the caller's arrays."""
    from functools import partial

    @partial(jax.jit, donate_argnums=(0,))
    def bump(x):
        return x + 1

    x = jnp.zeros((8,), jnp.float32)
    cost = hlo_cost(bump, x)
    assert cost["flops"] >= 0
    np.testing.assert_array_equal(np.asarray(x), 0.0)  # still alive
