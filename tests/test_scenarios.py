"""Scenario engine: schedule semantics, dynamic provider pool state,
segment-keyed evaluation caches, and the non-stationary env wrapper."""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.evaluation import SubsetEvaluationCore
from repro.federation.providers import ProviderProfile, default_providers
from repro.federation.traces import generate_traces
from repro.scenarios import (BUILTIN_SCENARIOS, DynamicProviderPool,
                             NonStationaryArmolEnv, build_scenario,
                             random_scenario)
from repro.scenarios.schedule import ProviderEvent, ScenarioSchedule

PROVS = default_providers()


# ---------------------------------------------------------------------------
# provider snapshots (the replace()/fingerprint path)
# ---------------------------------------------------------------------------

def test_provider_profile_is_frozen():
    p = PROVS[0]
    with pytest.raises(Exception):
        p.cost_milli_usd = 99.0


def test_replace_bumps_rev_and_keeps_base():
    p = PROVS[0]
    q = p.replace(cost_milli_usd=2.0)
    assert q.rev == p.rev + 1
    assert q.cost_milli_usd == 2.0
    assert p.cost_milli_usd == 1.0
    assert q.name == p.name


def test_fingerprint_separates_economics_from_detections():
    p = PROVS[0]
    repriced = p.replace(cost_milli_usd=3.0)
    drifted = p.replace(base_recall=p.base_recall * 0.5)
    assert p.fingerprint() != repriced.fingerprint()
    assert p.fingerprint(detection_only=True) == \
        repriced.fingerprint(detection_only=True)
    assert p.fingerprint(detection_only=True) != \
        drifted.fingerprint(detection_only=True)


# ---------------------------------------------------------------------------
# schedule semantics
# ---------------------------------------------------------------------------

def test_segment_index_and_ranges():
    sch = ScenarioSchedule("t", 100, [ProviderEvent(30, "price", "aws", 2.0),
                                      ProviderEvent(60, "outage", "aws")])
    assert sch.boundaries == [0, 30, 60]
    assert sch.segment_index(0) == 0
    assert sch.segment_index(29) == 0
    assert sch.segment_index(30) == 1
    assert sch.segment_index(99) == 2
    assert sch.segment_index(5000) == 2        # clamps past horizon
    assert sch.segment_range(1) == (30, 60)
    assert sch.segment_range(2) == (60, 100)


def test_latest_event_wins_and_recovery_toggles():
    sch = ScenarioSchedule("t", 100, [
        ProviderEvent(10, "price", "aws", 0.5),
        ProviderEvent(20, "price", "aws", 2.0),
        ProviderEvent(30, "outage", "azure"),
        ProviderEvent(40, "recovery", "azure")])
    assert dict(sch.effects_at(15).price) == {"aws": 0.5}
    assert dict(sch.effects_at(25).price) == {"aws": 2.0}
    assert "azure" in sch.effects_at(35).down
    assert "azure" not in sch.effects_at(45).down


def test_event_validation():
    with pytest.raises(ValueError):
        ProviderEvent(5, "explode", "aws")
    with pytest.raises(ValueError):
        ProviderEvent(5, "arrival", "x")       # arrival needs a profile
    with pytest.raises(ValueError):
        ScenarioSchedule("t", 10, [ProviderEvent(10, "price", "a", 1.0)])


def test_builtins_build_and_random_is_seeded():
    for name in BUILTIN_SCENARIOS:
        sch = build_scenario(name, PROVS, horizon=500)
        assert sch.horizon == 500 and sch.n_segments >= 2
        assert sch.describe()
    r1 = random_scenario(PROVS, horizon=500, seed=7)
    r2 = random_scenario(PROVS, horizon=500, seed=7)
    assert [(e.step, e.kind, e.provider, e.value) for e in r1.events] == \
        [(e.step, e.kind, e.provider, e.value) for e in r2.events]
    assert build_scenario("random:7", PROVS, horizon=500).events == r1.events
    with pytest.raises(ValueError):
        build_scenario("nope", PROVS)


# ---------------------------------------------------------------------------
# dynamic pool
# ---------------------------------------------------------------------------

def _pool(name="provider_outage", horizon=300, n=24, **kw):
    sch = build_scenario(name, PROVS, horizon=horizon)
    return DynamicProviderPool(PROVS, sch, n_images=n, seed=0, **kw)


def test_base_segment_reuses_base_traces_exactly():
    pool = _pool()
    tr0 = pool.traces_at(0)
    for t in range(5):
        for j in range(pool.n_providers):
            assert tr0.dets[t][j] is pool.base_traces.dets[t][j]


def test_outage_masks_detections_and_zeroes_fees():
    pool = _pool()
    victim = int(np.argmax([p.base_recall for p in PROVS]))
    mid = pool.view_at(150)                    # inside the outage window
    assert not mid.active[victim]
    assert mid.costs[victim] == 0.0
    assert mid.latencies[victim] == pool.outage_timeout_ms
    tr = pool.traces_at(150)
    assert all(len(tr.dets[t][victim]) == 0 for t in range(len(pool)))
    # untouched providers keep their base streams (shared objects)
    other = (victim + 1) % pool.n_providers
    assert tr.dets[0][other] is pool.base_traces.dets[0][other]


def test_recurring_regime_shares_one_core():
    pool = _pool()                             # outage recovers at 2h/3
    assert pool.core_at(0) is pool.core_at(299)
    assert pool.core_at(0) is not pool.core_at(150)


def test_price_change_shares_detection_core():
    pool = _pool("price_war")
    c0, c1 = pool.core_at(0), pool.core_at(100)    # aws at 0.25x fee
    assert c0 is c1                            # economics-only: same core
    v = pool.view_at(100)
    assert v.costs[0] == pytest.approx(0.25)
    assert v.econ_key != pool.view_at(0).econ_key


def test_drift_regenerates_only_the_drifted_provider():
    pool = _pool("accuracy_drift")
    tr = pool.traces_at(pool.schedule.horizon // 4)    # aws drift 0.7
    base = pool.base_traces
    assert any(not np.array_equal(tr.dets[t][0].boxes, base.dets[t][0].boxes)
               for t in range(len(pool)))
    # drift is monotone against the shared difficulty latents: scaled-down
    # recall can only lose true positives, never invent them
    google = 2
    for t in range(len(pool)):
        assert tr.dets[t][google] is base.dets[t][google]
    # deterministic: rebuilding the same regime gives identical arrays
    pool2 = _pool("accuracy_drift")
    tr2 = pool2.traces_at(pool.schedule.horizon // 4)
    for t in range(len(pool)):
        np.testing.assert_array_equal(tr.dets[t][0].boxes,
                                      tr2.dets[t][0].boxes)


def test_arrival_expands_roster_with_static_action_space():
    pool = _pool("provider_churn")
    assert pool.n_providers == len(PROVS) + 1
    v0 = pool.view_at(0)
    assert not v0.active[-1] and v0.costs[-1] == 0.0
    vend = pool.view_at(pool.schedule.horizon - 1)
    assert vend.active[-1] and vend.costs[-1] > 0
    # the challenger's detections exist in the roster traces and surface
    # once it arrives
    tr = pool.traces_at(pool.schedule.horizon - 1)
    assert sum(len(tr.dets[t][-1]) for t in range(len(pool))) > 0


def test_demand_weights():
    pool = _pool("flash_crowd")
    h = pool.schedule.horizon
    assert pool.demand_weights_at(0, range(len(pool))) is None
    w = pool.demand_weights_at(h // 2, range(len(pool)))
    assert w is not None and w.sum() == pytest.approx(1.0)
    focus = {"bottle", "cup", "dining table"}
    hit = [bool(pool._img_cats[i] & focus) for i in range(len(pool))]
    if any(hit) and not all(hit):
        assert w[hit.index(True)] > w[hit.index(False)]


def test_oracle_restricts_to_active_and_breaks_ties_cheap():
    pool = _pool()
    victim = int(np.argmax([p.base_recall for p in PROVS]))
    for img in range(3):
        m, r = pool.oracle(img, 150, -0.05)
        assert not (m >> victim) & 1           # never picks the dead one
        m2, r2 = pool.oracle(img, 150, -0.05)  # memo hit
        assert (m2, r2) == (m, r)


# ---------------------------------------------------------------------------
# non-stationary env
# ---------------------------------------------------------------------------

def test_env_clock_and_segment_costs():
    pool = _pool("price_war", horizon=120, n=24)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=-0.1,
                                observe_pool=False, seed=0)
    a = np.asarray([1, 0, 0], np.float32)      # aws only
    img = int(env.train_idx[0])
    r0, v0, c0 = env.evaluate_action(img, a)
    assert c0 == pytest.approx(1.0)
    env.set_clock(40)                          # aws at 0.25x
    r1, v1, c1 = env.evaluate_action(img, a)
    assert c1 == pytest.approx(0.25)
    assert v1 == v0                            # detections unchanged
    assert r1 == pytest.approx(v0 - 0.1 * 0.25)


def test_env_matches_static_env_on_empty_schedule():
    sch = ScenarioSchedule("static", 50, [])
    pool = DynamicProviderPool(PROVS, sch, n_images=24, seed=3)
    env_d = NonStationaryArmolEnv(pool, mode="gt", beta=-0.05,
                                  observe_pool=False, seed=5)
    from repro.federation.env import ArmolEnv
    env_s = ArmolEnv(pool.base_traces, mode="gt", beta=-0.05, seed=5)
    assert env_d.state_dim == env_s.state_dim
    acts = np.asarray([[1, 1, 0], [0, 1, 1], [1, 1, 1]], np.float32)
    imgs = [int(i) for i in env_s.train_idx[:3]]
    out_d = env_d.evaluate_actions(imgs, acts)
    out_s = env_s.evaluate_actions(imgs, acts)
    for k in ("reward", "ap50", "cost"):
        np.testing.assert_array_equal(out_d[k], out_s[k])


def test_step_lanes_advances_clock_and_flags_switch():
    pool = _pool("provider_outage", horizon=60, n=24)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=True, seed=0)
    env.reset_lanes(2)
    switches = 0
    for _ in range(30):
        a = np.ones((2, env.n_providers), np.float32)
        _, _, _, infos, _ = env.step_lanes(a)
        switches += bool(infos["switched"])
    assert env.clock == 60
    assert switches == pool.schedule.n_segments - 1


def test_observe_pool_status_features_track_segments():
    pool = _pool("provider_outage", horizon=60, n=24)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=True, seed=0)
    n = env.n_providers
    assert env.state_dim == env._base_dim + 2 * n
    victim = int(np.argmax([p.base_recall for p in PROVS]))
    active_col = env._base_dim + victim
    assert env.features[0, active_col] == 1.0
    env.set_clock(30)                          # outage window (h/3..2h/3)
    assert env.features[0, active_col] == 0.0
    # features_at never disturbs the live matrix
    f0 = env.features_at(0, [0])
    assert f0[0, active_col] == 1.0
    assert env.features[0, active_col] == 0.0


def test_empty_subset_of_down_providers_is_minus_one():
    pool = _pool("provider_outage", horizon=300, n=24)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=0)
    victim = int(np.argmax([p.base_recall for p in PROVS]))
    a = np.zeros(env.n_providers, np.float32)
    a[victim] = 1.0
    out = env.evaluate_actions_at(env.train_idx[:4], np.tile(a, (4, 1)),
                                  150)
    np.testing.assert_array_equal(out["reward"], -1.0)
    np.testing.assert_array_equal(out["cost"], 0.0)


def test_invalid_pool_duplicate_names():
    sch = ScenarioSchedule("t", 10, [])
    with pytest.raises(ValueError):
        DynamicProviderPool(PROVS + [PROVS[0]], sch, n_images=4)
