"""Online-adaptation driver: segment evaluation/oracle/regret accounting
and an end-to-end (slow) recovery smoke through a real regime switch."""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.sac import SAC, SACConfig
from repro.federation.providers import default_providers
from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                             build_scenario, evaluate_segment, run_online)
from repro.scenarios.schedule import ProviderEvent, ScenarioSchedule

PROVS = default_providers()


class FixedAgent:
    """Constant-subset agent (batch-polymorphic like the real heads)."""

    def __init__(self, action):
        self.action = np.asarray(action, np.float32)
        self.state = None

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _env(name="provider_outage", horizon=300, n=24, **kw):
    sch = build_scenario(name, PROVS, horizon=horizon)
    pool = DynamicProviderPool(PROVS, sch, n_images=n, seed=0)
    kw.setdefault("observe_pool", False)
    return NonStationaryArmolEnv(pool, mode="gt", beta=-0.05, seed=1, **kw)


def test_evaluate_segment_reward_matches_manual():
    env = _env()
    agent = FixedAgent([0, 1, 1])
    rec = evaluate_segment(agent, env, 150)
    imgs = env.test_idx
    out = env.evaluate_actions_at(imgs, np.tile(agent.action,
                                                (len(imgs), 1)), 150)
    assert rec["reward"] == pytest.approx(float(np.mean(out["reward"])),
                                          abs=1e-4)
    orc = np.mean([env.pool.oracle(int(i), 150, env.beta)[1]
                   for i in imgs])
    assert rec["oracle_reward"] == pytest.approx(float(orc), abs=1e-4)
    assert rec["recovery"] == pytest.approx(rec["reward"] / orc, abs=1e-3)
    assert rec["regret"] == pytest.approx(
        rec["oracle_reward"] - rec["reward"], abs=1e-3)


def test_oracle_dominates_any_policy_per_segment():
    env = _env("accuracy_drift")
    for action in ([1, 1, 1], [0, 1, 1], [1, 0, 0]):
        rec = evaluate_segment(FixedAgent(action), env, 200)
        assert rec["reward"] <= rec["oracle_reward"] + 1e-9
        # recovery may be negative for a terrible policy, never > 1
        assert rec["recovery"] <= 1.0 + 1e-9


def test_oracle_beats_full_ensemble_under_fee_pressure():
    env = _env("price_war")
    rec = evaluate_segment(FixedAgent([1, 1, 1]), env, 10)
    assert rec["oracle_reward"] > rec["reward"]


@pytest.mark.slow
def test_run_online_end_to_end_recovers_through_outage():
    sch = build_scenario("provider_outage", PROVS, horizon=900)
    pool = DynamicProviderPool(PROVS, sch, n_images=60, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=-0.03,
                                observe_pool=True, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, alpha=0.02,
                          lr=3e-4, gamma=0.0, hidden=(32, 32)))
    res = run_online(agent, env, lanes=4, seed=0, log=None)
    segs, summary = res["segments"], res["summary"]
    assert len(segs) == sch.n_segments
    assert summary["steps"] >= sch.horizon
    assert [s["seg"] for s in segs] == list(range(sch.n_segments))
    # the driver must keep a meaningful fraction of oracle reward after
    # every switch (the benchmark gates >= 0.8 at full budget; the test
    # budget is a third of that, so assert a conservative floor)
    assert summary["min_recovery_post_switch"] >= 0.6
    for s in segs:
        assert 0.0 <= s["cache_hit_rate"] <= 1.0
        assert s["oracle_reward"] >= s["reward"] - 1e-9
    # regime memory: outage recovery returns to the base dets regime, so
    # only two trace sets / cores exist over four segments
    assert summary["pool"]["cores"] == 2


@pytest.mark.slow
def test_run_online_relabel_keeps_buffer_on_price_only_switch():
    sch = ScenarioSchedule("p", 240, [ProviderEvent(120, "price", "aws",
                                                    3.0)])
    pool = DynamicProviderPool(PROVS, sch, n_images=24, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=-0.1,
                                observe_pool=True, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, gamma=0.0,
                          hidden=(16, 16)))
    res = run_online(agent, env, lanes=2, seed=0, log=None,
                     start_steps=40, explore_steps=20, batch_size=32)
    assert res["summary"]["pool"]["cores"] == 1    # one detection regime
    assert len(res["segments"]) == 2
