"""AsyncFederationService under a scenario pool: mid-stream regime swaps
with exact vectorized accounting, and the request-driven scenario clock."""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.providers import default_providers
from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                             build_scenario)
from repro.scenarios.schedule import ProviderEvent, ScenarioSchedule
from repro.serving.async_service import AsyncFederationService

PROVS = default_providers()


class FixedAgent:
    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _pool_env(name="provider_outage", horizon=300, n=24):
    sch = build_scenario(name, PROVS, horizon=horizon)
    pool = DynamicProviderPool(PROVS, sch, n_images=n, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    return pool, env


def test_swap_changes_costs_latency_and_detections():
    pool, env = _pool_env()
    victim = int(np.argmax([p.base_recall for p in PROVS]))
    agent = FixedAgent(np.ones(env.n_providers))
    with AsyncFederationService(env, agent, max_batch=4, workers=2,
                                pool=pool) as svc:
        r_base = svc.handle(3)
        svc.set_clock(150)                     # inside the outage
        r_out = svc.handle(3)
        svc.set_clock(pool.schedule.horizon - 1)
        r_back = svc.handle(3)
    n_up = env.n_providers - 1
    assert r_base.cost_milli_usd == pytest.approx(
        float(sum(p.cost_milli_usd for p in PROVS)))
    assert r_out.cost_milli_usd == pytest.approx(
        float(sum(p.cost_milli_usd for i, p in enumerate(PROVS)
                  if i != victim)))
    # selecting the dead provider costs its timeout in the latency model
    view = pool.view_at(150)
    want_lat = (svc._svc.transmission_ms * env.n_providers
                + float(np.max(view.latencies)))
    assert r_out.latency_ms == pytest.approx(want_lat)
    assert pool.outage_timeout_ms == float(np.max(view.latencies))
    # recovered regime serves the base-regime answer again, exactly
    np.testing.assert_array_equal(r_base.detections.boxes,
                                  r_back.detections.boxes)
    assert r_back.cost_milli_usd == r_base.cost_milli_usd
    assert n_up == env.n_providers - 1


def test_request_clock_advances_one_step_per_request():
    pool, env = _pool_env(horizon=64, n=24)
    agent = FixedAgent([0, 1, 1])
    with AsyncFederationService(env, agent, max_batch=4, workers=2,
                                pool=pool) as svc:
        svc.handle_many(list(range(10)))
        assert svc.clock == 10
        svc.handle(0)
        assert svc.clock == 11


def test_swap_matches_synchronous_segment_accounting():
    """Every result under the scenario service equals the synchronous
    per-segment accounting of the same (image, action) at the same
    scenario step."""
    sch = ScenarioSchedule("p", 40, [ProviderEvent(20, "price", "aws",
                                                   4.0)])
    pool = DynamicProviderPool(PROVS, sch, n_images=24, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    agent = FixedAgent([1, 0, 1])
    imgs = [int(i) for i in
            np.random.default_rng(0).integers(0, 24, 40)]
    with AsyncFederationService(env, agent, max_batch=1, workers=1,
                                pool=pool) as svc:
        got = [svc.handle(i) for i in imgs]    # clock == request index
    for step, (img, res) in enumerate(zip(imgs, got)):
        view = pool.view_at(step)
        core = pool.core_at(step)
        sel = res.action > 0.5
        want_cost = float(np.sum(view.costs[sel]))
        assert res.cost_milli_usd == pytest.approx(want_cost)
        ref = core.ensemble(img, core.mask_of(res.action))
        np.testing.assert_array_equal(res.detections.boxes, ref.boxes)
    # fees doubled across the boundary for the aws-including subset
    assert got[0].cost_milli_usd == pytest.approx(2.0)
    assert got[-1].cost_milli_usd == pytest.approx(5.0)


def test_no_pool_service_is_unchanged():
    """Without a pool the service never consults a scenario clock and the
    sharded core is built from the env core as before."""
    pool, env = _pool_env()
    agent = FixedAgent([0, 1, 0])
    with AsyncFederationService(env, agent, max_batch=2,
                                workers=2) as svc:
        r = svc.handle(5)
        assert svc.clock == 0
    assert r.cost_milli_usd == pytest.approx(float(PROVS[1].cost_milli_usd))


def test_pool_invalidate_sweeps_every_materialized_segment_core():
    """Pool-level invalidation must reach EVERY segment core the pool has
    built (the thread-backend counterpart of the process workers'
    all-regime fan-out), so a revisited regime recomputes instead of
    serving stale cached ensembles — and recomputes identically when the
    underlying traces are unchanged."""
    pool, env = _pool_env(horizon=300, n=24)
    full = (1 << pool.n_providers) - 1
    # materialize two segments' cores and warm image 3 in both
    steps = [0, 299]
    before = {}
    for s in steps:
        core = pool.core_at(s)
        before[s] = core.ap50(3, core.full_mask & full)
        assert 3 in core.cached_images()
    dropped = pool.invalidate_images([3])
    assert dropped >= len({pool.view_at(s).dets_key for s in steps})
    for s in steps:                 # swept everywhere, BEFORE any rewarm
        assert 3 not in pool.core_at(s).cached_images()
    for s in steps:                 # ... and recomputes loss-free
        assert pool.core_at(s).ap50(3, pool.core_at(s).full_mask
                                    & full) == before[s]
