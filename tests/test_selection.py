"""Selector policies (repro.selection): accounting parity with the
serving planes, budget/threshold contracts, and hybrid >= cascade."""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                             build_scenario)
from repro.selection import CascadeSelector, HybridSelector, MCTSelector
from repro.selection.cascade import detection_confidence
from repro.selection.frontier import score_masks_fn
from repro.serving.async_service import AsyncFederationService
from repro.serving.federation_service import FederationService

PROVS = default_providers()
N = len(PROVS)


def _static_env(n=40, seed=0):
    traces = generate_traces(PROVS, n, seed=seed)
    return ArmolEnv(traces, mode="gt", beta=0.0, seed=seed + 1)


def _pool_env(name, horizon=120, n=24, seed=0):
    sch = build_scenario(name, PROVS, horizon=horizon)
    pool = DynamicProviderPool(PROVS, sch, n_images=n, seed=seed)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=seed + 1)
    return pool, env


# -- accounting parity ------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda env: CascadeSelector(env, beta=-0.05),
    lambda env: MCTSelector(env, budget=2.0, seed=0),
], ids=["cascade", "mct"])
def test_selector_sync_async_accounting_parity(make):
    """launch/serve.py --policy cascade acceptance: the async plane's
    accounting is bit-identical to the thread-path FederationService for
    the same selector (same fees, latencies, actions, detections)."""
    env = _static_env()
    sel = make(env)
    imgs = [int(i) for i in env.test_idx[:10]] * 2       # repeats too
    sync = FederationService(env, sel).handle_many(imgs)
    with AsyncFederationService(env, sel, max_batch=4, workers=2) as svc:
        futs = [svc.submit(i) for i in imgs]
        async_res = [f.result() for f in futs]
    for a, b in zip(sync, async_res):
        assert a.cost_milli_usd == b.cost_milli_usd
        assert a.latency_ms == b.latency_ms
        np.testing.assert_array_equal(a.action, b.action)
        np.testing.assert_array_equal(a.detections.boxes,
                                      b.detections.boxes)
        np.testing.assert_array_equal(a.detections.scores,
                                      b.detections.scores)


def test_selector_handle_matches_handle_many():
    env = _static_env()
    sel = CascadeSelector(env, beta=-0.05)
    svc = FederationService(env, sel)
    imgs = [int(i) for i in env.test_idx[:6]]
    batched = svc.handle_many(imgs)
    for img, want in zip(imgs, batched):
        got = svc.handle(img)
        assert got.cost_milli_usd == want.cost_milli_usd
        assert got.latency_ms == want.latency_ms
        np.testing.assert_array_equal(got.action, want.action)


def test_selector_fees_match_selected_masks():
    """Billed fee is exactly the sum of the selected providers' fees —
    the selector's masks and the service's accounting agree."""
    env = _static_env()
    sel = MCTSelector(env, budget=2.0, seed=3)
    rng = np.random.default_rng(0)
    imgs = [int(i) for i in env.train_idx[:8]]
    sel.observe(imgs, sel.explore_masks(imgs), )
    serve = [int(i) for i in env.test_idx[:12]]
    masks = sel.select_masks(serve)
    results = FederationService(env, sel).handle_many(serve)
    costs = np.asarray(env.costs, np.float64)
    for m, r in zip(masks, results):
        want = sum(costs[j] for j in range(N) if int(m) >> j & 1)
        assert r.cost_milli_usd == pytest.approx(want)
    del rng


def test_selector_shares_the_service_core_cache():
    """Selectors ride the same SubsetEvaluationCore memo as the service:
    re-serving the same requests is all hits, no new ensemble work."""
    env = _static_env()
    sel = CascadeSelector(env, beta=-0.05)
    svc = FederationService(env, sel)
    imgs = [int(i) for i in env.test_idx[:8]]
    svc.handle_many(imgs)
    misses = env.core.stats["ens_misses"]
    hits = env.core.stats["ens_hits"]
    svc.handle_many(imgs)
    assert env.core.stats["ens_misses"] == misses
    assert env.core.stats["ens_hits"] > hits


# -- cascade contracts ------------------------------------------------------

def test_cascade_confident_images_pay_base_only():
    """The gate contract, swept across injected thresholds: once an
    image's confidence clears the threshold, the cascade serves it with
    the base provider ALONE — it never pays a second provider."""
    env = _static_env(n=60)
    imgs = [int(i) for i in env.test_idx]
    base = CascadeSelector(env, beta=-0.05)
    confs = np.asarray([base.confidence(i) for i in imgs])
    grid = np.unique(np.concatenate([confs, [0.0, 0.35, 0.9, np.inf]]))
    for th in grid:
        cas = CascadeSelector(env, beta=-0.05, threshold=float(th))
        masks = cas.select_masks(imgs)
        passes = confs >= th
        np.testing.assert_array_equal(
            masks[passes], np.full(passes.sum(), cas.base_mask),
            err_msg=f"threshold={th}: a confident image paid for more "
                    f"than the base provider")
        assert all(int(m) & cas.base_mask for m in masks)


def test_cascade_base_follows_cheapest_active():
    """Under an outage the per-segment gate re-bases onto the cheapest
    ACTIVE provider and keeps escalations inside the active roster."""
    pool, env = _pool_env("provider_outage", horizon=120, n=16)
    cas = CascadeSelector(env, beta=-0.05)
    for step in (0, pool.schedule.horizon // 2, pool.schedule.horizon - 1):
        view = pool.view_at(step)
        active_mask = int(sum(1 << j for j in np.flatnonzero(view.active)))
        _, b, esc = cas.gate(env.test_idx[:4], step=step)
        assert view.active[b]
        assert esc & ~active_mask == 0
        masks = cas.select_masks(env.test_idx[:4], step=step)
        assert all(int(m) & ~active_mask == 0 for m in masks)


def test_detection_confidence_shape():
    class Dets:
        def __init__(self, scores):
            self.scores = np.asarray(scores, np.float32)
    assert detection_confidence(Dets([])) == 0.0
    assert detection_confidence(Dets([0.8])) == pytest.approx(0.4)
    assert detection_confidence(Dets([0.8, 0.6])) == pytest.approx(
        0.8 * 2 / 3)


# -- MCT contracts ----------------------------------------------------------

def test_mct_respects_budget_with_single_floor():
    env = _static_env(n=40)
    m = MCTSelector(env, budget=1.5, seed=0)
    imgs = [int(i) for i in env.train_idx[:16]]
    m.observe(imgs, m.explore_masks(imgs))
    costs = np.asarray(env.costs, np.float64)
    for mk in m.select_masks([int(i) for i in env.test_idx]):
        mk = int(mk)
        assert mk != 0                       # never the empty ensemble
        fee = sum(costs[j] for j in range(N) if mk >> j & 1)
        assert fee <= 1.5 or bin(mk).count("1") == 1


def test_mct_learns_from_counterfactual_replay():
    """Observing paid subsets moves the regressors off the cold-start
    cheapest-single answer."""
    env = _static_env(n=40)
    m = MCTSelector(env, budget=3.0, seed=0)
    cold = m.select_masks([int(i) for i in env.test_idx[:6]])
    assert set(int(c) for c in cold) == {1 << m._cheapest_active(
        np.asarray(env.costs, np.float64), np.ones(N, bool))}
    imgs = [int(i) for i in env.train_idx]
    pairs = m.observe(imgs, np.full(len(imgs), (1 << N) - 1))
    assert pairs > 0 and m.n_observed == len(imgs)
    warm = m.select_masks([int(i) for i in env.test_idx[:6]])
    assert any(bin(int(w)).count("1") > 1 for w in warm)


# -- hybrid >= cascade ------------------------------------------------------

@pytest.mark.parametrize("scenario", ["price_war", "provider_outage"])
def test_hybrid_at_least_cascade_reward(scenario):
    """The validated escalation choice keeps the hybrid at or above the
    pure cascade's segment-mean reward — even when the RL arm it fronts
    is adversarially bad (here: an always-everything policy)."""
    pool, env = _pool_env(scenario, horizon=160, n=32)
    beta = -0.1
    cas = CascadeSelector(env, beta=beta)
    bad_rl = lambda imgs, step: np.full(len(imgs), (1 << N) - 1, np.int64)
    hyb = HybridSelector(env, cascade=cas, rl_masks_fn=bad_rl)
    pt_c = score_masks_fn(
        env, lambda imgs, step: cas.select_masks(imgs, step=step),
        beta=beta)
    pt_h = score_masks_fn(
        env, lambda imgs, step: hyb.select_masks(imgs, step=step),
        beta=beta)
    # calibration-split validation, test-split scoring: allow epsilon
    assert pt_h["reward"] >= pt_c["reward"] - 0.02


def test_hybrid_promotes_a_good_rl_arm():
    """A strictly-better RL arm (the per-image oracle) must be promoted
    by the per-segment validation and beat the cascade outright."""
    pool, env = _pool_env("price_war", horizon=120, n=24)
    beta = -0.1

    def oracle_masks(imgs, step):
        return np.asarray([pool.oracle(int(i), int(step or 0), beta,
                                       against=env._against)[0]
                           for i in imgs], np.int64)

    cas = CascadeSelector(env, beta=beta)
    hyb = HybridSelector(env, cascade=cas, rl_masks_fn=oracle_masks)
    pt_c = score_masks_fn(
        env, lambda imgs, step: cas.select_masks(imgs, step=step),
        beta=beta)
    pt_h = score_masks_fn(
        env, lambda imgs, step: hyb.select_masks(imgs, step=step),
        beta=beta)
    assert pt_h["reward"] >= pt_c["reward"] - 1e-9


def test_selector_state_adapters_roundtrip():
    """select_action/select_action_batch recover the image from the
    feature row, so agent_policy/evaluate_policy work unchanged."""
    env = _static_env()
    cas = CascadeSelector(env, beta=-0.05)
    imgs = [int(i) for i in env.test_idx[:5]]
    via_states, _ = cas.select_action_batch(env.features[np.asarray(imgs)])
    direct = cas.select_for_images(imgs)
    np.testing.assert_array_equal(via_states, direct)
    one, aux = cas.select_action(env.features[imgs[0]])
    assert aux is None
    np.testing.assert_array_equal(one, direct[0])
    with pytest.raises(KeyError):
        cas.select_action(np.full(env.state_dim, -123.0, np.float32))
