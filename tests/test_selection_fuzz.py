"""Property-based cascade contracts (hypothesis).

The deterministic sweep in ``test_selection.py`` covers the observed
confidence values; here hypothesis drives arbitrary thresholds, betas
and trace seeds at the same contract: the cascade NEVER pays for a
second provider once an image's confidence clears the threshold, and
every served subset contains the base provider.
"""
import numpy as np
import pytest

pytest.importorskip("jax")
hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.selection import CascadeSelector

PROVS = default_providers()
_ENVS = {}


def _env(seed: int) -> ArmolEnv:
    if seed not in _ENVS:
        traces = generate_traces(PROVS, 30, seed=seed)
        _ENVS[seed] = ArmolEnv(traces, mode="gt", beta=0.0, seed=seed + 1)
    return _ENVS[seed]


@settings(max_examples=40, deadline=None)
@given(threshold=st.one_of(st.floats(0.0, 1.2), st.just(float("inf"))),
       beta=st.floats(-1.0, 0.0),
       seed=st.integers(0, 3))
def test_cascade_never_pays_past_a_passing_threshold(threshold, beta,
                                                     seed):
    env = _env(seed)
    cas = CascadeSelector(env, beta=beta, threshold=threshold)
    imgs = [int(i) for i in env.test_idx]
    confs = np.asarray([cas.confidence(i) for i in imgs])
    masks = cas.select_masks(imgs)
    for conf, mask in zip(confs, masks):
        mask = int(mask)
        assert mask & cas.base_mask, "every subset contains the base"
        if conf >= cas.threshold:
            assert mask == cas.base_mask, (
                f"confidence {conf} passed threshold {cas.threshold} but "
                f"the cascade paid for mask {mask:b}")
