"""Process-backend serving shards: parity with the thread backend,
invalidation fan-out across process boundaries, mid-stream pool swaps,
and clean failure on worker crash.

The whole file is slow-marked: every test spawns (or reuses) worker
processes, which cost seconds each on the spawn context.  The nightly
--full lane runs them; tier-1 stays fast.
"""
import threading
import time

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.sac import SAC, SACConfig
from repro.federation.env import ArmolEnv
from repro.federation.evaluation import SubsetEvaluationCore
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving.async_service import AsyncFederationService
from repro.serving.federation_service import FederationService
from repro.serving.mp_shards import (ProcessShardedSubsetEvaluationCore,
                                     ShardWorkerError)

pytestmark = pytest.mark.slow

TR = generate_traces(default_providers(), 40, seed=5)
ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
N = TR.n_providers


@pytest.fixture(scope="module")
def proc_core():
    """One spawned worker pool shared by the direct-core tests (workers
    cost seconds to spawn; the tests only need fresh CACHES, which
    ``invalidate_images`` provides)."""
    core = ProcessShardedSubsetEvaluationCore.like(ENV.core, 3)
    yield core
    core.close()


class FixedAgent:
    """Always selects the same subset (batched-aware, like the real ones)."""

    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _assert_results_equal(got, ref):
    np.testing.assert_array_equal(got.action, ref.action)
    assert got.cost_milli_usd == ref.cost_milli_usd
    assert got.latency_ms == ref.latency_ms
    np.testing.assert_array_equal(got.detections.boxes, ref.detections.boxes)
    np.testing.assert_array_equal(got.detections.scores,
                                  ref.detections.scores)
    np.testing.assert_array_equal(got.detections.labels,
                                  ref.detections.labels)


# -- direct core parity ----------------------------------------------------

def test_core_matches_unsharded_bit_for_bit(proc_core):
    ref = SubsetEvaluationCore(TR)
    rng = np.random.default_rng(0)
    for _ in range(25):
        img = int(rng.integers(0, len(TR)))
        mask = int(rng.integers(0, 1 << N))
        a = proc_core.ensemble(img, mask)
        b = ref.ensemble(img, mask)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.labels, b.labels)
        if mask:
            assert proc_core.ap50(img, mask) == ref.ap50(img, mask)
        assert proc_core.cost(mask) == ref.cost(mask)


def test_eval_on_preserves_request_order(proc_core):
    imgs = [0, 3, 6, 9, 12]            # all home on shard 0 (W=3)
    masks = [7, 1, 5, 7, 2]
    got = proc_core.eval_on(0, imgs, masks)
    ref = SubsetEvaluationCore(TR)
    for d, img, m in zip(got, imgs, masks):
        np.testing.assert_array_equal(d.boxes, ref.ensemble(img, m).boxes)


def test_shard_partition_invariants(proc_core):
    proc_core.invalidate_images(range(len(TR)))
    imgs = [0, 1, 2, 3, 4, 5, 8, 9]
    proc_core.precompute(imgs)
    shard_imgs = proc_core.shard_images()
    flat = [i for s in shard_imgs for i in s]
    assert sorted(flat) == imgs                      # no dupes, no strays
    for sid, s_imgs in enumerate(shard_imgs):
        assert all(i % 3 == sid for i in s_imgs)
    assert proc_core.partition([0, 1, 2, 3, 4, 5, 8, 9]) == {
        0: [0, 3, 9], 1: [1, 4], 2: [2, 5, 8]}


def test_invalidate_fans_out_and_recompute_is_identical(proc_core):
    mask = (1 << N) - 1
    imgs = [0, 1, 2, 7, 8]
    proc_core.invalidate_images(range(len(TR)))      # known-clean slate
    ref = SubsetEvaluationCore(TR)
    before = {}
    for i in imgs:
        before[i] = proc_core.ap50(i, mask)
        assert before[i] == ref.ap50(i, mask)
    drop = imgs + [39]                  # 39 never cached on either side
    assert proc_core.invalidate_images(drop) == ref.invalidate_images(drop)
    for i in imgs:                      # loss-free: recompute == before
        assert proc_core.ap50(i, mask) == before[i]


def test_worker_crash_is_clean_error_not_hang(proc_core):
    """This test kills its own dedicated pool (the shared one must stay
    healthy for other tests)."""
    core = ProcessShardedSubsetEvaluationCore.like(ENV.core, 2)
    try:
        core.ensemble(0, 3)
        with core._locks[0]:
            core._conns[0].send((0, "crash"))     # test hook: os._exit(13)
        t0 = time.time()
        with pytest.raises(ShardWorkerError, match="shard 0"):
            core.ensemble(0, 5)                   # img 0 homes on shard 0
        assert time.time() - t0 < 30.0            # error, not a hang
        assert len(core.ensemble(1, 7)) >= 0      # shard 1 still serves
    finally:
        core.close()
    with pytest.raises(ShardWorkerError):
        core.ensemble(1, 1)                       # closed pool refuses


# -- async service: backend parity ----------------------------------------

def test_async_service_process_backend_matches_sync_reference():
    agent = SAC(SACConfig(state_dim=ENV.state_dim, n_providers=N,
                          hidden=(16, 16)))
    svc = FederationService(ENV, agent)
    imgs = [int(i) for i in
            np.random.default_rng(3).integers(0, len(TR), 40)]
    refs = [svc.handle(i) for i in imgs]
    with AsyncFederationService(ENV, agent, max_batch=8, workers=2,
                                shard_backend="process") as asvc:
        got = asvc.handle_many(imgs)
        stats = dict(asvc.stats)
    for g, r in zip(got, refs):
        _assert_results_equal(g, r)
    assert stats["requests"] == len(imgs)
    assert stats["flush_full"] >= 1


def test_async_service_backends_bit_identical_under_concurrency():
    agent = FixedAgent([0, 1, 1])
    rng = np.random.default_rng(11)
    streams = [[int(i) for i in rng.integers(0, len(TR), 40)]
               for _ in range(3)]
    results = {}
    for backend in ("thread", "process"):
        collected = [None] * len(streams)
        with AsyncFederationService(ENV, agent, max_batch=8, workers=2,
                                    max_wait_ms=1.0,
                                    shard_backend=backend) as asvc:
            def client(k):
                futs = [asvc.submit(i) for i in streams[k]]
                collected[k] = [f.result() for f in futs]

            threads = [threading.Thread(target=client, args=(k,))
                       for k in range(len(streams))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        results[backend] = collected
    for k in range(len(streams)):
        for a, b in zip(results["thread"][k], results["process"][k]):
            _assert_results_equal(a, b)


def test_process_backend_empty_selection_zero_cost():
    with AsyncFederationService(ENV, FixedAgent([0] * N), max_batch=4,
                                workers=2, shard_backend="process") as asvc:
        res = asvc.handle(5)
    assert len(res.detections) == 0
    assert res.cost_milli_usd == 0.0 and res.latency_ms == 0.0


def test_async_service_worker_death_fails_requests_cleanly():
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=4,
                                workers=2, shard_backend="process") as asvc:
        assert asvc.handle(0) is not None
        with asvc.core._locks[0]:
            asvc.core._conns[0].send((0, "crash"))
        with pytest.raises(ShardWorkerError):
            asvc.submit(0).result(timeout=60)     # img 0 -> dead shard 0
        # the other shard keeps serving
        assert asvc.submit(1).result(timeout=60).cost_milli_usd == \
            ENV.costs[0]


def test_bad_backend_rejected():
    with pytest.raises(ValueError, match="shard_backend"):
        AsyncFederationService(ENV, FixedAgent([1, 0, 0]),
                               shard_backend="greenlet")


def test_stale_reply_id_condemns_shard_never_misattributes():
    """Reply correlation is explicit on the wire: a reply whose request
    id does not match the in-flight request means the pipe is
    desynchronized (exactly the state a timed-out worker's late answer
    leaves behind) — the shard must be condemned, never have the stale
    rows attributed to the current request."""
    core = ProcessShardedSubsetEvaluationCore.like(ENV.core, 2)
    try:
        real_conn = core._conns[0]

        class StaleConn:
            """Answers every request with the PREVIOUS request's id —
            simulating replies arriving shuffled/shifted by one."""
            rid = 0

            def send(self, msg):
                self.rid = msg[0]

            def poll(self, timeout=0.0):
                return True

            def recv(self):
                return (self.rid - 1, "ok", [])

            def close(self):
                real_conn.close()

        core._conns[0] = StaleConn()
        with pytest.raises(ShardWorkerError, match="reply correlation"):
            core.ensemble(0, 3)               # img 0 homes on shard 0
        assert core._failed[0]
        # the survivor keeps serving correct rows
        ref = SubsetEvaluationCore(TR)
        got = core.ensemble(1, 3)
        np.testing.assert_array_equal(got.boxes, ref.ensemble(1, 3).boxes)
    finally:
        core.close()


# -- async service: mid-stream pool swap across the process boundary ------

def test_pool_swap_parity_thread_vs_process():
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario)
    providers = default_providers()
    schedule = build_scenario("provider_outage", providers, horizon=90)
    pool = DynamicProviderPool(providers, schedule, n_images=30, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, hidden=(16, 16)))
    reqs = [int(i) for i in np.random.default_rng(0).integers(0, 30, 90)]
    outs = {}
    for backend in ("thread", "process"):
        # max_batch=1: the scenario clock advances one request per flush,
        # so both backends account request i under the SAME segment and
        # results must match bit for bit across every switch
        with AsyncFederationService(env, agent, max_batch=1, workers=2,
                                    pool=pool, shard_backend=backend) as s:
            outs[backend] = [s.handle(i) for i in reqs]
            segs = pool.schedule.segment_index(s.clock - 1) + 1
    assert segs >= 2                        # the stream crossed a switch
    for a, b in zip(outs["thread"], outs["process"]):
        _assert_results_equal(a, b)


def test_service_invalidate_reaches_worker_and_pool_caches():
    """`AsyncFederationService.invalidate_images` is the one entry point
    that sweeps BOTH sides of a pool-backed process service: the worker
    processes' per-regime cores and the pool's parent-side segment
    cores — and results recompute identically afterwards."""
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario)
    providers = default_providers()
    schedule = build_scenario("provider_outage", providers, horizon=60)
    pool = DynamicProviderPool(providers, schedule, n_images=20, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    pool.core_at(0).ap50(3, 7)          # warm a parent-side segment core
    with AsyncFederationService(env, FixedAgent([1, 1, 1]), max_batch=1,
                                workers=2, pool=pool,
                                shard_backend="process") as svc:
        before = svc.handle(3)
        assert svc.core.cache_sizes()["tables"] >= 1
        dropped = svc.invalidate_images([3])
        assert dropped >= 2             # worker core(s) + pool-side core
        assert 3 not in pool.core_at(0).cached_images()
        svc.set_clock(0)
        _assert_results_equal(svc.handle(3), before)


def test_snapshot_carries_regeneration_seed():
    """Regenerated segments must follow the SNAPSHOT's seed (the pool
    that authored it), not any worker-local default: a core built
    straight from base traces — without ``for_pool`` — still answers
    drifted segments bit-identically for a pool seeded != 0."""
    from repro.scenarios import DynamicProviderPool, build_scenario
    providers = default_providers()
    schedule = build_scenario("accuracy_drift", providers, horizon=100)
    pool = DynamicProviderPool(providers, schedule, n_images=12, seed=7)
    core = ProcessShardedSubsetEvaluationCore(
        pool.base_traces, n_shards=2, voting=pool.voting,
        ablation=pool.ablation, use_kernel=pool.use_kernel)
    try:
        drifted = next(s for s in range(100) if pool.view_at(s).dets_key
                       != pool.view_at(0).dets_key)
        snap = pool.snapshot_at(drifted)
        ref = pool.core_at(drifted)
        for img in range(12):
            a = core.ensemble(img, 7, snapshot=snap)
            b = ref.ensemble(img, 7)
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
    finally:
        core.close()


def test_pool_snapshot_installs_once_per_worker_per_fingerprint():
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario)
    providers = default_providers()
    schedule = build_scenario("price_war", providers, horizon=80)
    pool = DynamicProviderPool(providers, schedule, n_images=20, seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    with AsyncFederationService(env, FixedAgent([1, 1, 0]), max_batch=4,
                                workers=2, pool=pool,
                                shard_backend="process") as svc:
        for i in range(80):
            svc.handle(i % 20)
        # price-war switches are economics-only: every segment shares ONE
        # detection fingerprint, so each worker installed at most one
        # segment core beyond the base — warm caches survive the regime
        # switches exactly like the thread backend's fingerprint keying
        installed = [len(s) for s in svc.core._installed]
        assert all(n <= 1 for n in installed)
