"""Property tests: the process-backed shard pool must agree with the
unsharded reference core under random op streams — evaluations
interleaved with per-image invalidations and mid-stream pool (segment)
swaps — and its partition invariants must survive them.

Mirrors ``tests/test_sharded_core_fuzz.py`` with the thread shards
replaced by worker PROCESSES.  Worker pools are spawned once per module
(seconds each) and shared across hypothesis examples: parity assertions
never depend on cache temperature, and invalidations are mirrored on
both sides, so persistent state cannot mask a divergence — any
cross-example cache reuse only makes the interleaving harsher.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
pytest.importorskip("jax")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.federation.evaluation import SubsetEvaluationCore  # noqa: E402
from repro.federation.providers import default_providers  # noqa: E402
from repro.federation.traces import generate_traces  # noqa: E402
from repro.serving.mp_shards import \
    ProcessShardedSubsetEvaluationCore  # noqa: E402

pytestmark = pytest.mark.slow

TR = generate_traces(default_providers(), 20, seed=9)
N = TR.n_providers
ALL_MASKS = list(range(1, 1 << N))
W = 3


@pytest.fixture(scope="module")
def pair():
    ref = SubsetEvaluationCore(TR)
    cut = ProcessShardedSubsetEvaluationCore(TR, n_shards=W)
    yield ref, cut
    cut.close()


# op stream: ("ap", img, mask) | ("ens", img, mask) | ("inv", [imgs])
_op = st.one_of(
    st.tuples(st.just("ap"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("ens"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("inv"),
              st.lists(st.integers(0, len(TR) - 1), min_size=1,
                       max_size=6)),
)


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=25))
def test_process_shards_match_unsharded_under_invalidations(pair, ops):
    ref, cut = pair
    for op in ops:
        if op[0] == "inv":
            # counts may differ only by entries surviving from earlier
            # examples on ONE side — mirror the drop, then require the
            # caches to answer identically afterwards
            ref.invalidate_images(op[1])
            cut.invalidate_images(op[1])
        elif op[0] == "ap":
            assert cut.ap50(op[1], op[2]) == ref.ap50(op[1], op[2])
        else:
            a, b = cut.ensemble(op[1], op[2]), ref.ensemble(op[1], op[2])
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
            np.testing.assert_array_equal(a.labels, b.labels)
    # partition invariants after the stream: entries only in their home
    # shard, no duplicates across shards
    shard_imgs = cut.shard_images()
    flat = [i for imgs in shard_imgs for i in imgs]
    assert len(flat) == len(set(flat))
    for sid, imgs in enumerate(shard_imgs):
        assert all(i % W == sid for i in imgs)


@pytest.fixture(scope="module")
def pool_pair():
    """A scenario pool plus a process shard pool seeded from its base
    traces — segments cross the process boundary as snapshots."""
    from repro.scenarios import DynamicProviderPool, build_scenario
    providers = default_providers()
    schedule = build_scenario("accuracy_drift", providers, horizon=120)
    pool = DynamicProviderPool(providers, schedule, n_images=16, seed=0)
    cut = ProcessShardedSubsetEvaluationCore.for_pool(pool, W)
    yield pool, cut
    cut.close()


_seg_op = st.one_of(
    st.tuples(st.just("ap"), st.integers(0, 15),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("ens"), st.integers(0, 15),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("swap"), st.integers(0, 119)),
    st.tuples(st.just("inv"),
              st.lists(st.integers(0, 15), min_size=1, max_size=4)),
)


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(_seg_op, min_size=2, max_size=20))
def test_process_shards_match_pool_cores_across_segment_swaps(pool_pair,
                                                              ops):
    """Mid-stream pool swaps: after any interleaving of segment swaps,
    evaluations and invalidations, the worker processes must answer
    exactly like the pool's own (in-process) segment cores."""
    pool, cut = pool_pair
    step = 0
    for op in ops:
        if op[0] == "swap":
            step = op[1]
            continue
        snap = pool.snapshot_at(step)
        ref = pool.core_at(step)
        if op[0] == "inv":
            # the process pool drops the images from EVERY regime it has
            # installed; mirror on every materialized pool core
            cut.invalidate_images(op[1])
            for core in pool._cores.values():
                core.invalidate_images(op[1])
        elif op[0] == "ap":
            assert cut.ap50(op[1], op[2], snapshot=snap) == \
                ref.ap50(op[1], op[2])
        else:
            a = cut.ensemble(op[1], op[2], snapshot=snap)
            b = ref.ensemble(op[1], op[2])
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
            np.testing.assert_array_equal(a.labels, b.labels)
