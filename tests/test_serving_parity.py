"""Prefill+decode must reproduce the full-forward logits for every family,
including the sliding-window ring cache across wrap-around, and the serving
engine must run end-to-end."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ARCH_IDS, ShapeConfig, get_arch
from repro.data.pipeline import batch_for
from repro.models.model import build_model
from repro.serving.engine import Request, ServeEngine

S, EXTRA, MAXLEN = 16, 4, 48


def _no_drop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_prefill_decode_parity(aid):
    cfg = _no_drop(get_arch(aid).reduced())
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    full = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", S + EXTRA, 2, "train"), seed=1).items()}
    ref, _ = model.forward(params, full)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S]
    logits, cache = model.prefill(params, pre, MAXLEN)
    errs = [float(jnp.max(jnp.abs(logits - ref[:, S - 1])))]
    for t in range(EXTRA):
        logits, cache = model.decode_step(params, cache,
                                          full["tokens"][:, S + t:S + t + 1])
        errs.append(float(jnp.max(jnp.abs(logits - ref[:, S + t]))))
    assert max(errs) < 2e-4, errs


def test_ring_cache_wraparound_parity():
    cfg = dataclasses.replace(get_arch("qwen1.5-0.5b").reduced(),
                              sliding_window=8)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    S0, extra = 12, 10                          # crosses the W=8 boundary
    full = {k: jnp.asarray(v) for k, v in batch_for(
        cfg, ShapeConfig("t", S0 + extra, 2, "train"), seed=1).items()}
    ref, _ = model.forward(params, full, window=8)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S0]
    logits, cache = model.prefill(params, pre, 32)
    assert cache["k"].shape[-3] == 8            # ring buffer allocated
    errs = [float(jnp.max(jnp.abs(logits - ref[:, S0 - 1])))]
    for t in range(extra):
        logits, cache = model.decode_step(
            params, cache, full["tokens"][:, S0 + t:S0 + t + 1])
        errs.append(float(jnp.max(jnp.abs(logits - ref[:, S0 + t]))))
    assert max(errs) < 2e-4, errs


@pytest.mark.parametrize("aid", ["qwen1.5-0.5b", "mamba2-370m",
                                 "seamless-m4t-medium"])
def test_serve_engine_end_to_end(aid):
    cfg = get_arch(aid).reduced()
    engine = ServeEngine(cfg, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, size=5 + i,
                                 dtype=np.int32),
                    max_new_tokens=4, rid=i) for i in range(3)]
    outs = engine.serve(reqs)
    assert len(outs) == 3
    for o in outs:
        assert o.tokens.shape == (4,)
        assert np.all(o.tokens >= 0) and np.all(o.tokens < cfg.vocab_size)


def test_serve_deterministic_greedy():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    engine = ServeEngine(cfg, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rng.integers(0, cfg.vocab_size, size=6, dtype=np.int32),
                    max_new_tokens=5)]
    a = engine.serve(reqs)[0].tokens
    b = engine.serve(reqs)[0].tokens
    np.testing.assert_array_equal(a, b)
