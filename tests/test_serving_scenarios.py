"""Scenario-driven serving SLOs on the process-backend plane: per-regime
accounting (outage bills 0 / costs the timeout), snapshot reuse across
revisited regimes, and the benchmark's per-segment aggregation.

Slow-marked: every test spawns worker processes (seconds each on the
spawn context); the nightly --full lane runs them.
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.providers import default_providers
from repro.serving.async_service import AsyncFederationService

pytestmark = pytest.mark.slow


class FixedAgent:
    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _outage_setup(horizon=120, n_images=30):
    from repro.scenarios import (DynamicProviderPool, NonStationaryArmolEnv,
                                 build_scenario)
    providers = default_providers()
    schedule = build_scenario("provider_outage", providers, horizon=horizon)
    pool = DynamicProviderPool(providers, schedule, n_images=n_images,
                               seed=0)
    env = NonStationaryArmolEnv(pool, mode="gt", beta=0.0,
                                observe_pool=False, seed=1)
    return pool, env


def test_outage_regime_bills_zero_and_charges_timeout():
    pool, env = _outage_setup()
    horizon = 120
    # find an outage segment and a provider that is down in it
    down_seg = down_j = None
    for seg in range(pool.schedule.segment_index(horizon - 1) + 1):
        view = pool.view_at(pool.schedule.segment_range(seg)[0])
        if not view.active.all():
            down_seg, down_j = seg, int(np.flatnonzero(~view.active)[0])
            break
    assert down_seg is not None, "provider_outage schedule has no outage"
    action = np.zeros(env.n_providers, np.float32)
    action[down_j] = 1.0                    # select ONLY the down provider
    start = pool.schedule.segment_range(down_seg)[0]
    with AsyncFederationService(env, FixedAgent(action), max_batch=1,
                                workers=2, pool=pool,
                                shard_backend="process") as svc:
        svc.set_clock(int(start))
        res = svc.handle(3)
    assert res.cost_milli_usd == 0.0        # a down provider bills nothing
    # ... but a request that waited on it pays the outage timeout
    assert res.latency_ms == pytest.approx(
        svc._svc.transmission_ms + pool.outage_timeout_ms)
    assert len(res.detections) == 0         # and gets no detections back


def test_revisited_regime_rehits_installed_snapshot():
    pool, env = _outage_setup()
    with AsyncFederationService(env, FixedAgent([1, 1, 0]), max_batch=1,
                                workers=2, pool=pool,
                                shard_backend="process") as svc:
        for i in range(120):                # walk outage AND recovery
            svc.handle(i % 30)
        installed = [set(s) for s in svc.core._installed]
        for i in range(30):                 # revisit: clock past horizon
            svc.handle(i)                   # clamps to the last segment
        assert [set(s) for s in svc.core._installed] == installed
        # recovery restores the pre-outage fingerprint: down segments and
        # up segments share at most 2 distinct detection keys
        assert all(len(s) <= 2 for s in installed)


def test_benchmark_segment_aggregation_matches_accounting():
    """The serving_scenarios benchmark attributes requests to segments by
    arrival index; with max_batch=1 the attribution is exact, and the
    per-segment cost means must reproduce the segment fee vectors."""
    pool, env = _outage_setup()
    sched = pool.schedule
    action = np.asarray([1, 1, 1], np.float32)
    with AsyncFederationService(env, FixedAgent(action), max_batch=1,
                                workers=2, pool=pool,
                                shard_backend="process") as svc:
        results = [svc.handle(i % 30) for i in range(120)]
    segs = np.asarray([sched.segment_index(i) for i in range(120)])
    cost = np.asarray([r.cost_milli_usd for r in results])
    for s in sorted(set(segs.tolist())):
        view = pool.view_at(int(sched.segment_range(s)[0]))
        want = float(view.costs.sum())      # all three providers selected
        got = cost[segs == s]
        np.testing.assert_allclose(got, want, rtol=1e-6)
