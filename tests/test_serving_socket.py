"""Socket (multi-HOST) serving plane: three-way transport parity,
host-kill condemn + requeue, health-check flap tolerance, reply
correlation over TCP, the HTTP front door, and the transport registry.

Slow-marked: every test spawns shard-host processes (seconds each on the
spawn context).  The nightly --full lane runs them; tier-1 stays fast.
"""
import os
import signal
import threading
import time

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.federation.env import ArmolEnv
from repro.federation.evaluation import SubsetEvaluationCore
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving import (AsyncFederationService, FederationClient,
                           FederationService, HttpFrontDoor,
                           HttpServingClient, ShardTransport,
                           ShardWorkerError,
                           SocketShardedSubsetEvaluationCore,
                           ThreadTransport, available_transports,
                           get_transport, register_transport)
from repro.serving.socket_shards import send_msg

pytestmark = pytest.mark.slow

TR = generate_traces(default_providers(), 30, seed=7)
ENV = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
N = TR.n_providers


class FixedAgent:
    """Always selects the same subset (batched-aware, like the real ones)."""

    def __init__(self, action):
        self.action = np.asarray(action, np.float32)

    def select_action(self, s, *, deterministic=False):
        s = np.asarray(s)
        if s.ndim == 2:
            return np.tile(self.action, (len(s), 1)), None
        return self.action.copy(), None


def _assert_results_equal(got, ref):
    np.testing.assert_array_equal(got.action, ref.action)
    assert got.cost_milli_usd == ref.cost_milli_usd
    assert got.latency_ms == ref.latency_ms
    np.testing.assert_array_equal(got.detections.boxes, ref.detections.boxes)
    np.testing.assert_array_equal(got.detections.scores,
                                  ref.detections.scores)
    np.testing.assert_array_equal(got.detections.labels,
                                  ref.detections.labels)


# -- direct core: parity, requeue, correlation, health ---------------------

@pytest.fixture(scope="module")
def sock_core():
    """One spawned 2-host pool shared by the read-only direct-core tests
    (tests that condemn hosts spawn their own)."""
    core = SocketShardedSubsetEvaluationCore(TR, n_shards=2)
    yield core
    core.close()


def test_socket_core_matches_unsharded_bit_for_bit(sock_core):
    ref = SubsetEvaluationCore(TR)
    rng = np.random.default_rng(0)
    for _ in range(25):
        img = int(rng.integers(0, len(TR)))
        mask = int(rng.integers(1, 1 << N))
        a = sock_core.ensemble(img, mask)
        b = ref.ensemble(img, mask)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.labels, b.labels)
    # the one-round-trip lattice too
    la = sock_core.evaluate_lattice(4)
    lb = ref.evaluate_lattice(4)
    np.testing.assert_array_equal(la.masks, lb.masks)
    np.testing.assert_allclose(la.ap, lb.ap)


def test_ring_routing_is_total_and_consistent(sock_core):
    groups = sock_core.partition(range(len(TR)))
    assert sorted(i for g in groups.values() for i in g) == \
        list(range(len(TR)))
    for hid, imgs in groups.items():
        assert all(sock_core.shard_id(i) == hid for i in imgs)


def test_host_kill_requeues_to_survivor_bit_identically():
    ref = SubsetEvaluationCore(TR)
    with SocketShardedSubsetEvaluationCore(TR, n_shards=2) as core:
        rng = np.random.default_rng(3)
        imgs = [int(i) for i in rng.integers(0, len(TR), 12)]
        masks = [int(m) for m in rng.integers(1, 1 << N, 12)]
        victim = core.shard_id(imgs[0])
        os.kill(core.host_pids()[victim], signal.SIGKILL)
        # rows homed to the dead host are requeued to the survivor —
        # the caller sees correct rows, not an error
        rows = core.eval_on(victim, imgs, masks)
        for img, mask, det in zip(imgs, masks, rows):
            np.testing.assert_array_equal(det.boxes,
                                          ref.ensemble(img, mask).boxes)
        assert core.condemned() == [victim]
        # the ring re-homed every image onto the survivor
        survivor = core.healthy_hosts()[0]
        assert {core.shard_id(i) for i in range(len(TR))} == {survivor}
        # condemned host is never reused
        with pytest.raises(ShardWorkerError, match="condemned"):
            core._rpc(victim, ("ping",))


def test_all_hosts_condemned_is_clean_error_not_hang():
    with SocketShardedSubsetEvaluationCore(TR, n_shards=2) as core:
        for pid in core.host_pids():
            os.kill(pid, signal.SIGKILL)
        with pytest.raises(ShardWorkerError):
            core.eval_on(0, [0, 1], [1, 2])
        assert core.healthy_hosts() == []


def test_stale_tcp_reply_condemns_host_never_misattributes():
    """A reply whose id does not match the in-flight request means the
    stream is desynchronized (e.g. a late answer from a previous wedge):
    the host must be condemned, never the row mis-attributed."""
    ref = SubsetEvaluationCore(TR)
    with SocketShardedSubsetEvaluationCore(TR, n_shards=2) as core:
        hid = core.shard_id(5)
        # inject an unsolicited request on the host's main connection:
        # its reply queues ahead of the client's next one
        send_msg(core._socks[hid], (999_999, "ping"))
        # the client detects the id mismatch, condemns the desynced
        # host, and transparently re-routes to the survivor — the
        # caller gets the CORRECT answer, never the stale one
        assert core.ap50(5, 3) == ref.ap50(5, 3)
        assert core.condemned() == [hid]
        # the survivor still answers bit-identically
        other = core.healthy_hosts()[0]
        img = next(i for i in range(len(TR))
                   if core.shard_id(i) == other)
        np.testing.assert_array_equal(core.ensemble(img, 5).boxes,
                                      ref.ensemble(img, 5).boxes)


def test_health_flap_marks_suspect_but_needs_consecutive_failures():
    with SocketShardedSubsetEvaluationCore(
            TR, n_shards=2, health_timeout_s=1.0,
            health_failures_to_condemn=2) as core:
        assert core.health_tick() == []
        # flap: point host 1's address at a dead port for one tick
        good_addr = core._addrs[1]
        core._health_socks[1] = None
        core._addrs[1] = ("127.0.0.1", 1)   # nothing listens there
        assert core.health_tick() == []     # 1 failure -> suspect only
        assert core._suspect[1] == 1 and core.condemned() == []
        core._addrs[1] = good_addr          # flap clears
        assert core.health_tick() == []
        assert core._suspect[1] == 0        # success resets the count
        # a real death: two consecutive failed ticks condemn
        os.kill(core.host_pids()[1], signal.SIGKILL)
        first, second = core.health_tick(), core.health_tick()
        assert first == [] and second == [1]
        assert core.condemned() == [1]


# -- service-level: three-way transport parity + mid-stream host kill ------

def test_three_transports_bit_identical_under_concurrency():
    agent = FixedAgent([0, 1, 1])
    rng = np.random.default_rng(11)
    streams = [[int(i) for i in rng.integers(0, len(TR), 30)]
               for _ in range(3)]
    results = {}
    for transport in ("thread", "process", "socket"):
        collected = [None] * len(streams)
        with AsyncFederationService(ENV, agent, max_batch=8, workers=2,
                                    max_wait_ms=1.0,
                                    transport=transport) as asvc:
            def client(k):
                futs = [asvc.submit(i) for i in streams[k]]
                collected[k] = [f.result() for f in futs]

            threads = [threading.Thread(target=client, args=(k,))
                       for k in range(len(streams))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert asvc.stats["requests"] == sum(map(len, streams))
        results[transport] = collected
    for k in range(len(streams)):
        for a, b, c in zip(results["thread"][k], results["process"][k],
                           results["socket"][k]):
            _assert_results_equal(a, b)
            _assert_results_equal(a, c)


def test_service_host_kill_mid_stream_keeps_serving_no_duplicates():
    agent = FixedAgent([1, 0, 1])
    svc_ref = FederationService(ENV, agent)
    imgs = [int(i) for i in
            np.random.default_rng(5).integers(0, len(TR), 40)]
    refs = [svc_ref.handle(i) for i in imgs]
    with AsyncFederationService(ENV, agent, max_batch=4, workers=2,
                                transport="socket") as asvc:
        first = asvc.handle_many(imgs[:10])
        victim = asvc.core.shard_id(imgs[10])
        os.kill(asvc.core.host_pids()[victim], signal.SIGKILL)
        rest = asvc.handle_many(imgs[10:])
        got = first + rest
        stats = dict(asvc.stats)
        assert asvc.transport.condemned == [victim]
        assert len(asvc.core.healthy_hosts()) == 1
    # every request answered exactly once, bit-identical to the sync
    # reference — the kill surfaced as a requeue, not an error or a dup
    assert len(got) == len(imgs)
    for g, r in zip(got, refs):
        _assert_results_equal(g, r)
    assert stats["requests"] == len(imgs)


# -- HTTP front door -------------------------------------------------------

def test_http_door_matches_in_process_and_degrades_on_kill():
    from repro.obs.prom import parse_prometheus
    agent = FixedAgent([1, 1, 0])
    imgs = [int(i) for i in
            np.random.default_rng(8).integers(0, len(TR), 16)]
    with AsyncFederationService(ENV, agent, max_batch=4, workers=2,
                                transport="socket") as asvc:
        local = FederationClient(asvc)
        with HttpFrontDoor(asvc) as door:
            cli = HttpServingClient(door.url)
            assert cli.healthz()["status"] == "ok"
            got = cli.handle_many(imgs)
            for g, r in zip(got, local.handle_many(imgs)):
                _assert_results_equal(g, r)
            # stats and invalidation flow through the same facade
            assert cli.stats["requests"] == asvc.stats["requests"]
            assert cli.invalidate_images(imgs[:3]) >= 1
            # /metrics is Prometheus text obs tooling can parse
            snap = parse_prometheus(cli.metrics_text())
            assert snap["counters"]["serving.requests"] == \
                asvc.stats["requests"]
            assert any(k.startswith("serving.host_rpc_ms")
                       for k in snap["histograms"])
            # kill one host: /healthz flips to degraded, serving goes on
            victim = asvc.core.healthy_hosts()[0]
            os.kill(asvc.core.host_pids()[victim], signal.SIGKILL)
            assert cli.handle(imgs[0]) is not None
            h = cli.healthz()
            assert h["status"] == "degraded" and h["condemned"] == [victim]
            cli.close()


def test_http_door_rejects_malformed_submit():
    import json
    import urllib.error
    import urllib.request
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]), max_batch=2,
                                workers=1) as asvc:
        with HttpFrontDoor(asvc) as door:
            req = urllib.request.Request(door.url + "/submit",
                                         data=b"not json", method="POST")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req)
            assert ei.value.code == 400
            req = urllib.request.Request(door.url + "/nope")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req)
            assert ei.value.code == 404
            body = json.dumps({"img": 3}).encode()
            req = urllib.request.Request(
                door.url + "/submit", data=body, method="POST",
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req) as resp:
                doc = json.loads(resp.read())
            assert doc["cost_milli_usd"] == float(ENV.costs[0])


# -- transport registry + deprecation --------------------------------------

def test_transport_registry_lists_and_resolves():
    names = available_transports()
    assert {"thread", "process", "socket"} <= set(names)
    assert get_transport("socket").name == "socket"
    with pytest.raises(ValueError, match="unknown shard transport"):
        get_transport("carrier-pigeon")


def test_service_accepts_prebuilt_transport_instance():
    tr = ThreadTransport.build(env=ENV, workers=3)
    with AsyncFederationService(ENV, FixedAgent([1, 0, 0]),
                                max_batch=2, transport=tr) as asvc:
        assert asvc.transport is tr
        assert asvc.workers == 3 and asvc.shard_backend == "thread"
        assert asvc.handle(2).cost_milli_usd == float(ENV.costs[0])


def test_custom_transport_registers_and_serves():
    @register_transport("loopback-test")
    class LoopbackTransport(ThreadTransport):
        pass

    try:
        with AsyncFederationService(ENV, FixedAgent([0, 1, 0]),
                                    max_batch=2, workers=2,
                                    transport="loopback-test") as asvc:
            assert asvc.shard_backend == "loopback-test"
            assert asvc.handle(1).cost_milli_usd == float(ENV.costs[1])
    finally:
        from repro.serving import transports as _t
        _t._REGISTRY.pop("loopback-test", None)


def test_shard_backend_kwarg_warns_but_works():
    with pytest.warns(DeprecationWarning, match="shard_backend"):
        asvc = AsyncFederationService(ENV, FixedAgent([1, 0, 0]),
                                      max_batch=2, workers=2,
                                      shard_backend="thread")
    with asvc:
        assert asvc.shard_backend == "thread"
        assert asvc.handle(3).cost_milli_usd == float(ENV.costs[0])
    # unknown legacy names still fail loudly (and mention the old kwarg)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="shard_backend"):
            AsyncFederationService(ENV, FixedAgent([1, 0, 0]),
                                   shard_backend="greenlet")
